//! End-to-end driver (DESIGN.md §8): data-parallel transformer LM
//! pretraining through the full three-layer stack.
//!
//!   L1  Pallas fused cross-entropy kernel (python/compile/kernels/xent.py)
//!   L2  JAX transformer fwd/bwd            (python/compile/transformer.py)
//!   AOT lowered once to artifacts/lm_step_gpt-tiny.hlo.txt
//!   L3  this binary: PS-resident parameters, P workers computing
//!       gradients via PJRT and INC-ing them back under ESSP.
//!
//! Trains on a synthetic bigram corpus with a known entropy floor
//! (~ln(branch)), logs the loss curve to results/lm_pretrain_loss.csv and
//! prints it. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example lm_pretrain -- [--clocks N]
//!       [--workers P] [--consistency essp:1] [--lr 0.12]`

use essptable::apps::lm::{run_lm, LmTrainConfig, PARAM_TABLE};
use essptable::metrics::export;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::ClusterConfig;
use essptable::runtime::artifact::ArtifactDir;
use essptable::runtime::engine::RuntimeService;
use essptable::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let clocks = args.u64("clocks", 150);
    let workers = args.usize("workers", 2);
    let consistency = Consistency::parse(&args.str("consistency", "essp:1"))
        .map_err(anyhow::Error::msg)?;
    let artifact = args.str("artifact", "lm_step_gpt-tiny");

    let art_dir = ArtifactDir::open(ArtifactDir::default_dir())?;
    let meta = art_dir.meta(&artifact)?.clone();
    let lm = meta.lm_config.clone().expect("lm artifact");
    println!(
        "LM pretrain: {} ({} params, vocab {}, seq {}, batch {}/worker) | {} workers, {}",
        artifact, lm.param_count, lm.vocab, lm.seq, lm.batch, workers, consistency
    );

    let rt = RuntimeService::start(art_dir)?;
    let cfg = LmTrainConfig {
        artifact,
        lr: args.f32("lr", 0.15),
        lr_decay: args.f64("lr-decay", 300.0),
        seed: args.u64("seed", 5),
        branch: args.usize("branch", 4),
    };
    let floor = (cfg.branch as f64).ln();
    let ccfg = ClusterConfig {
        workers,
        shards: 2,
        consistency,
        ..Default::default()
    };

    let report = run_lm(ccfg, cfg, &meta, rt.handle(), clocks)?;
    let series = report.convergence.mean();
    export::convergence_csv(
        Path::new("results/lm_pretrain_loss.csv"),
        &[(consistency.label(), series.clone())],
    )?;

    println!("\nloss curve (mean across workers; entropy floor ~{floor:.3}):");
    let stride = (series.len() / 15).max(1);
    for s in series.iter().step_by(stride) {
        println!("  clock {:>4}  t={:>7.1}s  loss {:.4}", s.clock, s.seconds, s.value);
    }
    let last = series.last().unwrap();
    println!("  clock {:>4}  t={:>7.1}s  loss {:.4}  (final)", last.clock, last.seconds, last.value);
    println!(
        "\nwall {:.1}s | staleness mean {:+.2} | params in PS table {PARAM_TABLE}: {} rows",
        report.wall.as_secs_f64(),
        report.staleness.mean(),
        meta.params.as_ref().map(|p| p.len()).unwrap_or(0),
    );
    println!("csv -> results/lm_pretrain_loss.csv");

    let first = series.first().unwrap().value;
    anyhow::ensure!(
        last.value < first,
        "loss did not improve: {first:.4} -> {:.4}",
        last.value
    );
    println!(
        "OK: loss {:.3} -> {:.3} (floor ~{:.3})",
        first, last.value, floor
    );
    Ok(())
}
