//! Netflix-style matrix factorization across consistency models — the
//! paper's headline workload, at example scale.
//!
//! Trains rank-32 factors of a synthetic 512x512 ratings matrix on a
//! simulated 8-worker cluster under BSP, SSP(3) and ESSP(3), then prints
//! the Fig-2-style comparison: final squared loss (per-iteration quality)
//! and wall time (per-second speed). Uses the pure-rust kernel so the
//! example runs without artifacts; pass --xla to use the AOT JAX+Pallas
//! kernel via PJRT instead.
//!
//! Run: `cargo run --release --example mf_netflix_sim [-- --xla]`

use essptable::apps::mf::train::{final_sq_loss, run_mf, MfBackend, MF_ARTIFACT};
use essptable::apps::mf::MfConfig;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::ClusterConfig;
use essptable::runtime::artifact::ArtifactDir;
use essptable::runtime::engine::RuntimeService;
use essptable::sim::net::NetConfig;
use essptable::sim::straggler::StragglerModel;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");
    let backend = if use_xla {
        let rt = RuntimeService::start(ArtifactDir::open(ArtifactDir::default_dir())?)?;
        let handle = rt.handle();
        handle.preload(MF_ARTIFACT)?;
        std::mem::forget(rt); // keep the service alive for the whole run
        MfBackend::Xla(handle)
    } else {
        MfBackend::Native
    };

    let mf = MfConfig {
        rows: 512,
        cols: 512,
        rank: 32,
        true_rank: 8,
        nnz_per_row: 48,
        noise: 0.05,
        gamma: 0.04,
        lambda: 0.05,
        minibatch: 0.5,
        ..Default::default()
    };

    println!("MF 512x512 rank 32, 8 workers, LAN-profile network, stragglers uniform:2");
    println!(
        "{:<8} {:>14} {:>10} {:>12} {:>8}",
        "model", "final sq loss", "wall (s)", "staleness μ", "comm %"
    );
    for consistency in [
        Consistency::Bsp,
        Consistency::Ssp { s: 3 },
        Consistency::Essp { s: 3 },
    ] {
        let ccfg = ClusterConfig {
            workers: 8,
            shards: 4,
            consistency,
            net: NetConfig::lan(42),
            straggler: StragglerModel::RandomUniform { max_factor: 2.0 },
            virtual_clock: Some(Duration::from_millis(20)),
            ..Default::default()
        };
        let (report, data) = run_mf(ccfg, mf.clone(), 40, backend.clone());
        println!(
            "{:<8} {:>14.2} {:>10.2} {:>12.2} {:>7.1}%",
            consistency.label(),
            final_sq_loss(&report, &data),
            report.wall.as_secs_f64(),
            report.staleness.mean(),
            100.0 * report.comm_fraction()
        );
    }
    println!("\nExpected shape (paper Fig. 2): comparable final loss per iteration;");
    println!("ESSP fastest per second, BSP slowest; ESSP staleness closest to -1.");
    Ok(())
}
