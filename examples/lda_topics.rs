//! LDA topic modeling via collapsed Gibbs sampling on the PS — the
//! paper's second workload, at example scale.
//!
//! Generates a synthetic Dirichlet corpus, runs the sampler on a
//! simulated 4-worker cluster under SSP(2) vs ESSP(2), prints the
//! log-likelihood ascent (Fig-2 style) and the comm/comp breakdown
//! (Fig-1-right style), then shows the top words of a few learned topics
//! to make the output tangible.
//!
//! Run: `cargo run --release --example lda_topics`

use essptable::apps::lda::gibbs::run_lda;
use essptable::apps::lda::{LdaConfig, WT_TABLE};
use essptable::ps::consistency::Consistency;
use essptable::ps::server::ClusterConfig;
use essptable::sim::net::NetConfig;
use essptable::sim::straggler::StragglerModel;
use std::time::Duration;

fn main() {
    let lda = LdaConfig {
        vocab: 400,
        topics: 8,
        docs: 300,
        doc_len: 60,
        minibatch: 0.5, // the paper's 50% minibatch per Clock()
        ..Default::default()
    };
    let clocks = 24;

    println!("LDA V={} K={} D={} | 4 workers, LAN profile", lda.vocab, lda.topics, lda.docs);
    println!(
        "{:<8} {:>16} {:>10} {:>8}",
        "model", "final log-lik", "wall (s)", "comm %"
    );
    let mut last_report = None;
    for consistency in [Consistency::Ssp { s: 2 }, Consistency::Essp { s: 2 }] {
        let ccfg = ClusterConfig {
            workers: 4,
            shards: 2,
            consistency,
            net: NetConfig::lan(7),
            straggler: StragglerModel::RandomUniform { max_factor: 2.0 },
            virtual_clock: Some(Duration::from_millis(20)),
            ..Default::default()
        };
        let (report, _) = run_lda(ccfg, lda.clone(), clocks);
        println!(
            "{:<8} {:>16.1} {:>10.2} {:>7.1}%",
            consistency.label(),
            report.convergence.last_value().unwrap_or(f64::NAN),
            report.wall.as_secs_f64(),
            100.0 * report.comm_fraction()
        );
        last_report = Some(report);
    }

    // Show learned topics from the last (ESSP) run: top-5 words per topic.
    let report = last_report.unwrap();
    println!("\ntop words per topic (ESSP run, word ids):");
    for k in 0..lda.topics {
        let mut scored: Vec<(u64, f32)> = (0..lda.vocab as u64)
            .filter_map(|w| {
                report
                    .table_rows
                    .get(&(WT_TABLE, w))
                    .map(|row| (w, row[k]))
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let top: Vec<String> = scored
            .iter()
            .take(5)
            .map(|(w, c)| format!("w{w}({c:.0})"))
            .collect();
        println!("  topic {k}: {}", top.join(" "));
    }
    println!("\nExpected shape (paper): ESSP log-lik >= SSP at equal clocks, lower comm share.");
}
