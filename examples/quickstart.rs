//! Quickstart: the GET / INC / CLOCK programming model in ~40 lines.
//!
//! Builds a 4-worker / 2-shard cluster with ESSP (staleness 2), shares a
//! single counter table, and shows that (a) additive updates from all
//! workers are never lost, and (b) reads observe bounded-stale values.
//!
//! Run: `cargo run --release --example quickstart`

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::Clock;

fn main() {
    let workers = 4;
    let clocks = 10;

    // 1. Describe the cluster: P workers, S server shards, a consistency
    //    model, and (optionally) a simulated network / stragglers.
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 2,
        consistency: Consistency::Essp { s: 2 },
        ..Default::default()
    });

    // 2. Declare the shared state: table 0 with 4 rows of 2 floats.
    cluster.add_table(TableSpec::zeros(0, 4, 2));

    // 3. Each worker runs this once per clock: read, compute, write.
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, clock: Clock| {
                let row = ps.get((0, w as u64 % 4)); // bounded-stale read
                ps.inc((0, w as u64 % 4), &[1.0, row[0] * 0.0]); // additive
                Some(clock as f64) // optional per-clock metric
            }) as Box<dyn PsApp>
        })
        .collect();

    // 4. Run and inspect.
    let report = cluster.run(apps, clocks);
    println!("wall time          {:?}", report.wall);
    println!(
        "staleness          mean {:+.2}, range [{}, {}]",
        report.staleness.mean(),
        report.staleness.min().unwrap(),
        report.staleness.max().unwrap()
    );
    for r in 0..4u64 {
        println!("row {r}             {:?}", report.table_rows[&(0, r)]);
    }
    let total: f32 = (0..4u64).map(|r| report.table_rows[&(0, r)][0]).sum();
    assert_eq!(total, (workers * clocks as usize) as f32, "no update lost");
    println!("OK: {total} increments accounted for");
}
