"""L2: GPT-style causal transformer LM, functional JAX, flat param list.

The parameter layout is a *flat ordered list* of arrays so that the Rust
coordinator can store each tensor as one parameter-server row and feed the
AOT-compiled step executable positionally. `param_spec(cfg)` is the single
source of truth for that ordering; aot.py serializes it to artifacts/meta.json
and rust/src/apps/lm reads it back.

Architecture: learned token + position embeddings, pre-LN blocks
(causal MHA -> MLP with GELU), final LN, output projection tied to the token
embedding. Loss is next-token cross entropy via the fused Pallas kernel
(kernels/xent.py) wired through a custom VJP (analytic softmax-minus-onehot
backward), so the Pallas kernel stays on the forward hot path while
jax.grad differentiates the whole step.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import xent as xent_kernel


@dataclass(frozen=True)
class LmConfig:
    vocab: int = 4096
    seq: int = 128
    d_model: int = 256
    n_layer: int = 4
    n_head: int = 4
    batch: int = 4

    @property
    def d_head(self):
        return self.d_model // self.n_head

    @property
    def d_ff(self):
        return 4 * self.d_model


# Presets referenced by aot.py --preset and the rust CLI.
PRESETS = {
    # ~4.9M params: sized for the 1-core CPU testbed (DESIGN.md §8).
    "gpt-tiny": LmConfig(vocab=4096, seq=128, d_model=256, n_layer=4, n_head=4, batch=4),
    # ~2x tiny, for scaling checks.
    "gpt-small": LmConfig(vocab=8192, seq=128, d_model=384, n_layer=6, n_head=6, batch=4),
    # ~124M params (GPT-2 small shape): compile-only on this testbed.
    "gpt-100m": LmConfig(vocab=32768, seq=256, d_model=768, n_layer=12, n_head=12, batch=2),
}


def param_spec(cfg: LmConfig):
    """Ordered (name, shape) list — the PS row layout contract."""
    d, ff = cfg.d_model, cfg.d_ff
    spec = [
        ("tok_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq, d)),
    ]
    for i in range(cfg.n_layer):
        spec += [
            (f"l{i}.ln1_g", (d,)),
            (f"l{i}.ln1_b", (d,)),
            (f"l{i}.wqkv", (d, 3 * d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_g", (d,)),
            (f"l{i}.ln2_b", (d,)),
            (f"l{i}.w1", (d, ff)),
            (f"l{i}.b1", (ff,)),
            (f"l{i}.w2", (ff, d)),
            (f"l{i}.b2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return spec


def param_count(cfg: LmConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def init_params(cfg: LmConfig, key):
    """He-ish init matching the spec ordering."""
    spec = param_spec(cfg)
    params = []
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", ".b1", ".b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 0.02 if "emb" in name else 1.0 / jnp.sqrt(fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wqkv, wo, cfg: LmConfig):
    B, S, d = x.shape
    qkv = x @ wqkv  # (B, S, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(B, S, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(cfg.d_head).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((S, S), bool))
    att = jnp.where(causal, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, d)
    return out @ wo


@jax.custom_vjp
def fused_xent(logits, targets):
    return xent_kernel.token_xent(logits, targets)


def _fused_xent_fwd(logits, targets):
    return xent_kernel.token_xent(logits, targets), (logits, targets)


def _fused_xent_bwd(res, g):
    logits, targets = res
    sm = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    dlogits = (sm - onehot) * g[:, None]
    return dlogits, jnp.zeros(targets.shape, jax.dtypes.float0)


fused_xent.defvjp(_fused_xent_fwd, _fused_xent_bwd)


def forward_logits(params, tokens, cfg: LmConfig):
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    it = iter(params)
    nxt = lambda: next(it)
    tok_emb, pos_emb = nxt(), nxt()
    x = tok_emb[tokens] + pos_emb[None, : tokens.shape[1]]
    for _ in range(cfg.n_layer):
        ln1_g, ln1_b, wqkv, wo = nxt(), nxt(), nxt(), nxt()
        ln2_g, ln2_b, w1, b1, w2, b2 = nxt(), nxt(), nxt(), nxt(), nxt(), nxt()
        x = x + _attention(_layernorm(x, ln1_g, ln1_b), wqkv, wo, cfg)
        h = _layernorm(x, ln2_g, ln2_b)
        x = x + jax.nn.gelu(h @ w1 + b1) @ w2 + b2
    lnf_g, lnf_b = nxt(), nxt()
    x = _layernorm(x, lnf_g, lnf_b)
    return x @ tok_emb.T  # tied output head


def loss_fn(params, tokens, targets, cfg: LmConfig):
    """Mean next-token NLL over the batch, via the fused Pallas kernel."""
    logits = forward_logits(params, tokens, cfg)
    B, S, V = logits.shape
    nll = fused_xent(logits.reshape(B * S, V), targets.reshape(B * S))
    return jnp.mean(nll)
