"""AOT lowering: JAX (L2, calling L1 Pallas) -> HLO *text* artifacts.

Interchange format is HLO text, NOT `.serialize()`d HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/).

Emits, per entry point:
    artifacts/<name>.hlo.txt     — the lowered module
and one shared
    artifacts/meta.json          — input/output shapes + LM param layout,
                                   consumed by rust/src/runtime/artifact.rs.

`make artifacts` runs this once; python is never on the request path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--lm-preset gpt-tiny ...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, transformer

# Fixed MF block geometry for the AOT artifact; the rust MF app partitions
# the rating matrix into blocks of exactly this shape (config validates).
MF_BM, MF_BN, MF_K = 64, 64, 32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_meta(args, names):
    return [
        {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
        for n, a in zip(names, args)
    ]


def lower_mf():
    args = (
        _spec((MF_BM, MF_K)),
        _spec((MF_K, MF_BN)),
        _spec((MF_BM, MF_BN)),
        _spec((MF_BM, MF_BN)),
        _spec((2,)),
    )
    lowered = jax.jit(model.mf_block_step).lower(*args)
    meta = {
        "inputs": _io_meta(args, ["L", "R", "D", "mask", "hp"]),
        "outputs": [
            {"name": "dL", "shape": [MF_BM, MF_K], "dtype": "float32"},
            {"name": "dR", "shape": [MF_K, MF_BN], "dtype": "float32"},
            {"name": "stats", "shape": [2], "dtype": "float32"},
        ],
        "block": {"bm": MF_BM, "bn": MF_BN, "k": MF_K},
    }
    return to_hlo_text(lowered), meta


def lower_lm(preset: str, eval_only: bool):
    cfg = transformer.PRESETS[preset]
    spec = transformer.param_spec(cfg)
    tok = _spec((cfg.batch, cfg.seq), jnp.int32)
    params = tuple(_spec(s) for _, s in spec)
    fn = model.lm_eval(cfg) if eval_only else model.lm_step(cfg)
    lowered = jax.jit(fn).lower(tok, tok, *params)
    meta = {
        "inputs": _io_meta(
            (tok, tok) + params, ["tokens", "targets"] + [n for n, _ in spec]
        ),
        "outputs": (
            [{"name": "loss", "shape": [], "dtype": "float32"}]
            + (
                []
                if eval_only
                else [
                    {"name": f"d_{n}", "shape": list(s), "dtype": "float32"}
                    for n, s in spec
                ]
            )
        ),
        "lm_config": {
            "preset": preset,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "d_model": cfg.d_model,
            "n_layer": cfg.n_layer,
            "n_head": cfg.n_head,
            "batch": cfg.batch,
            "param_count": int(transformer.param_count(cfg)),
        },
        "params": [{"name": n, "shape": list(s)} for n, s in spec],
    }
    return to_hlo_text(lowered), meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--lm-presets",
        nargs="*",
        default=["gpt-tiny"],
        choices=sorted(transformer.PRESETS),
        help="LM presets to lower (gpt-100m is compile-only on this testbed)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta_all = {}

    text, meta = lower_mf()
    name = f"mf_block_{MF_BM}x{MF_BN}x{MF_K}"
    with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    meta_all[name] = meta
    print(f"lowered {name}: {len(text)} chars")

    for preset in args.lm_presets:
        for eval_only, tag in ((False, "step"), (True, "eval")):
            text, meta = lower_lm(preset, eval_only)
            name = f"lm_{tag}_{preset}"
            with open(os.path.join(args.out_dir, f"{name}.hlo.txt"), "w") as f:
                f.write(text)
            meta_all[name] = meta
            print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta_all, f, indent=1)
    print(f"wrote meta.json with {len(meta_all)} artifacts")


if __name__ == "__main__":
    main()
