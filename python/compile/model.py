"""L2 entry points lowered by aot.py — the paper's compute graphs.

Two workloads, matching the paper's evaluation:

  * mf_block_step — SGD deltas for one dense rating block of the Netflix-
    style matrix factorization (calls the L1 Pallas kernel mf_sgd).
  * lm_step / lm_eval — fwd+bwd (resp. fwd) of the transformer LM used by
    the end-to-end data-parallel training driver (examples/lm_pretrain.rs);
    the loss calls the L1 fused cross-entropy Pallas kernel.

All functions are pure and take/return flat tuples of arrays so the rust
runtime can drive them positionally. Hyperparameters that must vary at run
time (step size, l2) travel as an f32[2] tensor, not as python constants.
"""

import jax
import jax.numpy as jnp

from . import transformer
from .kernels import mf_sgd


def mf_block_step(L, R, D, mask, hp):
    """SGD deltas for one (BM, BN) rating block.

    Args:
        L: (BM, K), R: (K, BN), D/mask: (BM, BN), hp: f32[2] = [gamma, lam].

    Returns:
        (dL, dR, stats) with stats = f32[2] = [sq_loss, obs_count].
    """
    dl, dr, loss, cnt = mf_sgd.mf_block_grads(L, R, D, mask, hp[0], hp[1])
    return dl, dr, jnp.stack([loss, cnt])


def lm_step(cfg: transformer.LmConfig):
    """Returns f(tokens, targets, *params) -> (loss, *grads)."""

    def step(tokens, targets, *params):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(
            list(params), tokens, targets, cfg
        )
        return (loss,) + tuple(grads)

    return step


def lm_eval(cfg: transformer.LmConfig):
    """Returns f(tokens, targets, *params) -> (loss,)."""

    def ev(tokens, targets, *params):
        return (transformer.loss_fn(list(params), tokens, targets, cfg),)

    return ev
