"""L1 Pallas kernel: tiled SGD gradient for one dense MF rating block.

This is the compute hot-spot of the paper's Matrix Factorization workload
(SGD on the l2-penalized Netflix objective). A worker holds a (BM, BN)
rating block, the corresponding L row-block (BM, K) and R column-block
(K, BN) fetched from the parameter server, and computes additive deltas:

    E  = mask * (D - L @ R)
    dL = gamma * (E @ R.T  - lam * L)
    dR = gamma * (L.T @ E  - lam * R)

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper is CPU-cluster
based, so there is no GPU kernel to port — instead we design for the MXU
directly. The kernel walks the grid over row-tiles of the block
(grid = BM / TM); each grid step keeps an (TM, K) slab of L, the full
(K, BN) R panel, and an (TM, BN) rating tile resident in VMEM, and issues
three MXU matmuls (L@R, E@R.T, L.T@E). dR, the squared loss and the
observed count are accumulated across sequential grid steps into output
tiles that stay in VMEM (revisited outputs are not flushed between steps
when their index map is constant).

VMEM footprint per grid step with defaults (TM=32, K=32, BN=64, f32):
    L 32*32 + R 32*64 + D/mask 2*32*64 + E 32*64 + dL 32*32 + dR 32*64
    = ~0.06 MB  << 16 MB VMEM — leaves room to scale TM/BN up ~16x each.
MXU estimate: 3 matmuls = 2*TM*K*BN*3 FLOPs per step over
(TM*K + K*BN + 3*TM*BN) * 4 bytes moved — arithmetic intensity ~24 FLOP/B
at defaults, ~MXU-bound once TM,BN >= 128.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ref.mf_block_grads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mf_kernel(gamma_lam_ref, l_ref, r_ref, d_ref, m_ref, dl_ref, dr_ref, loss_ref):
    """One grid step: row-tile i of the rating block.

    Revisited outputs (dr_ref, loss_ref) have constant index maps, so they
    stay in VMEM across the sequential grid and act as accumulators.
    """
    i = pl.program_id(0)
    gamma = gamma_lam_ref[0]
    lam = gamma_lam_ref[1]

    L = l_ref[...]            # (TM, K)
    R = r_ref[...]            # (K, BN)
    D = d_ref[...]            # (TM, BN)
    M = m_ref[...]            # (TM, BN)

    E = M * (D - jnp.dot(L, R, preferred_element_type=jnp.float32))
    dl_ref[...] = gamma * (jnp.dot(E, R.T, preferred_element_type=jnp.float32) - lam * L)

    dr_partial = jnp.dot(L.T, E, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        # First row-tile: seed the accumulators (regularizer counted once).
        dr_ref[...] = gamma * (dr_partial - lam * R)
        loss_ref[0] = jnp.sum(E * E)
        loss_ref[1] = jnp.sum(M)

    @pl.when(i > 0)
    def _accum():
        dr_ref[...] += gamma * dr_partial
        loss_ref[0] += jnp.sum(E * E)
        loss_ref[1] += jnp.sum(M)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def mf_block_grads(L, R, D, mask, gamma, lam, *, tile_m=32):
    """Pallas-tiled SGD deltas for one dense rating block.

    Same contract as ref.mf_block_grads, plus the row-tile size. BM must be
    divisible by tile_m.

    Returns (dL, dR, sq_loss, obs_count).
    """
    BM, K = L.shape
    K2, BN = R.shape
    assert K == K2, f"rank mismatch {K} vs {K2}"
    assert D.shape == (BM, BN) and mask.shape == (BM, BN)
    assert BM % tile_m == 0, f"BM={BM} not divisible by tile_m={tile_m}"
    grid = (BM // tile_m,)

    gamma_lam = jnp.stack([jnp.float32(gamma), jnp.float32(lam)])

    dl, dr, loss_cnt = pl.pallas_call(
        _mf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),                # gamma/lam
            pl.BlockSpec((tile_m, K), lambda i: (i, 0)),       # L row-tile
            pl.BlockSpec((K, BN), lambda i: (0, 0)),           # R panel
            pl.BlockSpec((tile_m, BN), lambda i: (i, 0)),      # D tile
            pl.BlockSpec((tile_m, BN), lambda i: (i, 0)),      # mask tile
        ],
        out_specs=[
            pl.BlockSpec((tile_m, K), lambda i: (i, 0)),       # dL row-tile
            pl.BlockSpec((K, BN), lambda i: (0, 0)),           # dR accumulator
            pl.BlockSpec((2,), lambda i: (0,)),                # [loss, cnt]
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BM, K), jnp.float32),
            jax.ShapeDtypeStruct((K, BN), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        interpret=True,
    )(gamma_lam, L, R, D, mask)

    return dl, dr, loss_cnt[0], loss_cnt[1]
