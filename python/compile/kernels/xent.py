"""L1 Pallas kernel: fused log-softmax cross-entropy over the vocab axis.

The LM loss is the hot spot of the transformer workload once the vocab axis
dominates ((T, V) logits with V >> d). The naive jnp path materializes a
(T, V) softmax plus a (T, V) one-hot gather; this kernel fuses max, exp-sum
and the target gather in one pass over each row-tile, so each logit is read
exactly once from VMEM and nothing (T, V)-shaped is written back.

TPU mapping: grid walks row-tiles (grid = T / TT); each step holds a
(TT, V) logit tile and a (TT, 1) target tile in VMEM and reduces along the
lane axis (VPU reduction, no MXU involvement — this kernel is bandwidth
bound, roofline = HBM read of the logits). VMEM per step at TT=8, V=4096:
8*4096*4 = 128 KB.

interpret=True: validated against ref.token_xent.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, tgt_ref, out_ref):
    logits = logits_ref[...]                      # (TT, V)
    tgt = tgt_ref[...]                            # (TT, 1) int32
    m = jnp.max(logits, axis=-1, keepdims=True)   # (TT, 1)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    V = logits.shape[-1]
    onehot = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) == tgt
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    out_ref[...] = (lse - picked)[:, None]


@functools.partial(jax.jit, static_argnames=("tile_t",))
def token_xent(logits, targets, *, tile_t=8):
    """Per-token cross entropy, fused. Same contract as ref.token_xent.

    Args:
        logits: (T, V) float32, T divisible by tile_t.
        targets: (T,) int32.

    Returns:
        (T,) float32 nll per token.
    """
    T, V = logits.shape
    assert T % tile_t == 0, f"T={T} not divisible by tile_t={tile_t}"
    grid = (T // tile_t,)
    out = pl.pallas_call(
        _xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, V), lambda i: (i, 0)),
            pl.BlockSpec((tile_t, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_t, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 1), jnp.float32),
        interpret=True,
    )(logits, targets[:, None].astype(jnp.int32))
    return out[:, 0]
