"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest compares kernel output against
these references with assert_allclose over hypothesis-generated shapes/seeds
(see python/tests/). The references are also what the theory in the paper
assumes: exact gradients of the l2-penalized MF objective and the exact
token-level cross entropy.
"""

import jax.numpy as jnp


def mf_block_grads(L, R, D, mask, gamma, lam):
    """Exact SGD deltas for one dense rating block.

    Objective (paper, "SGD for Low Rank Matrix Factorization"):
        sum_{(i,j) observed} (D_ij - L_i: R_:j)^2 + lam (|L|_F^2 + |R|_F^2)

    Deltas (constants absorbed into gamma, as in the paper):
        dL = gamma * (E @ R.T - lam * L)      E = mask * (D - L @ R)
        dR = gamma * (L.T @ E - lam * R)

    Args:
        L: (BM, K) row-factor block.
        R: (K, BN) column-factor block.
        D: (BM, BN) dense rating block (unobserved entries arbitrary).
        mask: (BM, BN) 1.0 where observed, 0.0 elsewhere.
        gamma: scalar step size.
        lam: scalar l2 penalty.

    Returns:
        (dL, dR, sq_loss, obs_count): deltas to *add* to L and R, the sum of
        squared residuals over observed entries, and the observed count.
    """
    E = mask * (D - L @ R)
    dL = gamma * (E @ R.T - lam * L)
    dR = gamma * (L.T @ E - lam * R)
    sq_loss = jnp.sum(E * E)
    cnt = jnp.sum(mask)
    return dL, dR, sq_loss, cnt


def token_xent(logits, targets):
    """Per-token cross entropy: -log softmax(logits)[target].

    Args:
        logits: (T, V) float32.
        targets: (T,) int32 in [0, V).

    Returns:
        (T,) float32 per-token negative log-likelihood.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - tgt
