"""L2 correctness: transformer shapes, init, gradients, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, transformer

jax.config.update("jax_platform_name", "cpu")

TINY = transformer.LmConfig(vocab=64, seq=16, d_model=32, n_layer=2, n_head=2, batch=2)


def _data(cfg, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    tokens = jax.random.randint(ks[0], (cfg.batch, cfg.seq), 0, cfg.vocab)
    targets = jax.random.randint(ks[1], (cfg.batch, cfg.seq), 0, cfg.vocab)
    return tokens, targets


def test_param_spec_shapes_match_init():
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    spec = transformer.param_spec(TINY)
    assert len(params) == len(spec)
    for p, (name, shape) in zip(params, spec):
        assert p.shape == shape, name


def test_param_count_presets():
    # gpt-tiny must be a few-million-param model; gpt-100m ~ 100M.
    n_tiny = transformer.param_count(transformer.PRESETS["gpt-tiny"])
    n_100m = transformer.param_count(transformer.PRESETS["gpt-100m"])
    assert 3e6 < n_tiny < 8e6, n_tiny
    assert 8e7 < n_100m < 1.6e8, n_100m


def test_forward_shape_and_finite():
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    tokens, _ = _data(TINY)
    logits = transformer.forward_logits(params, tokens, TINY)
    assert logits.shape == (TINY.batch, TINY.seq, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    tokens, targets = _data(TINY)
    loss = transformer.loss_fn(params, tokens, targets, TINY)
    assert abs(float(loss) - np.log(TINY.vocab)) < 1.0


def test_causality():
    """Changing a future token must not change past logits."""
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    tokens, _ = _data(TINY)
    logits1 = transformer.forward_logits(params, tokens, TINY)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % TINY.vocab)
    logits2 = transformer.forward_logits(params, tokens2, TINY)
    np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1], atol=1e-5)
    assert not np.allclose(logits1[:, -1], logits2[:, -1], atol=1e-5)


def test_lm_step_outputs_grads_for_every_param():
    step = jax.jit(model.lm_step(TINY))
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    tokens, targets = _data(TINY)
    out = step(tokens, targets, *params)
    assert len(out) == 1 + len(params)
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_grad_matches_finite_difference():
    params = transformer.init_params(TINY, jax.random.PRNGKey(1))
    tokens, targets = _data(TINY, seed=3)
    loss = lambda ps: transformer.loss_fn(ps, tokens, targets, TINY)
    grads = jax.grad(loss)(params)
    # Probe one weight in wqkv of layer 0 (index 4 in the spec). f32 central
    # differences need a fairly large eps; tolerance is correspondingly loose
    # (this is a sanity check on wiring, not a numerics test — the exact
    # gradient check is test_xent_kernel.test_custom_vjp_matches_jnp_grad).
    idx, (r, c) = 4, (3, 5)
    eps = 3e-2
    bumped_p = [p.at[r, c].add(eps) if i == idx else p for i, p in enumerate(params)]
    bumped_m = [p.at[r, c].add(-eps) if i == idx else p for i, p in enumerate(params)]
    fd = (loss(bumped_p) - loss(bumped_m)) / (2 * eps)
    np.testing.assert_allclose(float(grads[idx][r, c]), float(fd), rtol=0.25)


def test_sgd_reduces_loss():
    """A few SGD steps on a fixed batch must drive the loss down (memorize)."""
    step = jax.jit(model.lm_step(TINY))
    params = transformer.init_params(TINY, jax.random.PRNGKey(0))
    tokens, targets = _data(TINY)
    out = step(tokens, targets, *params)
    loss0 = float(out[0])
    lr = 0.5
    for _ in range(20):
        out = step(tokens, targets, *params)
        params = [p - lr * g for p, g in zip(params, out[1:])]
    loss1 = float(model.lm_eval(TINY)(tokens, targets, *params)[0])
    assert loss1 < 0.5 * loss0, (loss0, loss1)


def test_eval_matches_step_loss():
    params = transformer.init_params(TINY, jax.random.PRNGKey(2))
    tokens, targets = _data(TINY, seed=5)
    l_step = float(model.lm_step(TINY)(tokens, targets, *params)[0])
    l_eval = float(model.lm_eval(TINY)(tokens, targets, *params)[0])
    np.testing.assert_allclose(l_step, l_eval, rtol=1e-6)


def test_mf_block_step_hp_tensor():
    """model.mf_block_step must honor hp = [gamma, lam] as runtime inputs."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    L = jax.random.normal(ks[0], (64, 32))
    R = jax.random.normal(ks[1], (32, 64))
    D = jax.random.normal(ks[2], (64, 64))
    M = (jax.random.uniform(ks[3], (64, 64)) < 0.2).astype(jnp.float32)
    from compile.kernels import ref

    for gamma, lam in ((0.01, 0.0), (0.2, 0.3)):
        dl, dr, stats = model.mf_block_step(L, R, D, M, jnp.array([gamma, lam]))
        dl2, dr2, loss2, cnt2 = ref.mf_block_grads(L, R, D, M, gamma, lam)
        np.testing.assert_allclose(dl, dl2, rtol=3e-5, atol=1e-6)
        np.testing.assert_allclose(dr, dr2, rtol=3e-5, atol=1e-6)
        np.testing.assert_allclose(stats[0], loss2, rtol=3e-5)
        assert float(stats[1]) == float(cnt2)
