"""AOT path: lowered HLO text is well-formed and numerically faithful.

The heavyweight check — rust loading + executing the artifacts — lives in
rust/tests/integration_runtime.rs; here we verify the python half: the text
is a parseable HLO module with the right parameter count, and compiling the
lowered module gives the same numbers as eager execution.
"""

import json
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, transformer

jax.config.update("jax_platform_name", "cpu")


def test_mf_hlo_text_wellformed():
    text, meta = aot.lower_mf()
    assert "ENTRY" in text and "HloModule" in text
    # 5 inputs, tuple root of 3 outputs.
    assert len(meta["inputs"]) == 5
    assert len(meta["outputs"]) == 3
    assert meta["block"] == {"bm": 64, "bn": 64, "k": 32}


def test_mf_lowered_matches_eager():
    args = [
        jax.random.normal(jax.random.PRNGKey(i), s)
        for i, s in enumerate([(64, 32), (32, 64), (64, 64), (64, 64)])
    ]
    args[3] = (args[3] > 0.5).astype(jnp.float32)
    hp = jnp.array([0.05, 0.1], jnp.float32)
    eager = model.mf_block_step(*args, hp)
    compiled = jax.jit(model.mf_block_step).lower(*args, hp).compile()(*args, hp)
    for e, c in zip(eager, compiled):
        np.testing.assert_allclose(e, c, rtol=1e-6)


def test_lm_hlo_text_wellformed():
    cfg = transformer.PRESETS["gpt-tiny"]
    spec = transformer.param_spec(cfg)
    text, meta = aot.lower_lm("gpt-tiny", eval_only=False)
    assert "ENTRY" in text
    assert len(meta["inputs"]) == 2 + len(spec)
    assert len(meta["outputs"]) == 1 + len(spec)
    assert meta["lm_config"]["param_count"] == transformer.param_count(cfg)
    text_e, meta_e = aot.lower_lm("gpt-tiny", eval_only=True)
    assert len(meta_e["outputs"]) == 1
    assert len(text_e) < len(text)  # eval module must be smaller than fwd+bwd


def test_cli_writes_artifacts(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--lm-presets"],  # no LM presets: quick MF-only run
        capture_output=True,
        text=True,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert out.returncode == 0, out.stderr
    files = {p.name for p in tmp_path.iterdir()}
    assert "mf_block_64x64x32.hlo.txt" in files
    assert "meta.json" in files
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert "mf_block_64x64x32" in meta
