"""L1 correctness: Pallas MF kernel vs pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mf_sgd, ref

jax.config.update("jax_platform_name", "cpu")


def _mk(seed, bm, bn, k, density):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    L = 0.5 * jax.random.normal(ks[0], (bm, k), jnp.float32)
    R = 0.5 * jax.random.normal(ks[1], (k, bn), jnp.float32)
    D = jax.random.normal(ks[2], (bm, bn), jnp.float32)
    M = (jax.random.uniform(ks[3], (bm, bn)) < density).astype(jnp.float32)
    return L, R, D, M


def _check(L, R, D, M, gamma, lam, tile_m):
    dl, dr, loss, cnt = mf_sgd.mf_block_grads(L, R, D, M, gamma, lam, tile_m=tile_m)
    dl2, dr2, loss2, cnt2 = ref.mf_block_grads(L, R, D, M, gamma, lam)
    np.testing.assert_allclose(dl, dl2, rtol=3e-5, atol=1e-6)
    np.testing.assert_allclose(dr, dr2, rtol=3e-5, atol=1e-6)
    np.testing.assert_allclose(loss, loss2, rtol=3e-5, atol=1e-6)
    assert float(cnt) == float(cnt2)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bm_tiles=st.integers(1, 4),
    tile_m=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([4, 16, 32]),
    density=st.floats(0.05, 1.0),
    gamma=st.floats(1e-4, 0.5),
    lam=st.floats(0.0, 0.5),
)
def test_matches_ref_sweep(seed, bm_tiles, tile_m, bn, k, density, gamma, lam):
    bm = bm_tiles * tile_m
    L, R, D, M = _mk(seed, bm, bn, k, density)
    _check(L, R, D, M, gamma, lam, tile_m)


def test_empty_mask_only_regularizer():
    """With no observed entries the update is pure l2 shrinkage."""
    L, R, D, _ = _mk(0, 64, 64, 32, 1.0)
    M = jnp.zeros((64, 64), jnp.float32)
    dl, dr, loss, cnt = mf_sgd.mf_block_grads(L, R, D, M, 0.1, 0.05)
    np.testing.assert_allclose(dl, -0.1 * 0.05 * L, rtol=1e-6)
    np.testing.assert_allclose(dr, -0.1 * 0.05 * R, rtol=1e-6)
    assert float(loss) == 0.0 and float(cnt) == 0.0


def test_full_mask():
    L, R, D, _ = _mk(1, 64, 32, 16, 1.0)
    M = jnp.ones((64, 32), jnp.float32)
    _check(L, R, D, M, 0.01, 0.0, 32)


def test_single_tile_grid():
    """tile_m == BM: grid of one step still seeds accumulators correctly."""
    L, R, D, M = _mk(2, 32, 32, 8, 0.3)
    _check(L, R, D, M, 0.05, 0.1, 32)


def test_zero_step_size():
    L, R, D, M = _mk(3, 64, 64, 32, 0.3)
    dl, dr, _, _ = mf_sgd.mf_block_grads(L, R, D, M, 0.0, 0.05)
    np.testing.assert_allclose(dl, jnp.zeros_like(dl), atol=1e-8)
    np.testing.assert_allclose(dr, jnp.zeros_like(dr), atol=1e-8)


def test_descends_objective():
    """One kernel step on a noiseless low-rank block reduces the sq loss."""
    k0 = jax.random.PRNGKey(7)
    Lt = jax.random.normal(k0, (64, 8))
    Rt = jax.random.normal(jax.random.PRNGKey(8), (8, 64))
    D = Lt @ Rt
    M = jnp.ones_like(D)
    L, R, _, _ = _mk(9, 64, 64, 8, 1.0)
    _, _, loss0, _ = mf_sgd.mf_block_grads(L, R, D, M, 0.002, 0.0)
    for _ in range(60):
        dl, dr, _, _ = mf_sgd.mf_block_grads(L, R, D, M, 0.002, 0.0)
        L, R = L + dl, R + dr
    _, _, loss1, _ = mf_sgd.mf_block_grads(L, R, D, M, 0.002, 0.0)
    assert float(loss1) < 0.2 * float(loss0), (float(loss0), float(loss1))


def test_rejects_bad_tile():
    L, R, D, M = _mk(4, 48, 32, 8, 0.5)
    with pytest.raises(AssertionError):
        mf_sgd.mf_block_grads(L, R, D, M, 0.1, 0.1, tile_m=32)
