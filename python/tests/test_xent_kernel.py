"""L1 correctness: fused cross-entropy Pallas kernel vs oracle + VJP check."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import transformer
from compile.kernels import ref, xent

jax.config.update("jax_platform_name", "cpu")


def _mk(seed, t, v, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = scale * jax.random.normal(ks[0], (t, v), jnp.float32)
    targets = jax.random.randint(ks[1], (t,), 0, v)
    return logits, targets


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t_tiles=st.integers(1, 4),
    tile_t=st.sampled_from([1, 4, 8]),
    v=st.sampled_from([2, 33, 256, 1000]),
    scale=st.floats(0.1, 30.0),
)
def test_matches_ref_sweep(seed, t_tiles, tile_t, v, scale):
    t = t_tiles * tile_t
    logits, targets = _mk(seed, t, v, scale)
    got = xent.token_xent(logits, targets, tile_t=tile_t)
    want = ref.token_xent(logits, targets)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-5)


def test_extreme_logits_stable():
    """Large-magnitude logits: the fused max-subtraction keeps it finite."""
    logits = jnp.array([[1e4, -1e4, 0.0, 5.0]] * 8, jnp.float32)
    targets = jnp.array([0, 1, 2, 3, 0, 1, 2, 3], jnp.int32)
    got = xent.token_xent(logits, targets)
    want = ref.token_xent(logits, targets)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_certain_prediction_near_zero_loss():
    logits = jnp.full((8, 16), -30.0).at[:, 3].set(30.0)
    targets = jnp.full((8,), 3, jnp.int32)
    got = xent.token_xent(logits, targets)
    np.testing.assert_allclose(got, jnp.zeros(8), atol=1e-5)


def test_boundary_targets():
    logits, _ = _mk(0, 8, 64)
    for tgt in (0, 63):
        targets = jnp.full((8,), tgt, jnp.int32)
        np.testing.assert_allclose(
            xent.token_xent(logits, targets),
            ref.token_xent(logits, targets),
            rtol=3e-5,
            atol=1e-5,
        )


def test_custom_vjp_matches_jnp_grad():
    """grad through fused_xent == grad through the pure-jnp oracle."""
    logits, targets = _mk(11, 16, 128)

    def f_fused(lg):
        return jnp.mean(transformer.fused_xent(lg, targets))

    def f_ref(lg):
        return jnp.mean(ref.token_xent(lg, targets))

    g1 = jax.grad(f_fused)(logits)
    g2 = jax.grad(f_ref)(logits)
    np.testing.assert_allclose(g1, g2, rtol=3e-5, atol=1e-6)
