//! Server shard: owns a partition of the rows, applies coalesced updates,
//! tracks the table clock, answers pulls (SSP) and fires eager push waves
//! (ESSP) — the server half of the paper's ESSPTable.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::msg::{PushRow, ToShard, ToWorker};
use super::types::{Clock, Key, WorkerId};
use super::vap::VapTracker;
use super::vclock::MinClock;
use crate::sim::net::{NetHandle, NodeId, Packet};

/// A stored row: payload plus best-effort freshness.
#[derive(Debug, Clone)]
pub struct Row {
    pub data: Vec<f32>,
    /// Max update clock reflected in `data` (NEVER if untouched).
    pub fresh: Clock,
}

/// Counters reported back to the harness at shutdown.
#[derive(Debug, Default, Clone)]
pub struct ShardStats {
    pub gets_served: u64,
    pub gets_queued: u64,
    pub updates_applied: u64,
    pub rows_pushed: u64,
    pub push_waves: u64,
}

struct PendingGet {
    key: Key,
    worker: WorkerId,
    min_vclock: Clock,
}

/// Shard state. Owned by its thread after `spawn`; constructed (and row-
/// initialized) by the coordinator before launch.
pub struct Shard {
    id: usize,
    rows: HashMap<Key, Row>,
    clocks: MinClock,
    /// ESSP push lists: worker -> keys it registered (insertion-ordered
    /// Vec — iteration order affects only message layout).
    registered: Vec<Vec<Key>>,
    /// Rows updated since the last push wave: waves carry only these (the
    /// paper's server "pushes out the [updated] table-rows"), which keeps
    /// wave size proportional to update traffic, not to the working set.
    dirty: std::collections::HashSet<Key>,
    pending: Vec<PendingGet>,
    push_enabled: bool,
    net: NetHandle,
    vap: Option<Arc<VapTracker>>,
    stats: ShardStats,
}

impl Shard {
    pub fn new(
        id: usize,
        workers: usize,
        push_enabled: bool,
        net: NetHandle,
        vap: Option<Arc<VapTracker>>,
    ) -> Self {
        Self {
            id,
            rows: HashMap::new(),
            clocks: MinClock::new(workers),
            registered: vec![Vec::new(); workers],
            dirty: std::collections::HashSet::new(),
            pending: Vec::new(),
            push_enabled,
            net,
            vap,
            stats: ShardStats::default(),
        }
    }

    /// Pre-launch initialization of a row (coordinator only).
    pub fn init_row(&mut self, key: Key, data: Vec<f32>) {
        self.rows.insert(
            key,
            Row {
                data,
                fresh: super::types::NEVER,
            },
        );
    }

    pub fn table_clock(&self) -> Clock {
        self.clocks.min()
    }

    pub fn row(&self, key: &Key) -> Option<&Row> {
        self.rows.get(key)
    }

    pub fn stats(&self) -> &ShardStats {
        &self.stats
    }

    /// Drive the shard from its inbox until Shutdown. Returns final stats
    /// and the row store (for end-of-run evaluation by the harness).
    pub fn run(mut self, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) {
        while let Ok(msg) = inbox.recv() {
            if !self.handle(msg) {
                break;
            }
        }
        let _ = dump.send(ShardFinal {
            id: self.id,
            rows: self.rows,
            stats: self.stats,
        });
    }

    /// Process one message; false = shutdown requested.
    pub fn handle(&mut self, msg: ToShard) -> bool {
        match msg {
            ToShard::Get {
                key,
                worker,
                min_vclock,
            } => self.on_get(key, worker, min_vclock),
            ToShard::Update {
                worker,
                clock,
                rows,
            } => self.on_update(worker, clock, rows),
            ToShard::ClockTick { worker, clock } => self.on_tick(worker, clock),
            ToShard::Register { key, worker } => {
                if !self.registered[worker].contains(&key) {
                    self.registered[worker].push(key);
                }
            }
            // ESSP wave acks model ack traffic; nothing to track server-side.
            ToShard::PushAck { .. } => {}
            ToShard::VapAck { worker, seq } => {
                if let Some(vap) = &self.vap {
                    vap.on_wave_ack(worker, seq);
                }
            }
            ToShard::Shutdown => return false,
        }
        true
    }

    fn reply_row(&mut self, key: Key, worker: WorkerId) {
        let vclock = self.table_clock();
        let row = self
            .rows
            .get(&key)
            .unwrap_or_else(|| panic!("GET of uninitialized row {key:?} on shard {}", self.id));
        let msg = ToWorker::Row {
            key,
            data: row.data.clone(),
            vclock,
            fresh: row.fresh.max(vclock),
        };
        self.stats.gets_served += 1;
        self.net
            .send(NodeId::Shard(self.id), NodeId::Worker(worker), Packet::ToWorker(msg));
    }

    fn on_get(&mut self, key: Key, worker: WorkerId, min_vclock: Clock) {
        if self.table_clock() >= min_vclock {
            self.reply_row(key, worker);
        } else {
            // SSP wait condition: hold the reply until enough clocks commit.
            self.stats.gets_queued += 1;
            self.pending.push(PendingGet {
                key,
                worker,
                min_vclock,
            });
        }
    }

    fn on_update(&mut self, source: WorkerId, clock: Clock, rows: Vec<(Key, Vec<f32>)>) {
        let mut touched = Vec::with_capacity(rows.len());
        for (key, delta) in rows {
            self.stats.updates_applied += 1;
            if self.push_enabled {
                self.dirty.insert(key);
            }
            let row = self.rows.entry(key).or_insert_with(|| Row {
                data: vec![0.0; delta.len()],
                fresh: super::types::NEVER,
            });
            debug_assert_eq!(row.data.len(), delta.len(), "row length mismatch {key:?}");
            for (a, d) in row.data.iter_mut().zip(&delta) {
                *a += d;
            }
            row.fresh = row.fresh.max(clock);
            touched.push(key);
        }
        if self.vap.is_some() {
            self.vap_wave(source, clock, &touched);
        }
    }

    /// VAP eager propagation: immediately push the rows this batch touched
    /// to every *other* registered reader, ack-tracked per wave. This —
    /// a per-update round trip to every reader — is the synchronization
    /// cost the paper argues makes VAP impractical; here it is simulated
    /// faithfully so the cost can be measured (vap-compare experiment).
    fn vap_wave(&mut self, source: WorkerId, clock: Clock, touched: &[Key]) {
        let vap = self.vap.as_ref().unwrap().clone();
        let mut awaiting = std::collections::HashSet::new();
        let mut per_worker_rows: Vec<Vec<PushRow>> =
            (0..self.registered.len()).map(|_| Vec::new()).collect();
        for (w, regs) in self.registered.iter().enumerate() {
            if w == source {
                continue; // the writer reads-its-own-writes locally
            }
            for key in touched {
                if regs.contains(key) {
                    if let Some(row) = self.rows.get(key) {
                        per_worker_rows[w].push(PushRow {
                            key: *key,
                            data: row.data.clone(),
                            fresh: row.fresh,
                        });
                    }
                }
            }
            if !per_worker_rows[w].is_empty() {
                awaiting.insert(w);
            }
        }
        let seq = vap.assign_wave((source, clock), awaiting.clone());
        for w in awaiting {
            let rows = std::mem::take(&mut per_worker_rows[w]);
            self.stats.rows_pushed += rows.len() as u64;
            self.net.send(
                NodeId::Shard(self.id),
                NodeId::Worker(w),
                Packet::ToWorker(ToWorker::VapPush {
                    shard: self.id,
                    seq,
                    rows,
                }),
            );
        }
    }

    fn on_tick(&mut self, worker: WorkerId, clock: Clock) {
        if let Some(new_min) = self.clocks.commit(worker, clock) {
            self.serve_pending(new_min);
            if self.push_enabled {
                self.push_wave(new_min);
            }
        }
    }

    fn serve_pending(&mut self, table_clock: Clock) {
        let mut still = Vec::new();
        for p in std::mem::take(&mut self.pending) {
            if table_clock >= p.min_vclock {
                self.reply_row(p.key, p.worker);
            } else {
                still.push(p);
            }
        }
        self.pending = still;
    }

    /// ESSP: push the registered rows *updated since the last wave* to
    /// each registered client, batched per client into one wave message.
    fn push_wave(&mut self, vclock: Clock) {
        for worker in 0..self.registered.len() {
            if self.registered[worker].is_empty() {
                continue;
            }
            let rows: Vec<PushRow> = self.registered[worker]
                .iter()
                .filter(|key| self.dirty.contains(*key))
                .filter_map(|key| {
                    self.rows.get(key).map(|row| PushRow {
                        key: *key,
                        data: row.data.clone(),
                        fresh: row.fresh.max(vclock),
                    })
                })
                .collect();
            // Empty waves still announce the new table clock so clients
            // can advance their copies' guarantees without re-pulling.
            self.stats.rows_pushed += rows.len() as u64;
            self.stats.push_waves += 1;
            self.net.send(
                NodeId::Shard(self.id),
                NodeId::Worker(worker),
                Packet::ToWorker(ToWorker::Push {
                    shard: self.id,
                    vclock,
                    rows,
                }),
            );
        }
        self.dirty.clear();
    }
}

/// Final shard state returned to the harness at shutdown.
pub struct ShardFinal {
    pub id: usize,
    pub rows: HashMap<Key, Row>,
    pub stats: ShardStats,
}

/// Spawn a shard thread. Returns its join handle.
pub fn spawn(shard: Shard, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) -> JoinHandle<()> {
    let id = shard.id;
    std::thread::Builder::new()
        .name(format!("shard-{id}"))
        .spawn(move || {
            crate::sim::priority::infrastructure_thread();
            shard.run(inbox, dump)
        })
        .expect("spawn shard thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::net::{NetConfig, SimNet};
    use std::sync::mpsc::channel;
    use std::time::Duration;

    /// Single-shard fixture with an instant network and one worker inbox.
    fn fixture(workers: usize, push: bool) -> (Shard, std::sync::mpsc::Receiver<ToWorker>, SimNet)
    {
        let (wtx, wrx) = channel();
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![wtx], vec![stx]);
        let shard = Shard::new(0, workers, push, net.handle(), None);
        (shard, wrx, net)
    }

    #[test]
    fn get_after_init_replies_immediately() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 2.0]);
        // min_vclock NEVER-ish: satisfied at table clock -1.
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: -1,
        });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(data, vec![1.0, 2.0]);
                assert_eq!(vclock, -1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn get_blocks_until_clock_advances() {
        let (mut shard, wrx, _net) = fixture(2, false);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: 0,
        });
        assert!(wrx.try_recv().is_err(), "must queue until table clock 0");
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err(), "worker 1 has not committed");
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { vclock, .. } => assert_eq!(vclock, 0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn updates_are_additive_and_bump_fresh() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 1.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![0.5, -1.0])],
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![0.5, 0.0])],
        });
        let row = shard.row(&(0, 1)).unwrap();
        assert_eq!(row.data, vec![2.0, 0.0]);
        assert_eq!(row.fresh, 1);
    }

    #[test]
    fn essp_pushes_updated_registered_rows_on_advance() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        shard.init_row((0, 2), vec![8.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Register { key: (0, 2), worker: 0 });
        // Only row (0,1) is updated: the wave must carry exactly it
        // (delta pushes — unchanged rows are certified by omission).
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0])],
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 0);
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].key, (0, 1));
                assert_eq!(rows[0].data, vec![8.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().push_waves, 1);
        // Next advance with no updates: empty wave still announces vclock.
        shard.handle(ToShard::ClockTick { worker: 0, clock: 1 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 1);
                assert!(rows.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn ssp_mode_never_pushes() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![7.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err());
    }

    #[test]
    fn duplicate_registration_is_idempotent() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        for _ in 0..3 {
            shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        }
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0])],
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => assert_eq!(rows.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_returns_final_state() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![3.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0])],
        });
        assert!(!shard.handle(ToShard::Shutdown));
        assert_eq!(shard.row(&(0, 1)).unwrap().data, vec![4.0]);
    }
}
