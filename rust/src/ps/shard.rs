//! Server shard: owns a partition of the rows, applies coalesced updates,
//! tracks the table clock, answers pulls, and delegates every consistency
//! decision to a [`ServerPolicy`] — the server half of the paper's
//! ESSPTable.
//!
//! A [`Shard`] is a policy-agnostic [`ShardCore`] (rows, clocks, the
//! registration index, staged deterministic replay, pending GETs) driven
//! by the policy pair its [`Consistency`] config selects: ESSP's
//! clock-gated waves, VAP's per-update waves and visibility ledger, and
//! any future model live entirely in `ps::policy` — `handle` only routes
//! messages to core ops and policy hooks.
//!
//! Data-plane layout (zero-copy push):
//!  * Row payloads are shared immutable snapshots (`Arc<[f32]>`). A push
//!    wave addressed to P readers clones the `Arc` P times; the payload
//!    itself is deep-copied exactly zero times. `apply_rows` copies-on-
//!    write, so in-flight wave payloads are immutable.
//!  * Update deltas arrive as hybrid [`RowDelta`]s and are applied in
//!    their own representation: a sparse delta touches only its nnz
//!    indices of the stored row — never densified, here or in the staged
//!    deterministic-replay path (`staged_sums` accumulates previews with
//!    the same hybrid fold the client's coalescing uses).
//!  * Registrations live in an inverted index `Key -> ReaderSet` (bitset
//!    over workers), so wave construction costs O(dirty rows x
//!    interested readers) — the wave size — instead of scanning every
//!    worker's full registration list, and `Register` idempotency is a
//!    single O(1) bit test.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::consistency::Consistency;
use super::msg::{PushRow, ToShard, ToWorker};
use super::policy::ServerPolicy;
use super::types::{Clock, Key, RowDelta, TableId, WorkerId};
use super::vclock::MinClock;
use crate::transport::{NodeId, Packet, TransportHandle};
use crate::util::hash::{FxHashMap, FxHashSet};

/// A stored row: shared immutable payload plus best-effort freshness.
#[derive(Debug, Clone)]
pub struct Row {
    pub data: Arc<[f32]>,
    /// Max update clock reflected in `data` (NEVER if untouched).
    pub fresh: Clock,
}

/// The set of workers registered for eager pushes of one key: a fixed-
/// width bitset over worker ids (P is known at shard construction).
#[derive(Debug, Clone)]
pub struct ReaderSet {
    words: Vec<u64>,
}

impl ReaderSet {
    fn for_workers(workers: usize) -> Self {
        Self {
            words: vec![0; (workers + 63) / 64],
        }
    }

    /// Set worker `w`'s bit; returns true iff it was newly set (O(1)).
    fn insert(&mut self, w: WorkerId) -> bool {
        let (word, bit) = (w / 64, 1u64 << (w % 64));
        let fresh = self.words[word] & bit == 0;
        self.words[word] |= bit;
        fresh
    }

    pub fn contains(&self, w: WorkerId) -> bool {
        self.words[w / 64] & (1u64 << (w % 64)) != 0
    }

    /// Iterate set worker ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.words.iter().enumerate().flat_map(|(i, &w)| {
            let base = i * 64;
            let mut word = w;
            std::iter::from_fn(move || {
                if word == 0 {
                    return None;
                }
                let t = word.trailing_zeros() as usize;
                word &= word - 1;
                Some(base + t)
            })
        })
    }
}

/// Counters reported back to the harness at shutdown.
#[derive(Debug, Default, Clone)]
pub struct ShardStats {
    pub gets_served: u64,
    pub gets_queued: u64,
    pub updates_applied: u64,
    pub rows_pushed: u64,
    pub push_waves: u64,
}

struct PendingGet {
    key: Key,
    worker: WorkerId,
    min_vclock: Clock,
}

/// Policy-agnostic shard state and mechanism. Owned by its thread after
/// `spawn`; constructed (and row-initialized) by the coordinator before
/// launch. Policies receive `&mut ShardCore` in every hook and drive the
/// mechanism through its fields and helpers.
pub struct ShardCore {
    pub(crate) id: usize,
    pub(crate) workers: usize,
    pub(crate) rows: FxHashMap<Key, Row>,
    clocks: MinClock,
    /// Inverted registration index: key -> registered readers (addresses
    /// both ESSP clock waves and VAP per-update waves).
    pub(crate) readers: FxHashMap<Key, ReaderSet>,
    /// Per-worker registered-key count (a worker with >= 1 registration
    /// receives every clock wave, if only to learn the new table clock).
    pub(crate) reg_count: Vec<usize>,
    /// Rows updated since the last push wave: waves carry only these (the
    /// paper's server "pushes out the [updated] table-rows"), which keeps
    /// wave size proportional to update traffic, not to the working set.
    /// Maintained only when the policy pushes on commit.
    dirty: FxHashSet<Key>,
    track_dirty: bool,
    pending: Vec<PendingGet>,
    /// Deterministic application: buffer updates per (clock, worker) and
    /// apply them in that sorted order when the table clock commits, so
    /// float summation order — and hence the final parameters — is
    /// bit-identical no matter how messages interleave on the wire. Off
    /// by default (eager application propagates uncommitted freshness);
    /// multi-process runs enable it so a TCP cluster reproduces the
    /// in-process result exactly.
    deterministic: bool,
    /// Staged (not yet applied) update batches, keyed for sorted replay.
    staged: BTreeMap<(Clock, WorkerId), Vec<(Key, RowDelta)>>,
    net: TransportHandle,
    /// Uniform row length per table, for serving GETs of rows that no
    /// update or init has materialized yet (replied as zeros).
    row_len: HashMap<TableId, usize>,
    /// Cached all-zeros payloads per table (shared, never mutated).
    zero_rows: HashMap<TableId, Arc<[f32]>>,
    pub(crate) stats: ShardStats,
}

/// A shard = the policy-agnostic core plus the consistency policy its
/// config selects.
pub struct Shard {
    core: ShardCore,
    policy: Box<dyn ServerPolicy>,
}

impl Shard {
    pub fn new(
        id: usize,
        workers: usize,
        consistency: Consistency,
        net: TransportHandle,
        row_len: HashMap<TableId, usize>,
        deterministic: bool,
    ) -> Self {
        let policy = consistency.server_policy(workers);
        let track_dirty = policy.pushes_on_commit();
        Self {
            core: ShardCore {
                id,
                workers,
                rows: FxHashMap::default(),
                clocks: MinClock::new(workers),
                readers: FxHashMap::default(),
                reg_count: vec![0; workers],
                dirty: FxHashSet::default(),
                track_dirty,
                pending: Vec::new(),
                deterministic,
                staged: BTreeMap::new(),
                net,
                row_len,
                zero_rows: HashMap::new(),
                stats: ShardStats::default(),
            },
            policy,
        }
    }

    /// Pre-launch initialization of a row (coordinator only).
    pub fn init_row(&mut self, key: Key, data: Vec<f32>) {
        self.core.init_row(key, data);
    }

    pub fn table_clock(&self) -> Clock {
        self.core.table_clock()
    }

    pub fn row(&self, key: &Key) -> Option<&Row> {
        self.core.row(key)
    }

    pub fn stats(&self) -> &ShardStats {
        &self.core.stats
    }

    /// Drive the shard from its inbox until Shutdown. Returns final stats
    /// and the row store (for end-of-run evaluation by the harness).
    pub fn run(mut self, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) {
        while let Ok(msg) = inbox.recv() {
            if !self.handle(msg) {
                break;
            }
        }
        let _ = dump.send(ShardFinal {
            id: self.core.id,
            rows: self.core.rows,
            stats: self.core.stats,
        });
    }

    /// Process one message; false = shutdown requested. Pure routing:
    /// core mechanism first, then the matching policy hook — no model-
    /// specific branching.
    pub fn handle(&mut self, msg: ToShard) -> bool {
        match msg {
            ToShard::Get {
                key,
                worker,
                min_vclock,
            } => self.core.on_get(key, worker, min_vclock),
            ToShard::Update {
                worker,
                clock,
                rows,
            } => {
                let touched = self.core.on_update(worker, clock, rows);
                self.policy.on_update(&mut self.core, worker, clock, &touched);
            }
            ToShard::ClockTick { worker, clock } => {
                if let Some(new_min) = self.core.on_tick(worker, clock) {
                    self.policy.on_commit(&mut self.core, new_min);
                }
            }
            ToShard::Register { key, worker } => {
                self.core.on_register(key, worker);
                self.policy.on_register(&mut self.core, worker);
            }
            ToShard::PushAck { worker, vclock } => {
                self.policy.on_push_ack(&mut self.core, worker, vclock)
            }
            ToShard::VapAck { worker, seq } => {
                self.policy.on_wave_ack(&mut self.core, worker, seq)
            }
            ToShard::NormReport {
                worker,
                clock,
                inf_norm,
            } => self
                .policy
                .on_norm_report(&mut self.core, worker, clock, inf_norm),
            ToShard::Detach { worker } => self.policy.on_detach(&mut self.core, worker),
            ToShard::Shutdown => return false,
        }
        true
    }

    #[cfg(test)]
    fn core(&self) -> &ShardCore {
        &self.core
    }
}

impl ShardCore {
    pub fn init_row(&mut self, key: Key, data: Vec<f32>) {
        self.rows.insert(
            key,
            Row {
                data: data.into(),
                fresh: super::types::NEVER,
            },
        );
    }

    pub fn table_clock(&self) -> Clock {
        self.clocks.min()
    }

    pub fn row(&self, key: &Key) -> Option<&Row> {
        self.rows.get(key)
    }

    /// Send one message to a worker through the data plane.
    pub(crate) fn send_to_worker(&self, worker: WorkerId, msg: ToWorker) {
        self.net.send(
            NodeId::Shard(self.id),
            NodeId::Worker(worker),
            Packet::ToWorker(msg),
        );
    }

    /// All-zeros payload for `table`, shared across replies.
    fn zero_row(&mut self, table: TableId) -> Arc<[f32]> {
        if let Some(z) = self.zero_rows.get(&table) {
            return Arc::clone(z);
        }
        let len = *self.row_len.get(&table).unwrap_or_else(|| {
            panic!(
                "GET of uninitialized row in table {table} with unknown row \
                 length on shard {}",
                self.id
            )
        });
        let z: Arc<[f32]> = vec![0.0f32; len].into();
        self.zero_rows.insert(table, Arc::clone(&z));
        z
    }

    fn reply_row(&mut self, key: Key, worker: WorkerId) {
        let vclock = self.table_clock();
        // A GET may legitimately race ahead of row materialization (e.g.
        // the row will first exist when some worker's update creates it):
        // serve zeros of the table's row length rather than panicking.
        let (data, fresh) = match self.rows.get(&key) {
            Some(row) => (Arc::clone(&row.data), row.fresh),
            None => (self.zero_row(key.0), super::types::NEVER),
        };
        self.stats.gets_served += 1;
        self.send_to_worker(
            worker,
            ToWorker::Row {
                key,
                data,
                vclock,
                fresh: fresh.max(vclock),
            },
        );
    }

    fn on_get(&mut self, key: Key, worker: WorkerId, min_vclock: Clock) {
        if self.table_clock() >= min_vclock {
            self.reply_row(key, worker);
        } else {
            // SSP wait condition: hold the reply until enough clocks commit.
            self.stats.gets_queued += 1;
            self.pending.push(PendingGet {
                key,
                worker,
                min_vclock,
            });
        }
    }

    fn on_register(&mut self, key: Key, worker: WorkerId) {
        let workers = self.workers;
        let set = self
            .readers
            .entry(key)
            .or_insert_with(|| ReaderSet::for_workers(workers));
        if set.insert(worker) {
            self.reg_count[worker] += 1;
        }
    }

    /// Process one inbound Update batch: apply it (eager path) or stage
    /// it for deterministic replay. Returns the touched keys (for the
    /// policy's `on_update` hook).
    fn on_update(
        &mut self,
        source: WorkerId,
        clock: Clock,
        rows: Vec<(Key, RowDelta)>,
    ) -> Vec<Key> {
        if self.deterministic {
            // Defer until the table clock commits `clock`; replay is then
            // sorted by (clock, worker), independent of arrival order.
            let keys: Vec<Key> = rows.iter().map(|(k, _)| *k).collect();
            self.staged.entry((clock, source)).or_default().extend(rows);
            return keys;
        }
        self.apply_rows(clock, rows)
    }

    /// Apply one update batch to the row store (copy-on-write per row).
    /// Each delta is folded in its own representation: a sparse delta
    /// touches only its nnz indices — no densification on the apply path.
    fn apply_rows(&mut self, clock: Clock, rows: Vec<(Key, RowDelta)>) -> Vec<Key> {
        let mut touched = Vec::with_capacity(rows.len());
        for (key, delta) in rows {
            self.stats.updates_applied += 1;
            if self.track_dirty {
                self.dirty.insert(key);
            }
            // Materializing a row from its first update zero-fills the
            // delta's claimed width — and a decoded frame may lie about
            // it (a sparse row's `len` is a claim, not bytes actually on
            // the wire). Validate against the table registry when one
            // exists, so a corrupt frame cannot demand huge zero-fills;
            // tables without a registered uniform width (variable-length
            // LM tensors, bare test fixtures) keep the delta's word.
            let row_len = &self.row_len;
            let row = self.rows.entry(key).or_insert_with(|| {
                if let Some(&registered) = row_len.get(&key.0) {
                    assert_eq!(
                        registered,
                        delta.len(),
                        "update materializing {:?} claims width {} but table {} registers {}",
                        key,
                        delta.len(),
                        key.0,
                        registered
                    );
                }
                Row {
                    data: vec![0.0; delta.len()].into(),
                    fresh: super::types::NEVER,
                }
            });
            debug_assert_eq!(row.data.len(), delta.len(), "row length mismatch {key:?}");
            // Copy-on-write: mutate in place while we hold the only
            // reference; otherwise detach from the (in-flight) snapshot.
            if Arc::get_mut(&mut row.data).is_none() {
                let detached: Arc<[f32]> = row.data.iter().copied().collect();
                row.data = detached;
            }
            let data = Arc::get_mut(&mut row.data).expect("unique after copy-on-write");
            delta.add_into(data);
            row.fresh = row.fresh.max(clock);
            touched.push(key);
        }
        touched
    }

    /// Summed staged-but-unapplied deltas per key, restricted to `keys`
    /// (deterministic mode defers application to the table-clock commit).
    /// Policies that propagate update *values* eagerly overlay these sums
    /// so their waves carry everything the store will apply — including
    /// concurrent workers' staged parts, exactly like the eager path's
    /// accumulated store contents. Empty (and O(1)) outside deterministic
    /// mode. Summation follows the staged map's sorted (clock, worker)
    /// order, so previews are deterministic too; sparse parts accumulate
    /// with the same hybrid fold the client's coalescing uses, so a
    /// below-threshold sum stays sparse.
    pub(crate) fn staged_sums(&self, keys: &[Key]) -> FxHashMap<Key, RowDelta> {
        let mut out: FxHashMap<Key, RowDelta> = FxHashMap::default();
        if self.staged.is_empty() {
            return out;
        }
        let want: FxHashSet<Key> = keys.iter().copied().collect();
        for rows in self.staged.values() {
            for (k, d) in rows {
                if !want.contains(k) {
                    continue;
                }
                out.entry(*k)
                    .and_modify(|acc| acc.add_assign(d))
                    .or_insert_with(|| d.clone());
            }
        }
        out
    }

    /// Commit `worker`'s `clock`; on a table-clock advance, replay staged
    /// updates in sorted order and serve unblocked GETs, then report the
    /// new minimum (the caller runs the policy's commit hook after).
    fn on_tick(&mut self, worker: WorkerId, clock: Clock) -> Option<Clock> {
        let new_min = self.clocks.commit(worker, clock)?;
        // Deterministic mode: every update with clock <= new_min has
        // arrived (Update precedes ClockTick on each FIFO link), so
        // replay them in sorted (clock, worker) order before serving
        // reads or firing the wave for this advance.
        while let Some((&(c, w), _)) = self.staged.first_key_value() {
            if c > new_min {
                break;
            }
            let rows = self.staged.remove(&(c, w)).unwrap();
            self.apply_rows(c, rows);
        }
        self.serve_pending(new_min);
        Some(new_min)
    }

    fn serve_pending(&mut self, table_clock: Clock) {
        let mut still = Vec::new();
        for p in std::mem::take(&mut self.pending) {
            if table_clock >= p.min_vclock {
                self.reply_row(p.key, p.worker);
            } else {
                still.push(p);
            }
        }
        self.pending = still;
    }

    /// Clock-gated delta wave (ESSP; called from the policy's commit
    /// hook): push the registered rows *updated since the last wave* to
    /// each registered client, batched per client into one wave message.
    /// Cost is O(dirty rows x interested readers) — the total wave size —
    /// thanks to the inverted index; payloads are `Arc`-shared, so a wave
    /// to P readers performs zero payload deep-copies.
    pub fn push_wave(&mut self, vclock: Clock) {
        let mut per_worker: Vec<Vec<PushRow>> = Vec::new();
        per_worker.resize_with(self.workers, Vec::new);
        for key in self.dirty.drain() {
            let Some(readers) = self.readers.get(&key) else {
                continue;
            };
            let Some(row) = self.rows.get(&key) else {
                continue;
            };
            let fresh = row.fresh.max(vclock);
            for w in readers.iter() {
                per_worker[w].push(PushRow {
                    key,
                    data: Arc::clone(&row.data),
                    fresh,
                });
            }
        }
        for (worker, rows) in per_worker.into_iter().enumerate() {
            if self.reg_count[worker] == 0 {
                continue;
            }
            // Empty waves still announce the new table clock so clients
            // can advance their copies' guarantees without re-pulling.
            self.stats.rows_pushed += rows.len() as u64;
            self.stats.push_waves += 1;
            self.send_to_worker(
                worker,
                ToWorker::Push {
                    shard: self.id,
                    vclock,
                    rows,
                },
            );
        }
    }
}

/// Final shard state returned to the harness at shutdown.
pub struct ShardFinal {
    pub id: usize,
    pub rows: FxHashMap<Key, Row>,
    pub stats: ShardStats,
}

/// Spawn a shard thread. Returns its join handle.
pub fn spawn(shard: Shard, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) -> JoinHandle<()> {
    let id = shard.core.id;
    std::thread::Builder::new()
        .name(format!("shard-{id}"))
        .spawn(move || {
            crate::sim::priority::infrastructure_thread();
            shard.run(inbox, dump)
        })
        .expect("spawn shard thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::net::{NetConfig, SimNet};
    use std::sync::mpsc::channel;
    use std::time::Duration;

    /// Fixture with an instant network and one inbox per worker.
    fn fixture_n(
        workers: usize,
        consistency: Consistency,
        row_len: HashMap<TableId, usize>,
    ) -> (Shard, Vec<std::sync::mpsc::Receiver<ToWorker>>, SimNet) {
        let mut wtxs = Vec::new();
        let mut wrxs = Vec::new();
        for _ in 0..workers {
            let (wtx, wrx) = channel();
            wtxs.push(wtx);
            wrxs.push(wrx);
        }
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), wtxs, vec![stx]);
        let shard = Shard::new(
            0,
            workers,
            consistency,
            TransportHandle::new(net.handle()),
            row_len,
            false,
        );
        (shard, wrxs, net)
    }

    /// Single-worker fixture (the common case in these tests). `push`
    /// selects the clock-wave policy (ESSP) vs pull-only (SSP).
    fn fixture(workers: usize, push: bool) -> (Shard, std::sync::mpsc::Receiver<ToWorker>, SimNet)
    {
        let consistency = if push {
            Consistency::Essp { s: 1 }
        } else {
            Consistency::Ssp { s: 1 }
        };
        let (shard, mut wrxs, net) = fixture_n(workers, consistency, HashMap::new());
        (shard, wrxs.remove(0), net)
    }

    #[test]
    fn get_after_init_replies_immediately() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 2.0]);
        // min_vclock NEVER-ish: satisfied at table clock -1.
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: -1,
        });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(&data[..], &[1.0, 2.0]);
                assert_eq!(vclock, -1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn get_of_unmaterialized_row_serves_zeros() {
        // A GET can race ahead of any update/init materializing the row
        // (regression: this used to panic the shard thread). The reply
        // must be zeros of the table's registered row length, fresh NEVER.
        let mut row_len = HashMap::new();
        row_len.insert(0u32, 3usize);
        let (mut shard, wrxs, _net) = fixture_n(1, Consistency::Ssp { s: 1 }, row_len);
        shard.handle(ToShard::Get {
            key: (0, 99),
            worker: 0,
            min_vclock: -1,
        });
        match wrxs[0].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, fresh, .. } => {
                assert_eq!(&data[..], &[0.0, 0.0, 0.0]);
                assert_eq!(fresh, -1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The shard must not have materialized the row server-side.
        assert!(shard.row(&(0, 99)).is_none());
        // A later update to that row starts from zeros, consistently.
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 99), vec![1.0, 2.0, 3.0].into())],
        });
        assert_eq!(&shard.row(&(0, 99)).unwrap().data[..], &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "unknown row length")]
    fn get_of_unknown_table_still_panics() {
        // No row and no row-length registry entry: nothing sane to serve.
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.handle(ToShard::Get {
            key: (7, 0),
            worker: 0,
            min_vclock: -1,
        });
    }

    #[test]
    fn get_blocks_until_clock_advances() {
        let (mut shard, wrx, _net) = fixture(2, false);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: 0,
        });
        assert!(wrx.try_recv().is_err(), "must queue until table clock 0");
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err(), "worker 1 has not committed");
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { vclock, .. } => assert_eq!(vclock, 0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn updates_are_additive_and_bump_fresh() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 1.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![0.5, -1.0].into())],
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![0.5, 0.0].into())],
        });
        let row = shard.row(&(0, 1)).unwrap();
        assert_eq!(&row.data[..], &[2.0, 0.0]);
        assert_eq!(row.fresh, 1);
    }

    #[test]
    fn sparse_updates_apply_without_densifying() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 2.0, 3.0, 4.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), RowDelta::sparse(4, vec![(1, 0.5), (3, -4.0)]))],
        });
        let row = shard.row(&(0, 1)).unwrap();
        assert_eq!(&row.data[..], &[1.0, 2.5, 3.0, 0.0]);
        assert_eq!(row.fresh, 0);
        // A sparse update may also materialize a missing row (from zeros).
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 9), RowDelta::sparse(3, vec![(2, 7.0)]))],
        });
        assert_eq!(&shard.row(&(0, 9)).unwrap().data[..], &[0.0, 0.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "claims width")]
    fn materializing_update_with_lying_width_is_rejected() {
        // A decoded update may claim any row width (a sparse row's `len`
        // is a claim, not bytes on the wire): materializing a missing row
        // must validate the claim against the table registry rather than
        // zero-fill whatever the frame asked for.
        let mut row_len = HashMap::new();
        row_len.insert(0u32, 3usize);
        let (mut shard, _wrxs, _net) = fixture_n(1, Consistency::Ssp { s: 1 }, row_len);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 42), RowDelta::sparse(1 << 20, vec![]))],
        });
    }

    #[test]
    fn staged_sparse_sums_stay_sparse_below_threshold() {
        // Deterministic mode: two workers stage sparse parts for the same
        // wide row; the preview sum must accumulate as pairs (no
        // densification below the threshold) and the commit must apply
        // the same values.
        let (mut shard, _wrx, _net) = det_shard(2, true);
        shard.init_row((0, 0), vec![0.0; 1024]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), RowDelta::sparse(1024, vec![(3, 1.0), (900, 2.0)]))],
        });
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 0,
            rows: vec![((0, 0), RowDelta::sparse(1024, vec![(3, 0.5), (17, -1.0)]))],
        });
        let sums = shard.core().staged_sums(&[(0, 0)]);
        let sum = &sums[&(0, 0)];
        assert!(sum.is_sparse(), "below-threshold staged sum densified");
        assert_eq!(sum.nnz(), 3);
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        let row = &shard.row(&(0, 0)).unwrap().data;
        assert_eq!((row[3], row[17], row[900]), (1.5, -1.0, 2.0));
        assert_eq!(row.iter().filter(|x| **x != 0.0).count(), 3);
    }

    #[test]
    fn essp_pushes_updated_registered_rows_on_advance() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        shard.init_row((0, 2), vec![8.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Register { key: (0, 2), worker: 0 });
        // Only row (0,1) is updated: the wave must carry exactly it
        // (delta pushes — unchanged rows are certified by omission).
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 0);
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].key, (0, 1));
                assert_eq!(&rows[0].data[..], &[8.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().push_waves, 1);
        // Next advance with no updates: empty wave still announces vclock.
        shard.handle(ToShard::ClockTick { worker: 0, clock: 1 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 1);
                assert!(rows.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn push_wave_payloads_are_shared_not_copied() {
        // A wave addressed to P readers must carry the *same* allocation
        // the shard stores — Arc clones, zero payload deep-copies.
        let p = 3;
        let (mut shard, wrxs, _net) =
            fixture_n(p, Consistency::Essp { s: 1 }, HashMap::new());
        shard.init_row((0, 1), vec![0.0, 0.0]);
        for w in 0..p {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0, 2.0].into())],
        });
        for w in 0..p {
            shard.handle(ToShard::ClockTick { worker: w, clock: 0 });
        }
        let stored = Arc::clone(&shard.row(&(0, 1)).unwrap().data);
        let mut received = Vec::new();
        for wrx in &wrxs {
            match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
                ToWorker::Push { rows, .. } => {
                    assert_eq!(rows.len(), 1);
                    received.push(Arc::clone(&rows[0].data));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        for arc in &received {
            assert!(
                Arc::ptr_eq(arc, &stored),
                "push wave deep-copied the payload"
            );
        }
        // Refcount: shard's copy + our `stored` + P in-wave clones.
        assert_eq!(Arc::strong_count(&stored), 2 + p);
    }

    #[test]
    fn update_after_push_copies_on_write() {
        // While a pushed snapshot is still referenced (in flight / cached
        // by a reader), applying an update must detach, not mutate it.
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        let pushed = match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { mut rows, .. } => rows.remove(0).data,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(&pushed[..], &[1.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![1.0].into())],
        });
        // The held snapshot is unchanged; the stored row advanced.
        assert_eq!(&pushed[..], &[1.0]);
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[2.0]);
        assert!(!Arc::ptr_eq(&pushed, &shard.row(&(0, 1)).unwrap().data));
    }

    #[test]
    fn ssp_mode_never_pushes() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![7.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err());
    }

    #[test]
    fn duplicate_registration_is_idempotent() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        for _ in 0..3 {
            shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        }
        assert_eq!(
            shard.core().reg_count[0],
            1,
            "re-registration must not recount"
        );
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => assert_eq!(rows.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn reader_set_bitset_semantics() {
        let mut s = ReaderSet::for_workers(130);
        assert!(s.insert(0));
        assert!(s.insert(64));
        assert!(s.insert(129));
        assert!(!s.insert(64), "second insert reports already-present");
        assert!(s.contains(129) && !s.contains(1));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 64, 129]);
    }

    fn det_shard(
        workers: usize,
        deterministic: bool,
    ) -> (Shard, std::sync::mpsc::Receiver<ToWorker>, SimNet) {
        let (wtx, wrx) = channel();
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![wtx], vec![stx]);
        let shard = Shard::new(
            0,
            workers,
            Consistency::Ssp { s: 1 },
            TransportHandle::new(net.handle()),
            HashMap::new(),
            deterministic,
        );
        (shard, wrx, net)
    }

    #[test]
    fn deterministic_mode_applies_updates_in_worker_order() {
        // f32 addition is not associative: starting from 1e8, applying
        // +1.0 then -1e8 gives 0.0 (the +1 is absorbed), while -1e8 then
        // +1.0 gives 1.0. Deterministic mode must replay sorted by
        // (clock, worker) — yielding 0.0 — even when worker 1's update
        // arrives first.
        let mk = |deterministic: bool| {
            let (mut shard, _wrx, net) = det_shard(2, deterministic);
            shard.init_row((0, 0), vec![1e8]);
            shard.handle(ToShard::Update {
                worker: 1,
                clock: 0,
                rows: vec![((0, 0), vec![-1e8].into())],
            });
            shard.handle(ToShard::Update {
                worker: 0,
                clock: 0,
                rows: vec![((0, 0), vec![1.0].into())],
            });
            shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
            shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
            let v = shard.row(&(0, 0)).unwrap().data[0];
            drop(shard);
            net.shutdown();
            v
        };
        assert_eq!(mk(true), 0.0, "sorted replay: worker 0's +1 absorbed");
        assert_eq!(mk(false), 1.0, "eager application keeps arrival order");
    }

    #[test]
    fn deterministic_mode_defers_until_commit() {
        let (mut shard, wrx, _net) = det_shard(2, true);
        shard.init_row((0, 0), vec![0.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), vec![5.0].into())],
        });
        // Not applied yet: worker 1 has not committed clock 0.
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 0.0);
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 0.0);
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 5.0);
        // A GET served after the commit sees the applied value.
        shard.handle(ToShard::Get {
            key: (0, 0),
            worker: 0,
            min_vclock: 0,
        });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(&data[..], &[5.0]);
                assert_eq!(vclock, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_returns_final_state() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![3.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
        });
        assert!(!shard.handle(ToShard::Shutdown));
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[4.0]);
    }
}
