//! Server shard: owns a partition of the rows, applies coalesced updates,
//! tracks the table clock, answers pulls, and delegates every consistency
//! decision to a [`ServerPolicy`] — the server half of the paper's
//! ESSPTable.
//!
//! A [`Shard`] is a policy-agnostic [`ShardCore`] (rows, clocks, the
//! registration index, staged deterministic replay, pending GETs) driven
//! by the policy pair its [`Consistency`] config selects: ESSP's
//! clock-gated waves, VAP's per-update waves and visibility ledger, and
//! any future model live entirely in `ps::policy` — `handle` only routes
//! messages to core ops and policy hooks.
//!
//! Data-plane layout (zero-copy push):
//!  * Row payloads are shared immutable snapshots (`Arc<[f32]>`). A push
//!    wave addressed to P readers clones the `Arc` P times; the payload
//!    itself is deep-copied exactly zero times. `apply_rows` copies-on-
//!    write, so in-flight wave payloads are immutable.
//!  * Update deltas arrive as hybrid [`RowDelta`]s and are applied in
//!    their own representation: a sparse delta touches only its nnz
//!    indices of the stored row — never densified, here or in the staged
//!    deterministic-replay path (`staged_sums` accumulates previews with
//!    the same hybrid fold the client's coalescing uses).
//!  * Registrations live in an inverted index `Key -> ReaderSet` (bitset
//!    over workers), so wave construction costs O(dirty rows x
//!    interested readers) — the wave size — instead of scanning every
//!    worker's full registration list, and `Register` idempotency is a
//!    single O(1) bit test.
//!  * Staged deterministic-replay batches carry a per-key generation
//!    index, so VAP/AVAP wave previews (`staged_sums`) cost O(keys
//!    touched x straggle depth) instead of rescanning the backlog.
//!
//! The shard is also a node of the elastic shard plane (`ps::placement`):
//! it can be a live-migration *source* (replay to the fence, hand rows +
//! staged tails to new owners, then relay late traffic via a forward
//! table) and/or *destination* (fence replay and reads for in-flight keys
//! until their `RowHandoff` lands), and [`Shard::replica`] builds the
//! same core behind a pull-only policy for replica read fan-out.
//!
//! Crash tolerance (`ps::durability`, and see `ps::server`'s *Durability
//! & Failover* docs): with [`Shard::enable_durability`] every state-
//! bearing inbound message is appended to a per-shard write-ahead log
//! *before* it is processed, fsync'd per the configured policy, and
//! periodically compacted into a checkpoint + log-tail generation pair.
//! [`Shard::crash_and_recover`] (also fired by a fault plan's `crash`
//! action) rebuilds the durable state from disk through the same handler
//! code paths — bit-identical under deterministic replay. A `kill` fault
//! makes the shard die permanently and *silently*: failover is
//! detection-driven — the coordinator's failure detector (`ps::failover`)
//! observes the death via missed `StatsPull` heartbeats and transport
//! `PeerEvent`s and emits the [`ToShard::Promote`] itself; the replica
//! adopts the dead primary's logical identity and the run's full server
//! policy (handled like any other inbound message). After promoting, the
//! coordinator restores the replication factor by re-replicating onto a
//! spare node: [`ToShard::ReplicaSync`] makes the serving node copy its
//! row fold through a fence clock to the spare, whose
//! [`ToShard::ReplicaCatchUp`] gate holds all replay until the stream's
//! end-marker lands (or, double-failure fallback, rebuilds the dead
//! primary's state from its on-disk WAL generation).

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use super::consistency::Consistency;
use super::durability::{self, checkpoint, wal, DurabilityConfig};
use super::msg::{PushRow, ToShard, ToWorker};
use super::placement::PlacementDelta;
use super::policy::ServerPolicy;
use super::types::{Clock, Key, RowDelta, TableId, WorkerId, NEVER};
use super::vclock::MinClock;
use crate::sim::fault::{ShardAction, ShardFault};
use crate::telemetry::profile::HotKeySketch;
use crate::telemetry::registry::{Counter, Gauge, LogHist, MetricsSource, Snapshot};
use crate::telemetry::spans::{Mark, SpanCtx, SpanRing, SpanSampler};
use crate::telemetry::trace::TraceRing;
use crate::transport::{NodeId, Packet, Transport, TransportHandle};
use crate::util::hash::{FxHashMap, FxHashSet};

/// A stored row: shared immutable payload plus best-effort freshness.
#[derive(Debug, Clone)]
pub struct Row {
    pub data: Arc<[f32]>,
    /// Max update clock reflected in `data` (NEVER if untouched).
    pub fresh: Clock,
}

/// The set of workers registered for eager pushes of one key: a fixed-
/// width bitset over worker ids (P is known at shard construction).
#[derive(Debug, Clone)]
pub struct ReaderSet {
    words: Vec<u64>,
}

impl ReaderSet {
    fn for_workers(workers: usize) -> Self {
        Self {
            words: vec![0; (workers + 63) / 64],
        }
    }

    /// Set worker `w`'s bit; returns true iff it was newly set (O(1)).
    fn insert(&mut self, w: WorkerId) -> bool {
        let (word, bit) = (w / 64, 1u64 << (w % 64));
        let fresh = self.words[word] & bit == 0;
        self.words[word] |= bit;
        fresh
    }

    pub fn contains(&self, w: WorkerId) -> bool {
        self.words[w / 64] & (1u64 << (w % 64)) != 0
    }

    /// Iterate set worker ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = WorkerId> + '_ {
        self.words.iter().enumerate().flat_map(|(i, &w)| {
            let base = i * 64;
            let mut word = w;
            std::iter::from_fn(move || {
                if word == 0 {
                    return None;
                }
                let t = word.trailing_zeros() as usize;
                word &= word - 1;
                Some(base + t)
            })
        })
    }
}

/// Live telemetry registry of one shard node (see `ps::server`
/// § Observability). Fixed-layout relaxed atomics shared (`Arc`) with the
/// admin scrape thread; the counters mirror [`ShardStats`] — the plain
/// end-of-run dump — while also being safely readable mid-run from any
/// thread, and add the latency histograms and queue gauges only the live
/// plane needs. Updates are single relaxed RMWs on the message-handling
/// path; never locks, never allocation.
#[derive(Debug)]
pub struct ShardMetrics {
    /// Node label for snapshots, e.g. `"shard0"` (physical node id).
    pub node: String,
    pub gets_served: Counter,
    pub gets_queued: Counter,
    pub updates_applied: Counter,
    /// Update rows buffered for deterministic replay (before they apply).
    pub updates_staged: Counter,
    /// Table-clock advances (commit boundaries).
    pub commits: Counter,
    pub rows_pushed: Counter,
    /// Subset of `rows_pushed` that shipped as delta chains (wire v7)
    /// rather than full snapshots.
    pub rows_pushed_delta: Counter,
    pub push_waves: Counter,
    pub gets_forwarded: Counter,
    pub updates_forwarded: Counter,
    pub rows_migrated_out: Counter,
    pub rows_migrated_in: Counter,
    /// Promotions this node performed (replica takeover).
    pub promotions: Counter,
    /// Telemetry snapshots served over the wire (StatsPull).
    pub stats_pulls: Counter,
    /// Staged batches + queued GETs after each handled message; the
    /// high-water mark is the per-shard backlog figure `RunReport` cites.
    pub queue_depth: Gauge,
    /// WAL append / fsync wall latency in ns (durable shards only).
    pub wal_append_ns: LogHist,
    pub wal_fsync_ns: LogHist,
    /// Rows per push wave (fan-out shape of the eager plane).
    pub wave_fanout: LogHist,
    /// Sampled hot-key profiler: space-saving top-K sketches over GET
    /// and update-row traffic (`--hot-keys K`; k = 0 disables). Mutex-
    /// guarded rather than atomic, but taken only by the shard thread
    /// and the rare scrape — see `telemetry::profile`.
    pub hot_gets: HotKeySketch,
    pub hot_updates: HotKeySketch,
}

impl ShardMetrics {
    pub fn new(id: usize) -> Self {
        Self::with_hot_keys(id, 0)
    }

    /// Registry with the hot-key profiler tracking `k` heavy hitters per
    /// sketch (`ClusterConfig::hot_key_k`; 0 disables).
    pub fn with_hot_keys(id: usize, k: usize) -> Self {
        Self {
            node: format!("shard{id}"),
            gets_served: Counter::new(),
            gets_queued: Counter::new(),
            updates_applied: Counter::new(),
            updates_staged: Counter::new(),
            commits: Counter::new(),
            rows_pushed: Counter::new(),
            rows_pushed_delta: Counter::new(),
            push_waves: Counter::new(),
            gets_forwarded: Counter::new(),
            updates_forwarded: Counter::new(),
            rows_migrated_out: Counter::new(),
            rows_migrated_in: Counter::new(),
            promotions: Counter::new(),
            stats_pulls: Counter::new(),
            queue_depth: Gauge::new(),
            wal_append_ns: LogHist::new(),
            wal_fsync_ns: LogHist::new(),
            wave_fanout: LogHist::new(),
            hot_gets: HotKeySketch::new(k),
            hot_updates: HotKeySketch::new(k),
        }
    }

    /// Flatten to snapshot entries — the `StatsReport` payload and the
    /// admin socket's render source.
    pub fn entries(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = vec![
            ("gets_served".into(), self.gets_served.get()),
            ("gets_queued".into(), self.gets_queued.get()),
            ("updates_applied".into(), self.updates_applied.get()),
            ("updates_staged".into(), self.updates_staged.get()),
            ("commits".into(), self.commits.get()),
            ("rows_pushed".into(), self.rows_pushed.get()),
            ("rows_pushed_delta".into(), self.rows_pushed_delta.get()),
            ("push_waves".into(), self.push_waves.get()),
            ("gets_forwarded".into(), self.gets_forwarded.get()),
            ("updates_forwarded".into(), self.updates_forwarded.get()),
            ("rows_migrated_out".into(), self.rows_migrated_out.get()),
            ("rows_migrated_in".into(), self.rows_migrated_in.get()),
            ("promotions".into(), self.promotions.get()),
            ("stats_pulls".into(), self.stats_pulls.get()),
            ("queue_depth".into(), self.queue_depth.get()),
            ("queue_hwm".into(), self.queue_depth.hwm()),
        ];
        self.wal_append_ns.snapshot().entries("wal_append_ns", &mut out);
        self.wal_fsync_ns.snapshot().entries("wal_fsync_ns", &mut out);
        self.wave_fanout.snapshot().entries("wave_fanout", &mut out);
        // Hot-key profiler entries ride the same flattened convention
        // (`hot.g.<table>:<row>` / `hot.u.<table>:<row>`), so they reach
        // StatsReport, both admin endpoints, and ps-top for free.
        self.hot_gets.entries("hot.g.", &mut out);
        self.hot_updates.entries("hot.u.", &mut out);
        out
    }
}

impl MetricsSource for ShardMetrics {
    fn snapshots(&self) -> Vec<Snapshot> {
        vec![Snapshot {
            node: self.node.clone(),
            entries: self.entries(),
        }]
    }
}

/// Counters reported back to the harness at shutdown.
#[derive(Debug, Default, Clone)]
pub struct ShardStats {
    pub gets_served: u64,
    pub gets_queued: u64,
    pub updates_applied: u64,
    pub rows_pushed: u64,
    /// Subset of `rows_pushed` shipped as delta chains instead of snapshots.
    pub rows_pushed_delta: u64,
    pub push_waves: u64,
    /// Elastic shard plane: rows this shard handed off / received in a
    /// live migration, and late traffic relayed via the forward table.
    pub rows_migrated_out: u64,
    pub rows_migrated_in: u64,
    pub gets_forwarded: u64,
    pub updates_forwarded: u64,
}

struct PendingGet {
    key: Key,
    worker: WorkerId,
    min_vclock: Clock,
    /// Sampled span riding the GET (wire v9), echoed on the reply; the
    /// queue wait becomes its `policy_admission` segment.
    span: Option<SpanCtx>,
    /// When the GET queued (`SpanRing::now_us`), 0 when unsampled.
    queued_us: u64,
}

/// State of this shard's role in the (at most one) live migration —
/// see `ps::placement` for the protocol state machine.
struct Migration {
    epoch: u64,
    /// First clock owned by the new placement: this shard hands off once
    /// its table clock commits `at_clock - 1`, and (as a destination)
    /// fences replay/reads at `at_clock - 1` until every expected
    /// handoff arrived.
    at_clock: Clock,
    /// Keys leaving this shard -> destination. After the handoff this
    /// doubles as the forward table for late traffic.
    outgoing: FxHashMap<Key, usize>,
    /// Keys expected via RowHandoff before clock `at_clock` may commit.
    awaiting: FxHashSet<Key>,
    handed_off: bool,
    /// A table-clock advance withheld while handoffs were outstanding;
    /// released (replay + pending GETs + policy commit hook) by the last
    /// RowHandoff.
    held_min: Option<Clock>,
}

/// Destination-side state of a re-replication catch-up
/// ([`ToShard::ReplicaCatchUp`]): the *whole shard* is gated — staged
/// updates never replay, commits never fire, reads stay queued — until
/// the `MigrateCommit` ending the source's `ReplicaSync` row stream
/// lands. Client traffic duplicated from the attach fence onward stages
/// normally meanwhile, so once the gate clears the ordinary sorted replay
/// composes it onto the synced base rows exactly.
struct CatchUp {
    epoch: u64,
    /// First clock the duplicated client stream owns; the synced rows are
    /// the source's fold through `at_clock - 1`.
    at_clock: Clock,
    /// A table-clock advance withheld while the gate is closed; released
    /// by the stream's end-marker.
    held_min: Option<Clock>,
}

/// One-shot ingress dedup installed after a spare rebuilds a dead
/// primary's state from disk (`ReplicaCatchUp { from_disk: true }`):
/// per-worker clock floors at or below which replayed client traffic
/// (the bounded resend window, see `ClientConfig::resend_window`) is
/// already reflected in the recovered state and must be dropped rather
/// than double-applied. Exact for the clock models (one Update per
/// (worker, clock) pair); VAP/AVAP may flush several Updates within one
/// clock and are documented as excluded from WAL-fallback exactness.
struct ReplayFloors {
    /// Highest update clock per worker in the recovered state (committed,
    /// or present as a staged batch).
    update: Vec<Clock>,
    /// Committed clock per worker.
    tick: Vec<Clock>,
}

impl ReplayFloors {
    fn of(core: &ShardCore) -> Self {
        let mut tick = Vec::with_capacity(core.workers);
        let mut update = Vec::with_capacity(core.workers);
        for w in 0..core.workers {
            tick.push(core.clocks.committed(w));
        }
        update.extend_from_slice(&tick);
        for &(clock, worker) in core.staged.keys() {
            update[worker] = update[worker].max(clock);
        }
        Self { update, tick }
    }
}

/// The ordered delta sequence a key's row absorbed since the last wave
/// that consumed it — the raw material of a wire-v7 delta push. Order is
/// exactly application order (f32 addition is non-associative, so the
/// client must replay the same sequence to land on the same bits), and
/// deltas are *moved* in from `apply_rows`, never cloned.
#[derive(Default)]
pub(crate) struct WaveLog {
    pub(crate) deltas: Vec<RowDelta>,
    /// Workers that contributed an update in this interval. They fold
    /// their own pending updates into their cache locally (read-my-writes
    /// at tick), so shipping them a delta chain that includes their own
    /// contribution would double-count it; they get a snapshot instead.
    pub(crate) writers: Vec<WorkerId>,
}

/// Policy-agnostic shard state and mechanism. Owned by its thread after
/// `spawn`; constructed (and row-initialized) by the coordinator before
/// launch. Policies receive `&mut ShardCore` in every hook and drive the
/// mechanism through its fields and helpers.
pub struct ShardCore {
    pub(crate) id: usize,
    /// The logical shard this node currently serves. Equal to `id` for a
    /// primary; a promoted replica adopts its dead primary's logical id,
    /// so client-visible `shard:` fields (waves, bounds) keep naming the
    /// partition while transport addressing (`NodeId::Shard(id)`) keeps
    /// naming the physical node.
    pub(crate) logical: usize,
    pub(crate) workers: usize,
    pub(crate) rows: FxHashMap<Key, Row>,
    clocks: MinClock,
    /// Inverted registration index: key -> registered readers (addresses
    /// both ESSP clock waves and VAP per-update waves).
    pub(crate) readers: FxHashMap<Key, ReaderSet>,
    /// Per-worker registered-key count (a worker with >= 1 registration
    /// receives every clock wave, if only to learn the new table clock).
    pub(crate) reg_count: Vec<usize>,
    /// Rows updated since the last push wave: waves carry only these (the
    /// paper's server "pushes out the [updated] table-rows"), which keeps
    /// wave size proportional to update traffic, not to the working set.
    /// Maintained only when the policy pushes on commit.
    dirty: FxHashSet<Key>,
    track_dirty: bool,
    /// Whether `apply_rows` records per-key [`WaveLog`]s for delta waves.
    /// True when the policy waves (ESSP on commit, eager VAP per update);
    /// false on pull-only cores and during WAL replay, where logs would
    /// accumulate with no wave to consume them.
    log_wave_deltas: bool,
    /// Sticky override forcing every wave to ship full snapshots
    /// ([`Shard::force_snapshot_waves`]): the A/B control proving delta
    /// waves are bit-equivalent to snapshot waves, and a diagnostic
    /// escape hatch. Survives promotion.
    snapshot_waves_only: bool,
    /// Delta-wave chain state, per (key, worker): the vclock (ESSP) or
    /// wave seq (VAP) of the last wave that carried `key`'s row to that
    /// worker, `NEVER` if none — mirroring the client's per-row `wave`
    /// token. A key ships as a delta chain to exactly the readers whose
    /// token is live; anything that invalidates the client copy (pull
    /// reply, re-register, migration) resets the token to `NEVER` and the
    /// next wave re-seeds with a snapshot.
    pub(crate) shipped: FxHashMap<Key, Vec<Clock>>,
    /// Pending per-key delta logs, consumed (removed) by the next wave.
    pub(crate) wave_log: FxHashMap<Key, WaveLog>,
    /// Reusable per-worker wave assembly buffers (alloc-free steady
    /// state: `mem::take` of an empty Vec allocates nothing).
    wave_scratch: Vec<Vec<PushRow>>,
    /// Reusable buffer for dirty keys a wave defers (migration fence).
    wave_deferred: Vec<Key>,
    pending: Vec<PendingGet>,
    /// Deterministic application: buffer updates per (clock, worker) and
    /// apply them in that sorted order when the table clock commits, so
    /// float summation order — and hence the final parameters — is
    /// bit-identical no matter how messages interleave on the wire. Off
    /// by default (eager application propagates uncommitted freshness);
    /// multi-process runs enable it so a TCP cluster reproduces the
    /// in-process result exactly.
    deterministic: bool,
    /// Staged (not yet applied) update batches, keyed for sorted replay.
    staged: BTreeMap<(Clock, WorkerId), Vec<(Key, RowDelta)>>,
    /// Per-key generation index into `staged`: for each key, the
    /// (clock, worker, row-position) of every staged delta touching it.
    /// Entries are appended at staging time and pruned when their batch
    /// replays, so a deterministic VAP/AVAP preview (`staged_sums`) costs
    /// O(keys touched x straggle depth) instead of rescanning the whole
    /// backlog per inbound Update (the ROADMAP-flagged quadratic).
    /// Batches are only ever appended to or removed whole (the one
    /// exception, the migration handoff extraction, rebuilds the index),
    /// so stored positions never go stale.
    staged_index: FxHashMap<Key, Vec<(Clock, WorkerId, u32)>>,
    /// The live migration this shard participates in, if any.
    migration: Option<Migration>,
    /// Armed re-replication cut (source side): (epoch, fence clock,
    /// target node), fired once the table clock commits `at_clock - 1`.
    replica_sync: Option<(u64, Clock, usize)>,
    /// Re-replication catch-up gate (destination side), if closed.
    catchup: Option<CatchUp>,
    /// One-shot dedup floors after a disk rebuild (WAL-fallback spare).
    replay_floors: Option<ReplayFloors>,
    /// Keys this shard handed off, permanently mapped to their owners:
    /// late GETs/updates from clients that switched epochs after sending
    /// are relayed here. Empty (and O(1) to consult) until a handoff.
    forwards: FxHashMap<Key, usize>,
    net: TransportHandle,
    /// Uniform row length per table, for serving GETs of rows that no
    /// update or init has materialized yet (replied as zeros).
    row_len: HashMap<TableId, usize>,
    /// Cached all-zeros payloads per table (shared, never mutated).
    zero_rows: HashMap<TableId, Arc<[f32]>>,
    pub(crate) stats: ShardStats,
    /// Live telemetry registry, `Arc`-shared with the admin scrape thread
    /// (strictly out-of-band; see `ps::server` § Observability).
    pub(crate) metrics: Arc<ShardMetrics>,
    /// Event-trace flight recorder, when enabled (`--trace-out`).
    trace: Option<Arc<TraceRing>>,
    /// Request-span recorder (wire v9), when enabled (`--trace-spans` /
    /// `--span-sample`): inbound sampled Get/Update frames get
    /// `shard_queue` + `policy_admission` + `serve`/`apply` segments,
    /// and sampled push waves originate shard-side spans. Strictly
    /// out-of-band — never consulted by any protocol decision.
    spans: Option<Arc<SpanRing>>,
    /// Deterministic per-shard sampler for push-wave spans (one tick per
    /// emitted Push frame, so each frame gets its own trace id).
    span_sampler: SpanSampler,
}

/// Live write-ahead-log state of a durable shard (one generation).
struct Durability {
    cfg: DurabilityConfig,
    generation: u64,
    wal: wal::WalWriter,
    commits_since_compact: u64,
}

/// A shard = the policy-agnostic core plus the consistency policy its
/// config selects.
pub struct Shard {
    core: ShardCore,
    policy: Box<dyn ServerPolicy>,
    /// The run's consistency model, kept so a promoted replica can
    /// install the full server policy it must start enforcing.
    consistency: Consistency,
    durability: Option<Durability>,
    /// Scheduled faults for this shard, clock-sorted; `next_fault`
    /// indexes the first not-yet-fired one.
    faults: Vec<ShardFault>,
    next_fault: usize,
    /// Fault-injected slow-fsync stall, applied to every WAL generation.
    fsync_stall: Option<Duration>,
}

impl Shard {
    pub fn new(
        id: usize,
        workers: usize,
        consistency: Consistency,
        net: TransportHandle,
        row_len: HashMap<TableId, usize>,
        deterministic: bool,
    ) -> Self {
        Self::with_policy(
            id,
            workers,
            consistency.server_policy(workers),
            consistency,
            net,
            row_len,
            deterministic,
        )
    }

    /// A replica shard: the same core (same per-worker FIFO update/clock
    /// stream, same deterministic replay) behind a pull-only policy
    /// regardless of the run's consistency model. Replicas never push
    /// and never track value bounds — they serve GETs under the core's
    /// SSP wait condition, which is exactly the admission guarantee
    /// `ClientPolicy::replica_reads` relies on. The run's `consistency`
    /// is still carried: a [`ToShard::Promote`] swaps in its full server
    /// policy when this replica takes over a dead primary.
    pub fn replica(
        id: usize,
        workers: usize,
        consistency: Consistency,
        net: TransportHandle,
        row_len: HashMap<TableId, usize>,
        deterministic: bool,
    ) -> Self {
        Self::with_policy(
            id,
            workers,
            Box::new(super::policy::window::PullServer),
            consistency,
            net,
            row_len,
            deterministic,
        )
    }

    fn with_policy(
        id: usize,
        workers: usize,
        policy: Box<dyn ServerPolicy>,
        consistency: Consistency,
        net: TransportHandle,
        row_len: HashMap<TableId, usize>,
        deterministic: bool,
    ) -> Self {
        let track_dirty = policy.pushes_on_commit();
        let log_wave_deltas = track_dirty || (policy.waves_per_update() && !deterministic);
        Self {
            core: ShardCore {
                id,
                logical: id,
                workers,
                rows: FxHashMap::default(),
                clocks: MinClock::new(workers),
                readers: FxHashMap::default(),
                reg_count: vec![0; workers],
                dirty: FxHashSet::default(),
                track_dirty,
                log_wave_deltas,
                snapshot_waves_only: false,
                shipped: FxHashMap::default(),
                wave_log: FxHashMap::default(),
                wave_scratch: vec![Vec::new(); workers],
                wave_deferred: Vec::new(),
                pending: Vec::new(),
                deterministic,
                staged: BTreeMap::new(),
                staged_index: FxHashMap::default(),
                migration: None,
                replica_sync: None,
                catchup: None,
                replay_floors: None,
                forwards: FxHashMap::default(),
                net,
                row_len,
                zero_rows: HashMap::new(),
                stats: ShardStats::default(),
                metrics: Arc::new(ShardMetrics::new(id)),
                trace: None,
                spans: None,
                span_sampler: SpanSampler::new(0),
            },
            policy,
            consistency,
            durability: None,
            faults: Vec::new(),
            next_fault: 0,
            fsync_stall: None,
        }
    }

    /// Pre-launch initialization of a row (coordinator only).
    pub fn init_row(&mut self, key: Key, data: Vec<f32>) {
        self.core.init_row(key, data);
    }

    pub fn table_clock(&self) -> Clock {
        self.core.table_clock()
    }

    pub fn row(&self, key: &Key) -> Option<&Row> {
        self.core.row(key)
    }

    pub fn stats(&self) -> &ShardStats {
        &self.core.stats
    }

    /// The live telemetry registry (share with an admin scrape socket).
    pub fn metrics(&self) -> Arc<ShardMetrics> {
        Arc::clone(&self.core.metrics)
    }

    /// Attach the event-trace flight recorder.
    pub fn set_trace(&mut self, ring: Arc<TraceRing>) {
        self.core.trace = Some(ring);
    }

    /// Attach the request-span recorder (wire v9) and set the push-wave
    /// sampling rate (1-in-`sample`; 0 = record inbound sampled frames
    /// but originate no shard-side spans).
    pub fn set_spans(&mut self, ring: Arc<SpanRing>, sample: u64) {
        self.core.spans = Some(ring);
        self.core.span_sampler = SpanSampler::new(sample);
    }

    /// Size the hot-key profiler (`k` heavy hitters per sketch; 0
    /// disables). Must be called before [`Shard::metrics`] shares the
    /// registry handle (i.e. during cluster wiring).
    pub fn set_hot_key_k(&mut self, k: usize) {
        let m = Arc::get_mut(&mut self.core.metrics)
            .expect("set_hot_key_k after the metrics handle was shared");
        m.hot_gets = HotKeySketch::new(k);
        m.hot_updates = HotKeySketch::new(k);
    }

    /// Force every push wave to ship full row snapshots, never wire-v7
    /// delta chains. Deltas replay the exact ordered fold the shard
    /// applied, so a forced-snapshot run must be bit-identical to a
    /// delta run — this is the A/B control the equivalence tests (and
    /// `ClusterConfig::snapshot_waves`) flip. Sticky: survives
    /// promotion and crash recovery.
    pub fn force_snapshot_waves(&mut self) {
        self.core.snapshot_waves_only = true;
        self.core.log_wave_deltas = false;
    }

    /// Drive the shard from its inbox until Shutdown. Returns final stats
    /// and the row store (for end-of-run evaluation by the harness).
    pub fn run(mut self, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) {
        while let Ok(msg) = inbox.recv() {
            if !self.handle(msg) {
                break;
            }
            if !self.poll_faults() {
                // Killed by the fault plan: die without dumping — the
                // promoted replica's dump is authoritative for this
                // partition.
                return;
            }
        }
        // Safety net: staged updates are normally all replayed by the
        // final ClockTicks; anything left (e.g. a late forwarded update
        // from a client that switched epochs after its last tick) is
        // folded in sorted order rather than silently dropped.
        self.core.replay_staged_through(Clock::MAX);
        let metrics = self.core.metrics.entries();
        let _ = dump.send(ShardFinal {
            id: self.core.id,
            rows: self.core.rows,
            stats: self.core.stats,
            metrics,
        });
    }

    /// Process one message; false = shutdown requested. Pure routing:
    /// core mechanism first, then the matching policy hook — no model-
    /// specific branching.
    pub fn handle(&mut self, msg: ToShard) -> bool {
        // One-shot replay dedup after a WAL-fallback rebuild: the disk
        // history already contains every per-worker Update/ClockTick up
        // to the recorded floors, and clients re-send their in-window
        // tail unconditionally, so anything at or below a floor is a
        // duplicate and must be dropped *before* it reaches the WAL.
        if let Some(floors) = &self.core.replay_floors {
            let dup = match &msg {
                ToShard::Update { worker, clock, .. } => *clock <= floors.update[*worker],
                ToShard::ClockTick { worker, clock } => *clock <= floors.tick[*worker],
                ToShard::NormReport { worker, clock, .. } => *clock <= floors.tick[*worker],
                _ => false,
            };
            if dup {
                return true;
            }
        }
        // Write-ahead: every state-bearing message hits the log before it
        // is processed, so the durable history is never behind the live
        // state it produced.
        if let Some(d) = self.durability.as_mut() {
            if wal_loggable(&msg) {
                let t0 = std::time::Instant::now();
                d.wal.append(&msg).expect("WAL append");
                self.core
                    .metrics
                    .wal_append_ns
                    .record(t0.elapsed().as_nanos() as u64);
            }
        }
        match msg {
            ToShard::Get {
                key,
                worker,
                min_vclock,
                span,
            } => {
                self.core.span_arrive(span);
                self.core.on_get(key, worker, min_vclock, span);
            }
            ToShard::Update {
                worker,
                clock,
                rows,
                span,
            } => {
                self.core.span_arrive(span);
                let t0 = self.core.span_ts(span);
                let touched = self.core.on_update(worker, clock, rows);
                // In deterministic mode this times the staging step; the
                // sorted commit replay is not attributable to one trace.
                self.core.span_record(span, "apply", t0);
                self.policy.on_update(&mut self.core, worker, clock, &touched);
            }
            ToShard::ClockTick { worker, clock } => {
                if let Some(new_min) = self.core.on_tick(worker, clock) {
                    self.policy.on_commit(&mut self.core, new_min);
                    self.after_commit();
                }
            }
            ToShard::Register { key, worker } => {
                self.core.on_register(key, worker);
                self.policy.on_register(&mut self.core, worker);
            }
            ToShard::PushAck { worker, vclock } => {
                self.policy.on_push_ack(&mut self.core, worker, vclock)
            }
            ToShard::VapAck { worker, seq } => {
                self.policy.on_wave_ack(&mut self.core, worker, seq)
            }
            ToShard::NormReport {
                worker,
                clock,
                inf_norm,
            } => self
                .policy
                .on_norm_report(&mut self.core, worker, clock, inf_norm),
            ToShard::Detach { worker } => self.policy.on_detach(&mut self.core, worker),
            ToShard::MigrateBegin {
                epoch,
                at_clock,
                outgoing,
                incoming,
            } => self.core.on_migrate_begin(epoch, at_clock, outgoing, incoming),
            ToShard::RowHandoff {
                epoch,
                key,
                vclock,
                fresh,
                exists,
                data,
                staged,
            } => {
                // The last expected handoff releases a withheld table-
                // clock advance: run the policy's commit hook for it,
                // exactly as a ClockTick-driven advance would.
                if let Some(new_min) =
                    self.core
                        .on_row_handoff(epoch, key, vclock, fresh, exists, data, staged)
                {
                    self.policy.on_commit(&mut self.core, new_min);
                    self.after_commit();
                }
            }
            ToShard::MigrateCommit { epoch } => {
                // A catch-up commit can release a withheld table-clock
                // advance exactly like the last expected handoff does.
                if let Some(new_min) = self.core.on_migrate_commit(epoch) {
                    self.policy.on_commit(&mut self.core, new_min);
                    self.after_commit();
                }
            }
            ToShard::ReplicaSync {
                epoch,
                at_clock,
                target,
            } => self.core.on_replica_sync(epoch, at_clock, target as usize),
            ToShard::ReplicaCatchUp {
                epoch,
                at_clock,
                source,
                from_disk,
            } => {
                if from_disk {
                    self.recover_as_spare(source as usize);
                } else {
                    self.core.on_replica_catch_up(epoch, at_clock, source as usize);
                }
            }
            ToShard::Promote { delta } => self.on_promote(delta),
            ToShard::StatsPull { worker } => self.core.on_stats_pull(worker),
            ToShard::Shutdown => return false,
        }
        // One relaxed store + fetch_max per message: the backlog gauge
        // the scrape plane (and RunReport's high-water mark) reads.
        self.core
            .metrics
            .queue_depth
            .set((self.core.staged.len() + self.core.pending.len()) as u64);
        true
    }

    // --------------------------------------------- durability & faults

    /// Turn on the write-ahead log under `cfg`, recovering from the
    /// latest complete on-disk generation first if one exists. Call after
    /// row initialization: the fresh generation's checkpoint snapshots
    /// the current rows, so recovery never depends on re-running init.
    /// Returns true iff prior durable state was recovered.
    pub fn enable_durability(&mut self, cfg: DurabilityConfig) -> Result<bool> {
        let existing = durability::latest_generation(&cfg.dir, self.core.id);
        if let Some(g) = existing {
            let recovered = self.rebuild_core(&cfg, g)?;
            self.graft(recovered);
        }
        let next = existing.map_or(0, |g| g + 1);
        self.start_generation(cfg, next)?;
        Ok(existing.is_some())
    }

    /// Simulate a process crash plus restart: discard the volatile state
    /// the log covers, reload checkpoint + WAL tail from disk, and roll a
    /// fresh generation. Under deterministic replay the rebuilt state is
    /// bit-identical to the pre-crash state, so the run continues as if
    /// nothing happened. Session state (registrations, queued GETs,
    /// policy ledgers) survives in-process — the fault models losing the
    /// *durable* plane, which is what the WAL exists to cover.
    pub fn crash_and_recover(&mut self) -> Result<()> {
        let Some(cfg) = self.durability.as_ref().map(|d| d.cfg.clone()) else {
            eprintln!(
                "shard {}: crash fault ignored — durability is not enabled",
                self.core.id
            );
            return Ok(());
        };
        // Amnesia: abandon the live writer before re-reading disk, the
        // way a restarted process would find it.
        self.durability = None;
        let g = durability::latest_generation(&cfg.dir, self.core.id)
            .with_context(|| format!("shard {}: no durable generation to recover", self.core.id))?;
        let recovered = self.rebuild_core(&cfg, g)?;
        self.graft(recovered);
        self.core.trace_event(
            "crash_recover",
            format!("rebuilt from generation {g}, table clock {}", self.core.table_clock()),
        );
        self.start_generation(cfg, g + 1)
    }

    /// Install this shard's slice of a fault plan (clock-ordered).
    pub fn set_faults(&mut self, faults: Vec<ShardFault>) {
        self.faults = faults;
        self.next_fault = 0;
    }

    /// Fault-injected slow fsync applied to the WAL (current and future
    /// generations).
    pub fn set_fsync_stall(&mut self, stall: Option<Duration>) {
        self.fsync_stall = stall;
        if let Some(d) = self.durability.as_mut() {
            d.wal.set_fsync_stall(stall);
        }
    }

    /// Fire armed faults whose clock the table clock has reached. False =
    /// the shard was killed and must die without dumping.
    fn poll_faults(&mut self) -> bool {
        while self.next_fault < self.faults.len()
            && self.core.table_clock() >= self.faults[self.next_fault].at_clock
        {
            let fault = self.faults[self.next_fault];
            self.next_fault += 1;
            match fault.action {
                ShardAction::Pause(d) => {
                    eprintln!(
                        "shard {}: fault plan: pausing {d:?} at clock {}",
                        self.core.id, fault.at_clock
                    );
                    self.core.trace_event(
                        "fault_pause",
                        format!("pause {d:?} armed at clock {}", fault.at_clock),
                    );
                    std::thread::sleep(d);
                }
                ShardAction::Crash => {
                    eprintln!(
                        "shard {}: fault plan: crash + recover at clock {}",
                        self.core.id, fault.at_clock
                    );
                    self.core.trace_event(
                        "fault_crash",
                        format!("crash + recover armed at clock {}", fault.at_clock),
                    );
                    self.crash_and_recover().expect("fault-plan crash recovery");
                }
                ShardAction::Kill => {
                    eprintln!(
                        "shard {}: fault plan: killed at clock {}",
                        self.core.id, fault.at_clock
                    );
                    self.core.trace_event(
                        "fault_kill",
                        format!("killed at clock {}", fault.at_clock),
                    );
                    // No dying act: the shard dies silently and the
                    // coordinator's failure detector (missed heartbeats
                    // confirmed by the transport's peer_down) notices
                    // and emits the Promote itself.
                    return false;
                }
            }
        }
        true
    }

    /// Commit-boundary durability work: fsync the log per policy, and
    /// compact into a fresh generation when due. Compaction is skipped
    /// while this shard has migration state (forwards, fences): the
    /// arming frames live in the current log and a seed WAL does not
    /// re-encode them — the log simply keeps growing until the next
    /// migration-quiet window.
    fn after_commit(&mut self) {
        let Some(d) = self.durability.as_mut() else {
            return;
        };
        let t0 = std::time::Instant::now();
        d.wal.commit().expect("WAL commit fsync");
        self.core
            .metrics
            .wal_fsync_ns
            .record(t0.elapsed().as_nanos() as u64);
        d.commits_since_compact += 1;
        let due = d.cfg.compact_every > 0 && d.commits_since_compact >= d.cfg.compact_every;
        if due
            && self.core.migration.is_none()
            && self.core.forwards.is_empty()
            && self.core.catchup.is_none()
            && self.core.replica_sync.is_none()
        {
            let cfg = d.cfg.clone();
            let next = d.generation + 1;
            self.start_generation(cfg, next).expect("WAL compaction");
        }
    }

    /// Write generation `generation` from the current core state (the
    /// compaction step) and make it the live one, then purge older
    /// generations. Checkpoint first, seed WAL second — recovery requires
    /// BOTH, so a crash between the two leaves the previous pair intact.
    fn start_generation(&mut self, cfg: DurabilityConfig, generation: u64) -> Result<()> {
        self.core
            .trace_event("wal_generation", format!("rolling to generation {generation}"));
        let wal = write_generation(&self.core, &cfg, generation, self.fsync_stall)?;
        self.durability = Some(Durability {
            cfg,
            generation,
            wal,
            commits_since_compact: 0,
        });
        let d = self.durability.as_ref().unwrap();
        durability::purge_generations_below(&d.cfg.dir, self.core.id, generation);
        Ok(())
    }

    /// Rebuild a core from generation `g` on disk: load the checkpoint,
    /// then feed the WAL tail through the normal core handlers (no policy
    /// hooks, sends dropped). Deterministic mode re-stages exactly; eager
    /// mode re-applies in log order, which IS the original arrival order.
    fn rebuild_core(&self, cfg: &DurabilityConfig, g: u64) -> Result<ShardCore> {
        self.rebuild_core_of(cfg, self.core.id, g)
    }

    /// [`rebuild_core`] generalized over whose generation is read: a
    /// WAL-fallback spare rebuilds the *dead primary's* on-disk history
    /// (`owner` != `self.core.id`) to take over its partition when no
    /// live replica survived. The rebuilt core carries `owner` as both
    /// physical and logical identity; [`graft`] then adopts it.
    fn rebuild_core_of(&self, cfg: &DurabilityConfig, owner: usize, g: u64) -> Result<ShardCore> {
        let mut core = ShardCore {
            id: owner,
            logical: owner,
            workers: self.core.workers,
            rows: FxHashMap::default(),
            clocks: MinClock::new(self.core.workers),
            readers: FxHashMap::default(),
            reg_count: vec![0; self.core.workers],
            dirty: FxHashSet::default(),
            track_dirty: false,
            log_wave_deltas: false,
            snapshot_waves_only: false,
            shipped: FxHashMap::default(),
            wave_log: FxHashMap::default(),
            wave_scratch: vec![Vec::new(); self.core.workers],
            wave_deferred: Vec::new(),
            pending: Vec::new(),
            deterministic: self.core.deterministic,
            staged: BTreeMap::new(),
            staged_index: FxHashMap::default(),
            migration: None,
            replica_sync: None,
            catchup: None,
            replay_floors: None,
            forwards: FxHashMap::default(),
            net: TransportHandle::new(NullTransport),
            row_len: self.core.row_len.clone(),
            zero_rows: HashMap::new(),
            stats: ShardStats::default(),
            // Recovery replays history through a throwaway core: its
            // counters must not double into the live registry.
            metrics: Arc::new(ShardMetrics::new(self.core.id)),
            trace: None,
            spans: None,
            span_sampler: SpanSampler::new(0),
        };
        let ckpt = durability::ckpt_path(&cfg.dir, core.id, g);
        for (key, data, fresh) in checkpoint::load_v2(&ckpt)? {
            core.rows.insert(
                key,
                Row {
                    data: data.into(),
                    fresh,
                },
            );
        }
        let wal_file = durability::wal_path(&cfg.dir, core.id, g);
        let replayed = wal::replay(&wal_file)?;
        ensure!(
            replayed.header.shard as usize == core.id,
            "{wal_file:?} belongs to shard {}, not {}",
            replayed.header.shard,
            core.id
        );
        if replayed.dropped_bytes > 0 {
            eprintln!(
                "shard {}: WAL {wal_file:?}: dropped a {}-byte torn tail (crash mid-append)",
                core.id, replayed.dropped_bytes
            );
        }
        for m in replayed.records {
            match m {
                ToShard::Update {
                    worker,
                    clock,
                    rows,
                    ..
                } => {
                    core.on_update(worker, clock, rows);
                }
                ToShard::ClockTick { worker, clock } => {
                    core.on_tick(worker, clock);
                }
                ToShard::MigrateBegin {
                    epoch,
                    at_clock,
                    outgoing,
                    incoming,
                } => core.on_migrate_begin(epoch, at_clock, outgoing, incoming),
                ToShard::RowHandoff {
                    epoch,
                    key,
                    vclock,
                    fresh,
                    exists,
                    data,
                    staged,
                } => {
                    core.on_row_handoff(epoch, key, vclock, fresh, exists, data, staged);
                }
                ToShard::MigrateCommit { epoch } => {
                    core.on_migrate_commit(epoch);
                }
                ToShard::ReplicaSync {
                    epoch,
                    at_clock,
                    target,
                } => {
                    // Replayed against a NullTransport: the cut re-runs
                    // but its handoffs go nowhere, leaving only the
                    // (correct) cleared arming state behind.
                    core.on_replica_sync(epoch, at_clock, target as usize);
                }
                ToShard::ReplicaCatchUp {
                    epoch,
                    at_clock,
                    source,
                    from_disk,
                } => {
                    if from_disk {
                        // A disk rebuild inside a disk rebuild cannot
                        // recurse; the post-graft generation roll seeds
                        // a fresh log, so this frame is never re-read
                        // in practice.
                        eprintln!(
                            "shard {}: ignoring from-disk ReplicaCatchUp during replay",
                            core.id
                        );
                    } else {
                        core.on_replica_catch_up(epoch, at_clock, source as usize);
                    }
                }
                ToShard::Promote { delta } => {
                    if let Some((primary, _)) = delta.promote {
                        core.logical = primary as usize;
                    }
                }
                other => eprintln!(
                    "shard {}: ignoring non-loggable frame in WAL: {other:?}",
                    core.id
                ),
            }
        }
        Ok(core)
    }

    /// Adopt a rebuilt core's durable fields, keeping this shard's
    /// session state (registrations, queued GETs, policy, stats, network)
    /// untouched. If the policy pushes on commit, every row is marked
    /// dirty so the next wave re-certifies all client copies — pushing
    /// more than necessary is always sound.
    fn graft(&mut self, recovered: ShardCore) {
        let c = &mut self.core;
        c.rows = recovered.rows;
        c.staged = recovered.staged;
        c.staged_index = recovered.staged_index;
        c.clocks = recovered.clocks;
        c.forwards = recovered.forwards;
        c.migration = recovered.migration;
        c.logical = recovered.logical;
        c.dirty.clear();
        // Every delta chain is suspect after a rebuild: clients may hold
        // copies the replayed history never shipped. Drop all chain state
        // so the next wave re-seeds with snapshots (always sound).
        c.shipped.clear();
        c.wave_log.clear();
        if c.track_dirty {
            let keys: Vec<Key> = c.rows.keys().copied().collect();
            c.dirty.extend(keys);
        }
        let visible = c.visible_clock();
        c.serve_pending(visible);
    }

    /// A replica takes over its dead primary's partition: adopt the
    /// logical identity, install the run's full server policy, mark every
    /// row dirty (the first post-promotion wave re-certifies all client
    /// copies), and relay the placement delta to every worker so clients
    /// re-route.
    fn on_promote(&mut self, delta: PlacementDelta) {
        let Some((primary, node)) = delta.promote else {
            // A promotion-less delta (a re-replication attach, or a pure
            // death record) uses this serving node as the relay point:
            // forward it to every worker unchanged. The coordinator has
            // no direct channel to the workers in a multi-process
            // cluster, but any live shard does.
            self.core.trace_event(
                "placement_relay",
                format!("epoch {} relayed to {} workers", delta.epoch, self.core.workers),
            );
            for w in 0..self.core.workers {
                self.core
                    .send_to_worker(w, ToWorker::Placement { delta: delta.clone() });
            }
            return;
        };
        assert_eq!(
            node as usize, self.core.id,
            "Promote for node {node} delivered to shard {}",
            self.core.id
        );
        self.core.metrics.promotions.inc();
        self.core.trace_event(
            "promotion",
            format!("replica node {node} takes over partition {primary}"),
        );
        self.core.logical = primary as usize;
        self.policy = self.consistency.server_policy(self.core.workers);
        self.core.track_dirty = self.policy.pushes_on_commit();
        self.core.log_wave_deltas = !self.core.snapshot_waves_only
            && (self.core.track_dirty
                || (self.policy.waves_per_update() && !self.core.deterministic));
        // Chain state learned as a replica (there is none — replicas
        // never wave) or left over from a past life is void; snapshots
        // re-seed every reader on the first post-promotion wave.
        self.core.shipped.clear();
        self.core.wave_log.clear();
        if self.core.track_dirty {
            let keys: Vec<Key> = self.core.rows.keys().copied().collect();
            self.core.dirty.extend(keys);
        }
        for w in 0..self.core.workers {
            self.core.send_to_worker(w, ToWorker::Placement { delta: delta.clone() });
        }
    }

    /// WAL-fallback takeover (the double-failure path): this spare
    /// rebuilds the dead primary `owner`'s partition from the latest
    /// durable generation on shared storage — no live replica survived
    /// to stream it. The rebuilt fold is exact through the last frame
    /// the dead primary fsynced; clients close the gap by re-sending
    /// their in-window tail unconditionally, and the one-shot
    /// [`ReplayFloors`] recorded here drop the prefix the disk history
    /// already contains (exact for the five models whose server fold is
    /// a pure function of the committed update stream; VAP/AVAP value-
    /// bound ledgers are session state and restart conservatively).
    fn recover_as_spare(&mut self, owner: usize) {
        let Some(cfg) = self.durability.as_ref().map(|d| d.cfg.clone()) else {
            eprintln!(
                "shard {}: ignoring from-disk ReplicaCatchUp — durability is not enabled",
                self.core.id
            );
            return;
        };
        let Some(g) = durability::latest_generation(&cfg.dir, owner) else {
            eprintln!(
                "shard {}: ignoring from-disk ReplicaCatchUp — no durable generation for shard {owner}",
                self.core.id
            );
            return;
        };
        let recovered = self
            .rebuild_core_of(&cfg, owner, g)
            .expect("WAL-fallback rebuild");
        let floors = ReplayFloors::of(&recovered);
        self.graft(recovered);
        self.core.replay_floors = Some(floors);
        self.core.trace_event(
            "replica_catchup",
            format!(
                "from-disk: rebuilt partition {owner} from generation {g}, table clock {}",
                self.core.table_clock()
            ),
        );
        // Roll a fresh generation under this node's own id: the grafted
        // checkpoint + the Promote marker (logical != id) make future
        // crash recovery self-contained.
        let next = self
            .durability
            .as_ref()
            .map_or(0, |d| d.generation + 1);
        self.start_generation(cfg, next)
            .expect("WAL-fallback generation roll");
    }

    #[cfg(test)]
    fn core(&self) -> &ShardCore {
        &self.core
    }
}

/// Messages the WAL records: everything that mutates durable state
/// (rows, clocks, staged replay, migration/forward tables, logical
/// identity). Session traffic — GETs, registrations, acks, norm reports,
/// detaches — is rebuilt by live clients, not by recovery.
fn wal_loggable(m: &ToShard) -> bool {
    matches!(
        m,
        ToShard::Update { .. }
            | ToShard::ClockTick { .. }
            | ToShard::MigrateBegin { .. }
            | ToShard::RowHandoff { .. }
            | ToShard::MigrateCommit { .. }
            | ToShard::ReplicaSync { .. }
            | ToShard::ReplicaCatchUp { .. }
            | ToShard::Promote { .. }
    )
}

/// Write generation `generation`'s checkpoint + seed WAL from `core`'s
/// current state. The seed WAL re-seeds the per-worker committed clocks
/// (one ClockTick each; `MinClock` accepts forward jumps) and carries the
/// staged-but-uncommitted tail as ordinary Update frames, plus a Promote
/// marker when the node serves an adopted logical id — everything
/// recovery needs beyond the row snapshot.
fn write_generation(
    core: &ShardCore,
    cfg: &DurabilityConfig,
    generation: u64,
    stall: Option<Duration>,
) -> Result<wal::WalWriter> {
    let rows: Vec<(Key, Vec<f32>, Clock)> = core
        .rows
        .iter()
        .map(|(k, r)| (*k, r.data.to_vec(), r.fresh))
        .collect();
    checkpoint::save_v2(&durability::ckpt_path(&cfg.dir, core.id, generation), &rows)?;
    let mut w = wal::WalWriter::create(
        &durability::wal_path(&cfg.dir, core.id, generation),
        core.id,
        generation,
        cfg.fsync,
    )?;
    w.set_fsync_stall(stall);
    if core.logical != core.id {
        w.append(&ToShard::Promote {
            delta: PlacementDelta {
                epoch: 0,
                at_clock: 0,
                grow_active: None,
                promote: Some((core.logical as u32, core.id as u32)),
                attach: None,
                dead: vec![],
                moves: vec![],
            },
        })?;
    }
    for worker in 0..core.workers {
        let clock = core.clocks.committed(worker);
        if clock > NEVER {
            w.append(&ToShard::ClockTick { worker, clock })?;
        }
    }
    for (&(clock, worker), rows) in core.staged.iter() {
        if rows.is_empty() {
            continue;
        }
        w.append(&ToShard::Update {
            worker,
            clock,
            rows: rows.clone(),
            span: None,
        })?;
    }
    w.commit()?;
    Ok(w)
}

/// Transport that drops every send: recovery replays WAL frames through
/// the live handler code paths, whose side-channel sends (forward relays,
/// handoffs) already happened in the original run.
struct NullTransport;

impl Transport for NullTransport {
    fn send(&self, _src: NodeId, _dst: NodeId, _packet: Packet) {}
}

impl ShardCore {
    pub fn init_row(&mut self, key: Key, data: Vec<f32>) {
        self.rows.insert(
            key,
            Row {
                data: data.into(),
                fresh: super::types::NEVER,
            },
        );
    }

    pub fn table_clock(&self) -> Clock {
        self.clocks.min()
    }

    pub fn row(&self, key: &Key) -> Option<&Row> {
        self.rows.get(key)
    }

    /// Send one message to a worker through the data plane.
    pub(crate) fn send_to_worker(&self, worker: WorkerId, msg: ToWorker) {
        self.net.send(
            NodeId::Shard(self.id),
            NodeId::Worker(worker),
            Packet::ToWorker(msg),
        );
    }

    /// Send one message to a peer shard (migration handoffs/forwards).
    pub(crate) fn send_to_shard(&self, shard: usize, msg: ToShard) {
        self.net.send(
            NodeId::Shard(self.id),
            NodeId::Shard(shard),
            Packet::ToShard(msg),
        );
    }

    /// Record one lifecycle event on the attached trace ring (no-op when
    /// tracing is off), stamped with the current table clock.
    pub(crate) fn trace_event(&self, kind: &str, detail: String) {
        if let Some(t) = &self.trace {
            t.record(&self.metrics.node, self.table_clock(), kind, detail);
        }
    }

    /// Close a sampled frame's `shard_queue` segment: from the
    /// transport's inbox-arrival mark (same-process rings only; cross-
    /// process the mark is absent and the segment collapses to zero) to
    /// the moment the shard thread picked the message up.
    fn span_arrive(&self, span: Option<SpanCtx>) {
        let (Some(ring), Some(span)) = (&self.spans, span) else {
            return;
        };
        let now = SpanRing::now_us();
        let start = ring.take_mark(span.trace_id, Mark::ArriveShard).unwrap_or(now);
        ring.record(
            span,
            &self.metrics.node,
            "shard_queue",
            start,
            now.saturating_sub(start),
        );
    }

    /// Current span timestamp, or 0 when this frame records nothing here
    /// (avoids the clock syscall on the unsampled hot path).
    fn span_ts(&self, span: Option<SpanCtx>) -> u64 {
        if self.spans.is_some() && span.is_some() {
            SpanRing::now_us()
        } else {
            0
        }
    }

    /// Record one segment `seg` for `span` running from `start_us` to
    /// now. No-op unless both a ring is attached and the frame carried a
    /// span.
    fn span_record(&self, span: Option<SpanCtx>, seg: &'static str, start_us: u64) {
        if let (Some(ring), Some(span)) = (&self.spans, span) {
            let now = SpanRing::now_us();
            ring.record(
                span,
                &self.metrics.node,
                seg,
                start_us,
                now.saturating_sub(start_us),
            );
        }
    }

    /// Telemetry pull (out-of-band): reply immediately with this node's
    /// flattened metrics snapshot. Never staged, never WAL-logged, no
    /// protocol state touched — see `ps::server` § Observability.
    fn on_stats_pull(&mut self, worker: WorkerId) {
        self.metrics.stats_pulls.inc();
        let mut entries = self.metrics.entries();
        if worker == super::msg::COORD_STATS_WORKER {
            // The detector plans re-replication fences from the observed
            // table clock; ship it as a synthetic entry (the registry
            // itself only carries counters/histograms).
            entries.push(("table_clock".into(), self.table_clock().max(0) as u64));
            // Heartbeat probe from the coordinator's failure detector:
            // the reply routes back to the coordinator inbox, not to
            // any worker. The reply's arrival IS the liveness signal;
            // its payload doubles as the telemetry snapshot.
            self.net.send(
                NodeId::Shard(self.id),
                NodeId::Coordinator,
                Packet::ToWorker(ToWorker::StatsReport { shard: self.id, entries }),
            );
        } else {
            self.send_to_worker(worker, ToWorker::StatsReport { shard: self.id, entries });
        }
    }

    /// The table clock reads may be served at. Normally the MinClock
    /// minimum; while this shard still awaits migration handoffs — or a
    /// re-replication catch-up stream — it is capped at `at_clock - 1`:
    /// staged updates beyond the fence are not applied yet, so no reply
    /// may claim their clocks.
    fn visible_clock(&self) -> Clock {
        let mut min = self.clocks.min();
        if let Some(m) = &self.migration {
            if !m.awaiting.is_empty() {
                min = min.min(m.at_clock - 1);
            }
        }
        if let Some(cu) = &self.catchup {
            min = min.min(cu.at_clock - 1);
        }
        min
    }

    /// Destination shard for a key this shard has already handed off
    /// (the forward table for late traffic), if any.
    fn forward_of(&self, key: &Key) -> Option<usize> {
        if self.forwards.is_empty() {
            return None;
        }
        self.forwards.get(key).copied()
    }

    /// Is `key` still in flight toward this shard (handoff not arrived)?
    fn awaiting_handoff(&self, key: &Key) -> bool {
        self.migration
            .as_ref()
            .is_some_and(|m| m.awaiting.contains(key))
    }

    /// All-zeros payload for `table`, shared across replies.
    fn zero_row(&mut self, table: TableId) -> Arc<[f32]> {
        if let Some(z) = self.zero_rows.get(&table) {
            return Arc::clone(z);
        }
        let len = *self.row_len.get(&table).unwrap_or_else(|| {
            panic!(
                "GET of uninitialized row in table {table} with unknown row \
                 length on shard {}",
                self.id
            )
        });
        let z: Arc<[f32]> = vec![0.0f32; len].into();
        self.zero_rows.insert(table, Arc::clone(&z));
        z
    }

    fn reply_row(&mut self, key: Key, worker: WorkerId, span: Option<SpanCtx>) {
        let t0 = self.span_ts(span);
        let vclock = self.visible_clock();
        // A pull reply replaces the worker's cached copy outside the wave
        // chain (the client installs it with a broken token), so the next
        // wave must re-seed it with a snapshot.
        if let Some(tokens) = self.shipped.get_mut(&key) {
            tokens[worker] = super::types::NEVER;
        }
        // A GET may legitimately race ahead of row materialization (e.g.
        // the row will first exist when some worker's update creates it):
        // serve zeros of the table's row length rather than panicking.
        let (data, fresh) = match self.rows.get(&key) {
            Some(row) => (Arc::clone(&row.data), row.fresh),
            None => (self.zero_row(key.0), super::types::NEVER),
        };
        self.stats.gets_served += 1;
        self.metrics.gets_served.inc();
        self.send_to_worker(
            worker,
            ToWorker::Row {
                key,
                data,
                vclock,
                fresh: fresh.max(vclock),
                span,
            },
        );
        self.span_record(span, "serve", t0);
    }

    fn on_get(&mut self, key: Key, worker: WorkerId, min_vclock: Clock, span: Option<SpanCtx>) {
        // A key this shard already handed off is answered by its new
        // owner: relay the GET (the reply goes straight to the worker).
        // The span rides along — its next segments record at the owner.
        if let Some(dst) = self.forward_of(&key) {
            self.stats.gets_forwarded += 1;
            self.metrics.gets_forwarded.inc();
            self.send_to_shard(
                dst,
                ToShard::Get {
                    key,
                    worker,
                    min_vclock,
                    span,
                },
            );
            return;
        }
        self.metrics.hot_gets.observe(key);
        if !self.awaiting_handoff(&key) && self.visible_clock() >= min_vclock {
            // Admitted on arrival: a zero-length admission segment keeps
            // the per-segment histograms comparable across models.
            self.span_record(span, "policy_admission", self.span_ts(span));
            self.reply_row(key, worker, span);
        } else {
            // SSP wait condition — or a migrated-in key whose handoff
            // has not landed: hold the reply.
            self.stats.gets_queued += 1;
            self.metrics.gets_queued.inc();
            self.pending.push(PendingGet {
                key,
                worker,
                min_vclock,
                span,
                queued_us: self.span_ts(span),
            });
        }
    }

    fn on_register(&mut self, key: Key, worker: WorkerId) {
        let workers = self.workers;
        let set = self
            .readers
            .entry(key)
            .or_insert_with(|| ReaderSet::for_workers(workers));
        if set.insert(worker) {
            self.reg_count[worker] += 1;
            // A fresh registration (or a re-registration after eviction)
            // means we cannot assume the worker still holds any copy a
            // past wave shipped: break the delta chain so the next wave
            // re-seeds with a snapshot.
            if let Some(tokens) = self.shipped.get_mut(&key) {
                tokens[worker] = super::types::NEVER;
            }
        }
    }

    /// Process one inbound Update batch: apply it (eager path) or stage
    /// it for deterministic replay. Returns the touched keys (for the
    /// policy's `on_update` hook). Rows for keys already handed off in a
    /// migration are relayed to their new owner instead (a client that
    /// learned the epoch late); their waves fire there.
    fn on_update(
        &mut self,
        source: WorkerId,
        clock: Clock,
        mut rows: Vec<(Key, RowDelta)>,
    ) -> Vec<Key> {
        if !self.forwards.is_empty() {
            let mut forwarded: FxHashMap<usize, Vec<(Key, RowDelta)>> = FxHashMap::default();
            let mut kept = Vec::with_capacity(rows.len());
            for (key, delta) in rows {
                match self.forward_of(&key) {
                    Some(dst) => forwarded.entry(dst).or_default().push((key, delta)),
                    None => kept.push((key, delta)),
                }
            }
            for (dst, fwd) in forwarded {
                self.stats.updates_forwarded += fwd.len() as u64;
                self.metrics.updates_forwarded.add(fwd.len() as u64);
                // Relayed without the original span: an update can split
                // toward several owners, and one trace id must not ride
                // multiple concurrent frames (the arrival marks collide).
                self.send_to_shard(
                    dst,
                    ToShard::Update {
                        worker: source,
                        clock,
                        rows: fwd,
                        span: None,
                    },
                );
            }
            rows = kept;
        }
        if self.deterministic {
            // Defer until the table clock commits `clock`; replay is then
            // sorted by (clock, worker), independent of arrival order.
            let keys: Vec<Key> = rows.iter().map(|(k, _)| *k).collect();
            self.stage_rows(clock, source, rows);
            return keys;
        }
        self.apply_rows(clock, source, rows)
    }

    /// Stage a batch's rows for deterministic replay, maintaining the
    /// per-key generation index.
    fn stage_rows(&mut self, clock: Clock, source: WorkerId, rows: Vec<(Key, RowDelta)>) {
        if rows.is_empty() {
            return;
        }
        self.metrics.updates_staged.add(rows.len() as u64);
        let base = self.staged.entry((clock, source)).or_default().len();
        for (i, (key, _)) in rows.iter().enumerate() {
            self.staged_index
                .entry(*key)
                .or_default()
                .push((clock, source, (base + i) as u32));
        }
        self.staged
            .get_mut(&(clock, source))
            .expect("batch just created")
            .extend(rows);
    }

    /// Apply one update batch to the row store (copy-on-write per row).
    /// Each delta is folded in its own representation: a sparse delta
    /// touches only its nnz indices — no densification on the apply path.
    /// When the policy waves, each delta is then *moved* into the key's
    /// [`WaveLog`] (tagged with the contributing `source`), so the next
    /// wave can ship the exact ordered fold instead of a snapshot.
    fn apply_rows(
        &mut self,
        clock: Clock,
        source: WorkerId,
        rows: Vec<(Key, RowDelta)>,
    ) -> Vec<Key> {
        let mut touched = Vec::with_capacity(rows.len());
        for (key, delta) in rows {
            self.stats.updates_applied += 1;
            self.metrics.updates_applied.inc();
            self.metrics.hot_updates.observe(key);
            if self.track_dirty {
                self.dirty.insert(key);
            }
            // Materializing a row from its first update zero-fills the
            // delta's claimed width — and a decoded frame may lie about
            // it (a sparse row's `len` is a claim, not bytes actually on
            // the wire). Validate against the table registry when one
            // exists, so a corrupt frame cannot demand huge zero-fills;
            // tables without a registered uniform width (variable-length
            // LM tensors, bare test fixtures) keep the delta's word.
            let row_len = &self.row_len;
            let row = self.rows.entry(key).or_insert_with(|| {
                if let Some(&registered) = row_len.get(&key.0) {
                    assert_eq!(
                        registered,
                        delta.len(),
                        "update materializing {:?} claims width {} but table {} registers {}",
                        key,
                        delta.len(),
                        key.0,
                        registered
                    );
                }
                Row {
                    data: vec![0.0; delta.len()].into(),
                    fresh: super::types::NEVER,
                }
            });
            debug_assert_eq!(row.data.len(), delta.len(), "row length mismatch {key:?}");
            // Copy-on-write: mutate in place while we hold the only
            // reference; otherwise detach from the (in-flight) snapshot.
            if Arc::get_mut(&mut row.data).is_none() {
                let detached: Arc<[f32]> = row.data.iter().copied().collect();
                row.data = detached;
            }
            let data = Arc::get_mut(&mut row.data).expect("unique after copy-on-write");
            delta.add_into(data);
            row.fresh = row.fresh.max(clock);
            touched.push(key);
            if self.log_wave_deltas {
                let log = self.wave_log.entry(key).or_default();
                if !log.writers.contains(&source) {
                    log.writers.push(source);
                }
                log.deltas.push(delta);
            }
        }
        touched
    }

    /// Summed staged-but-unapplied deltas per key, restricted to `keys`
    /// (deterministic mode defers application to the table-clock commit).
    /// Policies that propagate update *values* eagerly overlay these sums
    /// so their waves carry everything the store will apply — including
    /// concurrent workers' staged parts, exactly like the eager path's
    /// accumulated store contents. Empty (and O(1)) outside deterministic
    /// mode.
    ///
    /// Cost is O(keys touched x straggle depth) via the per-key
    /// generation index — NOT a rescan of the whole staged backlog, which
    /// degraded quadratically under a straggler (see the regression test
    /// `staggered_staged_sums_cost_does_not_rescan_backlog`). Per key,
    /// entries are folded in (clock, worker, row-position) order —
    /// exactly the order the sorted commit replay applies them — so
    /// previews stay bit-deterministic with zero float subtraction;
    /// sparse parts accumulate with the same hybrid fold the client's
    /// coalescing uses, so a below-threshold sum stays sparse.
    pub(crate) fn staged_sums(&self, keys: &[Key]) -> FxHashMap<Key, RowDelta> {
        let mut out: FxHashMap<Key, RowDelta> = FxHashMap::default();
        if self.staged.is_empty() {
            return out;
        }
        for key in keys {
            let Some(entries) = self.staged_index.get(key) else {
                continue;
            };
            if entries.is_empty() {
                continue;
            }
            // Appended in arrival order; fold in replay order.
            let mut ordered: Vec<(Clock, WorkerId, u32)> = entries.clone();
            ordered.sort_unstable();
            let mut acc: Option<RowDelta> = None;
            for (c, w, i) in ordered {
                let (k, d) = &self.staged[&(c, w)][i as usize];
                debug_assert_eq!(k, key, "staged index points at the wrong row");
                match &mut acc {
                    Some(a) => a.add_assign(d),
                    None => acc = Some(d.clone()),
                }
            }
            if let Some(a) = acc {
                out.insert(*key, a);
            }
        }
        out
    }

    /// Commit `worker`'s `clock`; on a table-clock advance, run the
    /// commit-side effects (staged replay, pending GETs) subject to the
    /// migration fences, and report the clock the policy's commit hook
    /// should observe (None while the advance is withheld awaiting
    /// handoffs — the final RowHandoff releases it).
    fn on_tick(&mut self, worker: WorkerId, clock: Clock) -> Option<Clock> {
        let new_min = self.clocks.commit(worker, clock)?;
        self.advance(new_min)
    }

    fn advance(&mut self, new_min: Clock) -> Option<Clock> {
        // Re-replication catch-up gate (destination side): this spare is
        // a shard-wide migration destination — every row is "awaiting".
        // Hold the whole advance until MigrateCommit opens the gate;
        // updates duplicated from clients all carry clock >= at_clock,
        // so nothing below the fence can be missing.
        if let Some(cu) = self.catchup.as_mut() {
            cu.held_min = Some(cu.held_min.unwrap_or(new_min).max(new_min));
            let visible = cu.at_clock - 1;
            self.replay_staged_through(visible);
            self.serve_pending(visible);
            return None;
        }
        // Source fence: once every worker has committed at_clock-1, all
        // pre-migration updates are here — replay through the fence,
        // then hand the migrated rows (plus their staged tails) off.
        let fence = self
            .migration
            .as_ref()
            .filter(|m| !m.handed_off)
            .map(|m| m.at_clock);
        if let Some(at) = fence {
            if new_min >= at - 1 {
                self.replay_staged_through(at - 1);
                self.do_handoff();
            }
        }
        // Re-replication cut (source side): at the commit of at_clock-1
        // the rows are exactly the fold of every committed update — copy
        // that fold to the spare. Unlike the migration fence this does
        // not gate this shard's own progress: rows are copied, not
        // moved, and updates from at_clock on are duplicated to the
        // spare by the clients themselves.
        if let Some((_, at, _)) = self.replica_sync {
            if new_min >= at - 1 {
                self.replay_staged_through(at - 1);
                self.do_replica_sync();
            }
        }
        // Destination fence: hold the visible advance at at_clock-1
        // while expected handoffs are outstanding; a staged update with
        // clock >= at_clock must never apply before the base row it
        // lands on has arrived. (Wave soundness for in-flight keys needs
        // no hold: push_wave defers them, and a shard's announcements
        // only ever certify copies that shard itself served — see
        // `RowCache`'s source tag.)
        let hold = match self.migration.as_mut() {
            Some(m) if !m.awaiting.is_empty() && new_min >= m.at_clock => {
                m.held_min = Some(m.held_min.unwrap_or(new_min).max(new_min));
                Some(m.at_clock - 1)
            }
            _ => None,
        };
        if let Some(visible) = hold {
            self.replay_staged_through(visible);
            self.serve_pending(visible);
            return None;
        }
        // Deterministic mode: every update with clock <= new_min has
        // arrived (Update precedes ClockTick on each FIFO link), so
        // replay them in sorted (clock, worker) order before serving
        // reads or firing the wave for this advance.
        self.replay_staged_through(new_min);
        self.serve_pending(new_min);
        self.metrics.commits.inc();
        Some(new_min)
    }

    /// Replay staged batches with clock <= `limit` in sorted
    /// (clock, worker) order, pruning their index entries.
    pub(crate) fn replay_staged_through(&mut self, limit: Clock) {
        while let Some((&(c, w), _)) = self.staged.first_key_value() {
            if c > limit {
                break;
            }
            let rows = self.staged.remove(&(c, w)).unwrap();
            for (key, _) in &rows {
                let mut emptied = false;
                if let Some(ix) = self.staged_index.get_mut(key) {
                    ix.retain(|e| !(e.0 == c && e.1 == w));
                    emptied = ix.is_empty();
                }
                if emptied {
                    self.staged_index.remove(key);
                }
            }
            self.apply_rows(c, w, rows);
        }
        debug_assert!(
            !self.staged.is_empty() || self.staged_index.is_empty(),
            "staged index leaked entries past an empty backlog"
        );
    }

    fn serve_pending(&mut self, table_clock: Clock) {
        let mut still = Vec::new();
        for p in std::mem::take(&mut self.pending) {
            if let Some(dst) = self.forward_of(&p.key) {
                // The key moved while the GET waited: relay it.
                self.stats.gets_forwarded += 1;
            self.metrics.gets_forwarded.inc();
                self.send_to_shard(
                    dst,
                    ToShard::Get {
                        key: p.key,
                        worker: p.worker,
                        min_vclock: p.min_vclock,
                        span: p.span,
                    },
                );
            } else if !self.awaiting_handoff(&p.key) && table_clock >= p.min_vclock {
                // The whole queue wait is the admission segment.
                self.span_record(p.span, "policy_admission", p.queued_us);
                self.reply_row(p.key, p.worker, p.span);
            } else {
                still.push(p);
            }
        }
        self.pending = still;
    }

    /// Clock-gated delta wave (ESSP; called from the policy's commit
    /// hook): push the registered rows *updated since the last wave* to
    /// each registered client, batched per client into one wave message.
    /// Cost is O(dirty rows x interested readers) — the total wave size —
    /// thanks to the inverted index.
    ///
    /// Payload selection is per (key, reader). A reader whose chain token
    /// (`shipped[key]`) is live — its cached copy is exactly the last
    /// shipment — gets the interval's ordered [`WaveLog`] delta sequence
    /// (wire v7): typically a few sparse pairs instead of the full row,
    /// and bit-identical by construction since the client replays the
    /// same fold the store performed. Everyone else gets the `Arc`-shared
    /// snapshot, which is always sound: readers with a broken chain
    /// (first wave, post-pull, re-registered) and this interval's
    /// *writers*, whose local read-my-writes fold already holds their own
    /// contribution — a delta chain would double-count it. Delta payloads
    /// are shared per key (`Arc<[RowDelta]>`), so fan-out to P readers
    /// still performs zero payload deep-copies, and the per-worker
    /// assembly buffers are reused across waves.
    pub fn push_wave(&mut self, vclock: Clock) {
        let workers = self.workers;
        let mut delta_rows: u64 = 0;
        for key in self.dirty.drain() {
            // A migrated-in key whose handoff has not landed holds only a
            // partial fold (eager mode applies post-switch updates onto
            // zeros): defer it to the post-handoff wave rather than
            // pushing partial contents as authoritative. Its WaveLog
            // keeps accumulating meanwhile; chain tokens are untouched,
            // so a multi-interval chain stays consistent.
            if self
                .migration
                .as_ref()
                .is_some_and(|m| m.awaiting.contains(&key))
            {
                self.wave_deferred.push(key);
                continue;
            }
            // Consume the interval's delta log unconditionally (even on
            // the skip paths below) so it never outlives its wave.
            let log = self.wave_log.remove(&key);
            let Some(readers) = self.readers.get(&key) else {
                continue;
            };
            let Some(row) = self.rows.get(&key) else {
                continue;
            };
            let fresh = row.fresh.max(vclock);
            let deltas: Option<(Arc<[RowDelta]>, Vec<WorkerId>)> =
                log.map(|l| (l.deltas.into(), l.writers));
            let tokens = self
                .shipped
                .entry(key)
                .or_insert_with(|| vec![super::types::NEVER; workers]);
            for w in readers.iter() {
                let base = tokens[w];
                tokens[w] = vclock;
                let push = match &deltas {
                    Some((d, writers)) if base != super::types::NEVER && !writers.contains(&w) => {
                        delta_rows += 1;
                        PushRow::deltas(key, base, Arc::clone(d), fresh)
                    }
                    _ => PushRow::snapshot(key, Arc::clone(&row.data), fresh),
                };
                self.wave_scratch[w].push(push);
            }
        }
        for key in self.wave_deferred.drain(..) {
            self.dirty.insert(key);
        }
        self.stats.rows_pushed_delta += delta_rows;
        self.metrics.rows_pushed_delta.add(delta_rows);
        for worker in 0..workers {
            if self.reg_count[worker] == 0 {
                debug_assert!(self.wave_scratch[worker].is_empty());
                continue;
            }
            // Empty waves still announce the new table clock so clients
            // can advance their copies' guarantees without re-pulling.
            // `mem::take` of an empty scratch Vec allocates nothing.
            let rows = std::mem::take(&mut self.wave_scratch[worker]);
            self.stats.rows_pushed += rows.len() as u64;
            self.stats.push_waves += 1;
            self.metrics.rows_pushed.add(rows.len() as u64);
            self.metrics.push_waves.inc();
            self.metrics.wave_fanout.record(rows.len() as u64);
            // Shard-originated span, sampled per emitted frame (not per
            // wave): each frame needs its own trace id, or the arrival
            // marks of a fanned-out wave would collide.
            let span = if self.spans.is_some() {
                self.span_sampler
                    .tick()
                    .map(|seq| SpanCtx::for_shard(self.logical as u32, seq))
            } else {
                None
            };
            self.send_to_worker(
                worker,
                ToWorker::Push {
                    shard: self.logical,
                    vclock,
                    rows,
                    span,
                },
            );
        }
    }

    // ------------------------------------------------- live migration

    /// Arm a migration (see `ps::placement` for the full state machine).
    /// Idempotent for a repeated arm of the same epoch (the multi-process
    /// bootstrap self-arms; an in-process coordinator may arm again).
    fn on_migrate_begin(
        &mut self,
        epoch: u64,
        at_clock: Clock,
        outgoing: Vec<(Key, u32)>,
        incoming: Vec<Key>,
    ) {
        if let Some(m) = &self.migration {
            if m.epoch == epoch {
                return;
            }
            assert!(
                m.handed_off && m.awaiting.is_empty(),
                "shard {}: migration to epoch {epoch} armed while epoch {} \
                 is still in flight",
                self.id,
                m.epoch
            );
        }
        self.trace_event(
            "migrate_begin",
            format!(
                "epoch {epoch} armed: fence at clock {at_clock}, {} outgoing, {} incoming",
                outgoing.len(),
                incoming.len()
            ),
        );
        self.migration = Some(Migration {
            epoch,
            at_clock,
            outgoing: outgoing.into_iter().map(|(k, d)| (k, d as usize)).collect(),
            awaiting: incoming.into_iter().collect(),
            handed_off: false,
            held_min: None,
        });
        // A Begin arriving after the fence already passed (late arm in a
        // non-deterministic run): hand off immediately with whatever the
        // rows hold now — conserving; the clean clock split additionally
        // needs the announce to precede the fence, which the coordinator
        // provides by arming at launch.
        if self.clocks.min() >= at_clock - 1 {
            self.do_handoff();
        }
    }

    /// Source side of the fence: ship every outgoing key's row (the fold
    /// through the fence), its freshness, and its staged tail (deltas
    /// with clock >= at_clock) to the new owner; then turn the key set
    /// into the permanent forward table for late traffic. Called exactly
    /// once, with staged updates below the fence already replayed.
    fn do_handoff(&mut self) {
        let (epoch, outgoing) = match self.migration.as_mut() {
            Some(m) if !m.handed_off => {
                m.handed_off = true;
                (m.epoch, m.outgoing.clone())
            }
            _ => return,
        };
        if outgoing.is_empty() {
            return;
        }
        self.trace_event(
            "migrate_handoff",
            format!("epoch {epoch}: handing off {} keys", outgoing.len()),
        );
        // Extract the staged tails of migrated keys; the destination
        // merges them into its own (clock, worker)-sorted replay, so the
        // global fold order per key is unchanged by the move.
        let mut staged_out: FxHashMap<Key, Vec<(Clock, WorkerId, RowDelta)>> =
            FxHashMap::default();
        for (&(c, w), rows) in self.staged.iter_mut() {
            if rows.iter().all(|(k, _)| !outgoing.contains_key(k)) {
                continue;
            }
            let drained = std::mem::take(rows);
            let mut kept = Vec::with_capacity(drained.len());
            for (k, d) in drained {
                if outgoing.contains_key(&k) {
                    staged_out.entry(k).or_default().push((c, w, d));
                } else {
                    kept.push((k, d));
                }
            }
            *rows = kept;
        }
        // Row positions shifted in the drained batches: rebuild the
        // per-key index once (O(backlog); only ever paid at a handoff).
        self.rebuild_staged_index();
        // Deterministic send order (sorted keys), so two runs emit
        // byte-identical handoff streams.
        let mut ordered: Vec<(Key, usize)> = outgoing.iter().map(|(k, d)| (*k, *d)).collect();
        ordered.sort_unstable();
        let mut dsts: Vec<usize> = ordered.iter().map(|(_, d)| *d).collect();
        dsts.sort_unstable();
        dsts.dedup();
        let vclock = self.visible_clock();
        for (key, dst) in ordered {
            let (exists, data, fresh) = match self.rows.remove(&key) {
                Some(row) => (true, row.data, row.fresh),
                None => (false, Vec::<f32>::new().into(), super::types::NEVER),
            };
            if let Some(readers) = self.readers.remove(&key) {
                // Readers re-register with the new owner at their epoch
                // switch; keep the per-worker counts consistent.
                for w in readers.iter() {
                    self.reg_count[w] -= 1;
                }
            }
            self.dirty.remove(&key);
            // Chain state leaves with the key: the new owner must seed
            // every reader with a snapshot before it can ship deltas, and
            // if the key ever comes home the same applies here.
            self.shipped.remove(&key);
            self.wave_log.remove(&key);
            let staged = staged_out.remove(&key).unwrap_or_default();
            self.stats.rows_migrated_out += 1;
            self.metrics.rows_migrated_out.inc();
            self.forwards.insert(key, dst);
            self.send_to_shard(
                dst,
                ToShard::RowHandoff {
                    epoch,
                    key,
                    vclock,
                    fresh,
                    exists,
                    data,
                    staged,
                },
            );
        }
        for dst in dsts {
            self.send_to_shard(dst, ToShard::MigrateCommit { epoch });
        }
        // GETs queued for migrated keys relay to the new owner (the
        // forward table is live now); others re-evaluate harmlessly.
        let visible = self.visible_clock();
        self.serve_pending(visible);
    }

    /// Destination side: install one migrated key. Returns the released
    /// table clock if this was the last awaited handoff and a commit
    /// advance was withheld (the caller fires the policy's commit hook).
    fn on_row_handoff(
        &mut self,
        epoch: u64,
        key: Key,
        _vclock: Clock,
        fresh: Clock,
        exists: bool,
        data: Arc<[f32]>,
        staged: Vec<(Clock, WorkerId, RowDelta)>,
    ) -> Option<Clock> {
        // Re-replication install (spare under a catch-up gate): the
        // whole shard is "awaiting", so every handoff of the gate's
        // epoch installs directly — no per-key bookkeeping, no forward
        // retirement (nothing ever left this node).
        if self.catchup.as_ref().is_some_and(|cu| cu.epoch == epoch) {
            self.stats.rows_migrated_in += 1;
            self.metrics.rows_migrated_in.inc();
            if exists {
                self.rows.insert(key, Row { data, fresh });
            }
            for (c, w, d) in staged {
                self.stage_rows(c, w, vec![(key, d)]);
            }
            return None;
        }
        let expected = match self.migration.as_mut() {
            Some(m) if m.epoch == epoch => m.awaiting.remove(&key),
            _ => false,
        };
        if !expected {
            eprintln!(
                "shard {}: ignoring unexpected row handoff for {key:?} (epoch {epoch})",
                self.id
            );
            return None;
        }
        // A key that once left this shard has come home: retire the
        // stale forward so reads stop bouncing.
        self.forwards.remove(&key);
        self.stats.rows_migrated_in += 1;
        self.metrics.rows_migrated_in.inc();
        if exists {
            if self.track_dirty {
                // The next clock wave must carry the row to (re-)
                // registered readers here.
                self.dirty.insert(key);
            }
            // Any delta log accumulated while awaiting the handoff
            // described a fold onto zeros, not onto the handed-off base:
            // drop it so the post-handoff wave ships the full row. (No
            // reader can hold a live chain for a key we never waved, so
            // this only forces the snapshot that was due anyway.)
            self.wave_log.remove(&key);
            match self.rows.get_mut(&key) {
                // Eager (non-deterministic) mode may already have applied
                // post-switch updates to this key, materialized from
                // zeros. Updates are additive, so the handed-off base
                // FOLDS in rather than replacing — nothing is lost. In
                // deterministic mode the fence guarantees this arm is
                // never taken (staged updates beyond the fence have not
                // replayed), so the install stays bit-exact.
                Some(row) => {
                    if Arc::get_mut(&mut row.data).is_none() {
                        let detached: Arc<[f32]> = row.data.iter().copied().collect();
                        row.data = detached;
                    }
                    let out = Arc::get_mut(&mut row.data).expect("unique after copy-on-write");
                    for (a, b) in out.iter_mut().zip(data.iter()) {
                        *a += b;
                    }
                    row.fresh = row.fresh.max(fresh);
                }
                None => {
                    self.rows.insert(key, Row { data, fresh });
                }
            }
        }
        for (c, w, d) in staged {
            self.stage_rows(c, w, vec![(key, d)]);
        }
        let release = match self.migration.as_mut() {
            Some(m) if m.awaiting.is_empty() => m.held_min.take(),
            _ => None,
        };
        if release.is_some() {
            self.trace_event(
                "migrate_release",
                format!("epoch {epoch}: last handoff landed, releasing held commit"),
            );
        }
        match release {
            Some(new_min) => self.advance(new_min),
            None => {
                // No withheld commit, but a queued GET for this key may
                // be serveable now.
                let visible = self.visible_clock();
                self.serve_pending(visible);
                None
            }
        }
    }

    /// End-marker after one source's last handoff (FIFO guarantees the
    /// handoffs preceded it). For a plain key migration the gate is
    /// keyed by individual handoffs, so this is informational; for a
    /// re-replication catch-up it is the gate opener — the spare cannot
    /// know the row count up front, so the commit frame (FIFO-ordered
    /// after every RowHandoff of the stream) marks the stream complete.
    /// Returns the released table clock if a commit advance was withheld
    /// behind the gate (the caller fires the policy's commit hook).
    fn on_migrate_commit(&mut self, epoch: u64) -> Option<Clock> {
        let matches = self.catchup.as_ref().is_some_and(|cu| cu.epoch == epoch);
        if !matches {
            return None;
        }
        let cu = self.catchup.take().unwrap();
        self.trace_event(
            "replica_catchup_done",
            format!(
                "epoch {epoch}: caught up through clock {}, gate open",
                cu.at_clock - 1
            ),
        );
        match cu.held_min {
            Some(new_min) => self.advance(new_min),
            None => {
                let visible = self.visible_clock();
                self.serve_pending(visible);
                None
            }
        }
    }

    /// Source side of a re-replication: arm the cut. At the commit of
    /// `at_clock - 1` (possibly right now, if the table clock is already
    /// there) the row fold is copied — not moved — to `target`, followed
    /// by the MigrateCommit end-marker that opens the spare's gate.
    fn on_replica_sync(&mut self, epoch: u64, at_clock: Clock, target: usize) {
        self.trace_event(
            "replica_sync",
            format!("epoch {epoch} armed: copy cut at clock {at_clock} -> node {target}"),
        );
        self.replica_sync = Some((epoch, at_clock, target));
        if self.clocks.min() >= at_clock - 1 {
            self.replay_staged_through(at_clock - 1);
            self.do_replica_sync();
        }
    }

    /// Fire the armed re-replication cut: ship every row (sorted keys,
    /// so two runs emit byte-identical streams) to the target, then the
    /// end-marker. Rows stay; no forwards, no reader churn — the spare
    /// is an addition, not a move.
    fn do_replica_sync(&mut self) {
        let Some((epoch, at_clock, target)) = self.replica_sync.take() else {
            return;
        };
        let mut ordered: Vec<Key> = self.rows.keys().copied().collect();
        ordered.sort_unstable();
        self.trace_event(
            "replica_sync_cut",
            format!(
                "epoch {epoch}: copying {} rows at clock {} -> node {target}",
                ordered.len(),
                at_clock - 1
            ),
        );
        let vclock = self.visible_clock();
        for key in ordered {
            let row = &self.rows[&key];
            self.stats.rows_migrated_out += 1;
            self.metrics.rows_migrated_out.inc();
            self.send_to_shard(
                target,
                ToShard::RowHandoff {
                    epoch,
                    key,
                    vclock,
                    fresh: row.fresh,
                    exists: true,
                    data: Arc::clone(&row.data),
                    staged: vec![],
                },
            );
        }
        self.send_to_shard(target, ToShard::MigrateCommit { epoch });
    }

    /// Destination side of a re-replication: close the whole-shard gate.
    /// Until the source's MigrateCommit opens it, every commit advance
    /// is withheld at `at_clock - 1` — updates duplicated from clients
    /// (all clock >= at_clock) stage behind the fence and must not apply
    /// before the base rows they land on have arrived.
    fn on_replica_catch_up(&mut self, epoch: u64, at_clock: Clock, source: usize) {
        self.trace_event(
            "replica_catchup",
            format!("epoch {epoch}: gate closed, awaiting cut from node {source} at clock {at_clock}"),
        );
        self.catchup = Some(CatchUp {
            epoch,
            at_clock,
            held_min: None,
        });
    }

    fn rebuild_staged_index(&mut self) {
        self.staged_index.clear();
        for (&(c, w), rows) in self.staged.iter() {
            for (i, (key, _)) in rows.iter().enumerate() {
                self.staged_index
                    .entry(*key)
                    .or_default()
                    .push((c, w, i as u32));
            }
        }
    }
}

/// Final shard state returned to the harness at shutdown.
pub struct ShardFinal {
    pub id: usize,
    pub rows: FxHashMap<Key, Row>,
    pub stats: ShardStats,
    /// Flattened end-of-run metrics snapshot (`telemetry::registry`
    /// entry convention) — the harness folds these into `RunReport`
    /// (queue high-water marks, WAL latency quantiles).
    pub metrics: Vec<(String, u64)>,
}

/// Spawn a shard thread. Returns its join handle.
pub fn spawn(shard: Shard, inbox: Receiver<ToShard>, dump: Sender<ShardFinal>) -> JoinHandle<()> {
    let id = shard.core.id;
    std::thread::Builder::new()
        .name(format!("shard-{id}"))
        .spawn(move || {
            crate::sim::priority::infrastructure_thread();
            shard.run(inbox, dump)
        })
        .expect("spawn shard thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::net::{NetConfig, SimNet};
    use std::sync::mpsc::channel;
    use std::time::Duration;

    /// Fixture with an instant network and one inbox per worker.
    fn fixture_n(
        workers: usize,
        consistency: Consistency,
        row_len: HashMap<TableId, usize>,
    ) -> (Shard, Vec<std::sync::mpsc::Receiver<ToWorker>>, SimNet) {
        let mut wtxs = Vec::new();
        let mut wrxs = Vec::new();
        for _ in 0..workers {
            let (wtx, wrx) = channel();
            wtxs.push(wtx);
            wrxs.push(wrx);
        }
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), wtxs, vec![stx]);
        let shard = Shard::new(
            0,
            workers,
            consistency,
            TransportHandle::new(net.handle()),
            row_len,
            false,
        );
        (shard, wrxs, net)
    }

    /// Single-worker fixture (the common case in these tests). `push`
    /// selects the clock-wave policy (ESSP) vs pull-only (SSP).
    fn fixture(workers: usize, push: bool) -> (Shard, std::sync::mpsc::Receiver<ToWorker>, SimNet)
    {
        let consistency = if push {
            Consistency::Essp { s: 1 }
        } else {
            Consistency::Ssp { s: 1 }
        };
        let (shard, mut wrxs, net) = fixture_n(workers, consistency, HashMap::new());
        (shard, wrxs.remove(0), net)
    }

    #[test]
    fn get_after_init_replies_immediately() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 2.0]);
        // min_vclock NEVER-ish: satisfied at table clock -1.
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: -1,
            span: None,
        });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(&data[..], &[1.0, 2.0]);
                assert_eq!(vclock, -1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn get_of_unmaterialized_row_serves_zeros() {
        // A GET can race ahead of any update/init materializing the row
        // (regression: this used to panic the shard thread). The reply
        // must be zeros of the table's registered row length, fresh NEVER.
        let mut row_len = HashMap::new();
        row_len.insert(0u32, 3usize);
        let (mut shard, wrxs, _net) = fixture_n(1, Consistency::Ssp { s: 1 }, row_len);
        shard.handle(ToShard::Get {
            key: (0, 99),
            worker: 0,
            min_vclock: -1,
            span: None,
        });
        match wrxs[0].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, fresh, .. } => {
                assert_eq!(&data[..], &[0.0, 0.0, 0.0]);
                assert_eq!(fresh, -1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The shard must not have materialized the row server-side.
        assert!(shard.row(&(0, 99)).is_none());
        // A later update to that row starts from zeros, consistently.
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 99), vec![1.0, 2.0, 3.0].into())],
            span: None,
        });
        assert_eq!(&shard.row(&(0, 99)).unwrap().data[..], &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "unknown row length")]
    fn get_of_unknown_table_still_panics() {
        // No row and no row-length registry entry: nothing sane to serve.
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.handle(ToShard::Get {
            key: (7, 0),
            worker: 0,
            min_vclock: -1,
            span: None,
        });
    }

    #[test]
    fn get_blocks_until_clock_advances() {
        let (mut shard, wrx, _net) = fixture(2, false);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 0,
            min_vclock: 0,
            span: None,
        });
        assert!(wrx.try_recv().is_err(), "must queue until table clock 0");
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err(), "worker 1 has not committed");
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { vclock, .. } => assert_eq!(vclock, 0),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn updates_are_additive_and_bump_fresh() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 1.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![0.5, -1.0].into())],
            span: None,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![0.5, 0.0].into())],
            span: None,
        });
        let row = shard.row(&(0, 1)).unwrap();
        assert_eq!(&row.data[..], &[2.0, 0.0]);
        assert_eq!(row.fresh, 1);
    }

    #[test]
    fn sparse_updates_apply_without_densifying() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![1.0, 2.0, 3.0, 4.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), RowDelta::sparse(4, vec![(1, 0.5), (3, -4.0)]))],
            span: None,
        });
        let row = shard.row(&(0, 1)).unwrap();
        assert_eq!(&row.data[..], &[1.0, 2.5, 3.0, 0.0]);
        assert_eq!(row.fresh, 0);
        // A sparse update may also materialize a missing row (from zeros).
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 9), RowDelta::sparse(3, vec![(2, 7.0)]))],
            span: None,
        });
        assert_eq!(&shard.row(&(0, 9)).unwrap().data[..], &[0.0, 0.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "claims width")]
    fn materializing_update_with_lying_width_is_rejected() {
        // A decoded update may claim any row width (a sparse row's `len`
        // is a claim, not bytes on the wire): materializing a missing row
        // must validate the claim against the table registry rather than
        // zero-fill whatever the frame asked for.
        let mut row_len = HashMap::new();
        row_len.insert(0u32, 3usize);
        let (mut shard, _wrxs, _net) = fixture_n(1, Consistency::Ssp { s: 1 }, row_len);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 42), RowDelta::sparse(1 << 20, vec![]))],
            span: None,
        });
    }

    #[test]
    fn staged_sparse_sums_stay_sparse_below_threshold() {
        // Deterministic mode: two workers stage sparse parts for the same
        // wide row; the preview sum must accumulate as pairs (no
        // densification below the threshold) and the commit must apply
        // the same values.
        let (mut shard, _wrx, _net) = det_shard(2, true);
        shard.init_row((0, 0), vec![0.0; 1024]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), RowDelta::sparse(1024, vec![(3, 1.0), (900, 2.0)]))],
            span: None,
        });
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 0,
            rows: vec![((0, 0), RowDelta::sparse(1024, vec![(3, 0.5), (17, -1.0)]))],
            span: None,
        });
        let sums = shard.core().staged_sums(&[(0, 0)]);
        let sum = &sums[&(0, 0)];
        assert!(sum.is_sparse(), "below-threshold staged sum densified");
        assert_eq!(sum.nnz(), 3);
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        let row = &shard.row(&(0, 0)).unwrap().data;
        assert_eq!((row[3], row[17], row[900]), (1.5, -1.0, 2.0));
        assert_eq!(row.iter().filter(|x| **x != 0.0).count(), 3);
    }

    #[test]
    fn essp_pushes_updated_registered_rows_on_advance() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        shard.init_row((0, 2), vec![8.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Register { key: (0, 2), worker: 0 });
        // Only row (0,1) is updated: the wave must carry exactly it
        // (delta pushes — unchanged rows are certified by omission).
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 0);
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].key, (0, 1));
                assert_eq!(&rows[0].snapshot_data()[..], &[8.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().push_waves, 1);
        // Next advance with no updates: empty wave still announces vclock.
        shard.handle(ToShard::ClockTick { worker: 0, clock: 1 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 1);
                assert!(rows.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn push_wave_payloads_are_shared_not_copied() {
        // A wave addressed to P readers must carry the *same* allocation
        // the shard stores — Arc clones, zero payload deep-copies.
        let p = 3;
        let (mut shard, wrxs, _net) =
            fixture_n(p, Consistency::Essp { s: 1 }, HashMap::new());
        shard.init_row((0, 1), vec![0.0, 0.0]);
        for w in 0..p {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0, 2.0].into())],
            span: None,
        });
        for w in 0..p {
            shard.handle(ToShard::ClockTick { worker: w, clock: 0 });
        }
        let stored = Arc::clone(&shard.row(&(0, 1)).unwrap().data);
        let mut received = Vec::new();
        for wrx in &wrxs {
            match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
                ToWorker::Push { rows, .. } => {
                    assert_eq!(rows.len(), 1);
                    received.push(Arc::clone(rows[0].snapshot_data()));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        for arc in &received {
            assert!(
                Arc::ptr_eq(arc, &stored),
                "push wave deep-copied the payload"
            );
        }
        // Refcount: shard's copy + our `stored` + P in-wave clones.
        assert_eq!(Arc::strong_count(&stored), 2 + p);
    }

    #[test]
    fn update_after_push_copies_on_write() {
        // While a pushed snapshot is still referenced (in flight / cached
        // by a reader), applying an update must detach, not mutate it.
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        let pushed = match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { mut rows, .. } => Arc::clone(rows.remove(0).snapshot_data()),
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(&pushed[..], &[1.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        // The held snapshot is unchanged; the stored row advanced.
        assert_eq!(&pushed[..], &[1.0]);
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[2.0]);
        assert!(!Arc::ptr_eq(&pushed, &shard.row(&(0, 1)).unwrap().data));
    }

    #[test]
    fn second_wave_ships_delta_chain_to_pure_readers() {
        use super::super::msg::PushPayload;
        let (mut shard, wrxs, _net) = fixture_n(2, Consistency::Essp { s: 1 }, HashMap::new());
        shard.init_row((0, 1), vec![0.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        for w in 0..2 {
            shard.handle(ToShard::ClockTick { worker: w, clock: 0 });
        }
        // First wave: no reader holds a certified copy — snapshots seed
        // the chains.
        for wrx in &wrxs {
            match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
                ToWorker::Push { rows, .. } => {
                    assert_eq!(&rows[0].snapshot_data()[..], &[1.0]);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(shard.stats().rows_pushed_delta, 0);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), RowDelta::sparse(1, vec![(0, 2.0)]))],
            span: None,
        });
        for w in 0..2 {
            shard.handle(ToShard::ClockTick { worker: w, clock: 1 });
        }
        // Second wave: the writer re-seeds with a snapshot (its local
        // read-my-writes fold already holds the +2); the pure reader gets
        // the interval's delta chain based on the seeding wave's vclock.
        match wrxs[0].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => {
                assert_eq!(&rows[0].snapshot_data()[..], &[3.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        match wrxs[1].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { vclock, rows, .. } => {
                assert_eq!(vclock, 1);
                match &rows[0].payload {
                    PushPayload::Deltas { base, deltas } => {
                        assert_eq!(*base, 0, "base names the wave that seeded the chain");
                        assert_eq!(deltas.len(), 1);
                        let mut v = [1.0f32];
                        deltas[0].add_into(&mut v);
                        assert_eq!(v, [3.0], "replaying the chain lands on the store's bits");
                    }
                    other => panic!("expected a delta chain, got {other:?}"),
                }
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().rows_pushed_delta, 1);
    }

    #[test]
    fn pull_and_reregistration_break_the_chain() {
        use super::super::msg::PushPayload;
        let (mut shard, wrxs, _net) = fixture_n(2, Consistency::Essp { s: 1 }, HashMap::new());
        shard.init_row((0, 1), vec![0.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        let wave = |shard: &mut Shard, clock: Clock| {
            shard.handle(ToShard::Update {
                worker: 0,
                clock,
                rows: vec![((0, 1), vec![1.0].into())],
                span: None,
            });
            for w in 0..2 {
                shard.handle(ToShard::ClockTick { worker: w, clock });
            }
        };
        wave(&mut shard, 0);
        for wrx in &wrxs {
            let _ = wrx.recv_timeout(Duration::from_secs(1)).unwrap();
        }
        // Worker 1 re-pulls the row: its cached copy now came from the
        // reply, not the wave, so the chain must re-seed.
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 1,
            min_vclock: -1,
            span: None,
        });
        match wrxs[1].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        wave(&mut shard, 1);
        match wrxs[1].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => {
                assert!(
                    !rows[0].payload.is_deltas(),
                    "post-pull wave must re-seed with a snapshot"
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        // With the chain re-seeded, the next interval ships deltas again.
        wave(&mut shard, 2);
        match wrxs[1].recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => match &rows[0].payload {
                PushPayload::Deltas { base, .. } => assert_eq!(*base, 1),
                other => panic!("expected a delta chain, got {other:?}"),
            },
            other => panic!("unexpected {other:?}"),
        }
    }

    /// Acceptance: an LDA-shaped ESSP wave (K=1024 topic rows, sparse
    /// updates with nnz<=8, several pure readers) must ship at least 8x
    /// fewer framed bytes via delta chains than forced full snapshots.
    /// Both arms run the identical message sequence; the only difference
    /// is [`Shard::force_snapshot_waves`] on the control shard.
    #[test]
    fn lda_shaped_delta_wave_ships_8x_fewer_framed_bytes() {
        use crate::transport::Packet;
        const K: usize = 1024;
        const WORKERS: usize = 5; // one writer + four pure readers
        let run = |force_snapshots: bool| -> usize {
            let row_len: HashMap<TableId, usize> = std::iter::once((0, K)).collect();
            let (mut shard, wrxs, _net) = fixture_n(WORKERS, Consistency::Essp { s: 1 }, row_len);
            if force_snapshots {
                shard.force_snapshot_waves();
            }
            shard.init_row((0, 1), vec![0.0; K]);
            for w in 0..WORKERS {
                shard.handle(ToShard::Register { key: (0, 1), worker: w });
            }
            let sparse = || RowDelta::sparse(K, (0..8u32).map(|i| (i * 100, 0.5)).collect());
            let wave = |shard: &mut Shard, clock: Clock| {
                shard.handle(ToShard::Update {
                    worker: 0,
                    clock,
                    rows: vec![((0, 1), sparse())],
                    span: None,
                });
                for w in 0..WORKERS {
                    shard.handle(ToShard::ClockTick { worker: w, clock });
                }
            };
            // Wave 1 seeds every chain with a snapshot in both arms.
            wave(&mut shard, 0);
            for wrx in &wrxs {
                let _ = wrx.recv_timeout(Duration::from_secs(1)).unwrap();
            }
            // Wave 2 is the measured steady-state wave. The writer always
            // re-seeds (read-my-writes), so only the pure readers count.
            wave(&mut shard, 1);
            let mut bytes = 0;
            for wrx in wrxs.iter().skip(1) {
                let msg = wrx.recv_timeout(Duration::from_secs(1)).unwrap();
                assert!(matches!(msg, ToWorker::Push { .. }), "unexpected {msg:?}");
                bytes += Packet::ToWorker(msg).wire_bytes();
            }
            bytes
        };
        let delta = run(false);
        let snapshot = run(true);
        assert!(
            snapshot >= 8 * delta,
            "delta waves must ship >=8x fewer framed bytes: snapshot={snapshot} delta={delta}"
        );
    }

    #[test]
    fn ssp_mode_never_pushes() {
        let (mut shard, wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![7.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err());
    }

    #[test]
    fn duplicate_registration_is_idempotent() {
        let (mut shard, wrx, _net) = fixture(1, true);
        shard.init_row((0, 1), vec![7.0]);
        for _ in 0..3 {
            shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        }
        assert_eq!(
            shard.core().reg_count[0],
            1,
            "re-registration must not recount"
        );
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { rows, .. } => assert_eq!(rows.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn reader_set_bitset_semantics() {
        let mut s = ReaderSet::for_workers(130);
        assert!(s.insert(0));
        assert!(s.insert(64));
        assert!(s.insert(129));
        assert!(!s.insert(64), "second insert reports already-present");
        assert!(s.contains(129) && !s.contains(1));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 64, 129]);
    }

    fn det_shard(
        workers: usize,
        deterministic: bool,
    ) -> (Shard, std::sync::mpsc::Receiver<ToWorker>, SimNet) {
        let (wtx, wrx) = channel();
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![wtx], vec![stx]);
        let shard = Shard::new(
            0,
            workers,
            Consistency::Ssp { s: 1 },
            TransportHandle::new(net.handle()),
            HashMap::new(),
            deterministic,
        );
        (shard, wrx, net)
    }

    #[test]
    fn deterministic_mode_applies_updates_in_worker_order() {
        // f32 addition is not associative: starting from 1e8, applying
        // +1.0 then -1e8 gives 0.0 (the +1 is absorbed), while -1e8 then
        // +1.0 gives 1.0. Deterministic mode must replay sorted by
        // (clock, worker) — yielding 0.0 — even when worker 1's update
        // arrives first.
        let mk = |deterministic: bool| {
            let (mut shard, _wrx, net) = det_shard(2, deterministic);
            shard.init_row((0, 0), vec![1e8]);
            shard.handle(ToShard::Update {
                worker: 1,
                clock: 0,
                rows: vec![((0, 0), vec![-1e8].into())],
                span: None,
            });
            shard.handle(ToShard::Update {
                worker: 0,
                clock: 0,
                rows: vec![((0, 0), vec![1.0].into())],
                span: None,
            });
            shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
            shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
            let v = shard.row(&(0, 0)).unwrap().data[0];
            drop(shard);
            net.shutdown();
            v
        };
        assert_eq!(mk(true), 0.0, "sorted replay: worker 0's +1 absorbed");
        assert_eq!(mk(false), 1.0, "eager application keeps arrival order");
    }

    #[test]
    fn deterministic_mode_defers_until_commit() {
        let (mut shard, wrx, _net) = det_shard(2, true);
        shard.init_row((0, 0), vec![0.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), vec![5.0].into())],
            span: None,
        });
        // Not applied yet: worker 1 has not committed clock 0.
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 0.0);
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 0.0);
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        assert_eq!(shard.row(&(0, 0)).unwrap().data[0], 5.0);
        // A GET served after the commit sees the applied value.
        shard.handle(ToShard::Get {
            key: (0, 0),
            worker: 0,
            min_vclock: 0,
            span: None,
        });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(&data[..], &[5.0]);
                assert_eq!(vclock, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    /// Fixture with one worker inbox and TWO shard inboxes: the shard
    /// under test is id 0; the second sink captures shard->shard
    /// migration traffic addressed to shard 1.
    fn mig_fixture(
        workers: usize,
        deterministic: bool,
    ) -> (
        Shard,
        std::sync::mpsc::Receiver<ToWorker>,
        std::sync::mpsc::Receiver<ToShard>,
        SimNet,
    ) {
        let (wtx, wrx) = channel();
        let (stx0, _srx0) = channel();
        let (stx1, srx1) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![wtx], vec![stx0, stx1]);
        let shard = Shard::new(
            0,
            workers,
            Consistency::Ssp { s: 1 },
            TransportHandle::new(net.handle()),
            HashMap::new(),
            deterministic,
        );
        (shard, wrx, srx1, net)
    }

    #[test]
    fn migration_source_hands_off_row_and_staged_tail_then_forwards() {
        let (mut shard, _wrx, srx1, _net) = mig_fixture(2, true);
        shard.init_row((0, 7), vec![1.0]);
        shard.init_row((0, 8), vec![5.0]);
        // Arm: key (0,7) leaves for shard 1 at clock 2.
        shard.handle(ToShard::MigrateBegin {
            epoch: 1,
            at_clock: 2,
            outgoing: vec![((0, 7), 1)],
            incoming: vec![],
        });
        // Pre-fence updates (clocks 0 and 1) for the migrating key...
        for c in 0..2 {
            shard.handle(ToShard::Update {
                worker: 0,
                clock: c,
                rows: vec![((0, 7), vec![1.0].into())],
                span: None,
            });
        }
        // ...plus a post-fence update from a client that has not switched
        // epochs yet: it must travel as the handoff's staged tail.
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 2,
            rows: vec![((0, 7), vec![100.0].into())],
            span: None,
        });
        for w in 0..2 {
            shard.handle(ToShard::ClockTick { worker: w, clock: 1 });
        }
        match srx1.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToShard::RowHandoff {
                epoch,
                key,
                vclock,
                exists,
                data,
                staged,
                ..
            } => {
                assert_eq!(epoch, 1);
                assert_eq!(key, (0, 7));
                assert_eq!(vclock, 1, "handoff must carry the fence fold's clock");
                assert!(exists);
                assert_eq!(&data[..], &[3.0], "fold through the fence: 1 + 1 + 1");
                assert_eq!(staged.len(), 1);
                assert_eq!((staged[0].0, staged[0].1), (2, 0));
            }
            other => panic!("unexpected {other:?}"),
        }
        match srx1.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToShard::MigrateCommit { epoch } => assert_eq!(epoch, 1),
            other => panic!("unexpected {other:?}"),
        }
        // The migrated row is gone, the kept row intact.
        assert!(shard.row(&(0, 7)).is_none());
        assert_eq!(&shard.row(&(0, 8)).unwrap().data[..], &[5.0]);
        assert_eq!(shard.stats().rows_migrated_out, 1);
        // Late traffic relays through the forward table.
        shard.handle(ToShard::Get {
            key: (0, 7),
            worker: 0,
            min_vclock: -1,
            span: None,
        });
        match srx1.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToShard::Get { key, worker, .. } => {
                assert_eq!(key, (0, 7));
                assert_eq!(worker, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 2,
            rows: vec![
                ((0, 7), vec![7.0].into()),
                ((0, 8), vec![1.0].into()),
            ],
            span: None,
        });
        match srx1.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToShard::Update { worker, clock, rows, .. } => {
                assert_eq!((worker, clock), (1, 2));
                assert_eq!(rows.len(), 1, "only the migrated key is relayed");
                assert_eq!(rows[0].0, (0, 7));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().gets_forwarded, 1);
        assert_eq!(shard.stats().updates_forwarded, 1);
    }

    #[test]
    fn migration_destination_fences_until_handoff_then_releases() {
        let (mut shard, wrx, _srx1, _net) = mig_fixture(2, true);
        shard.handle(ToShard::MigrateBegin {
            epoch: 1,
            at_clock: 2,
            outgoing: vec![],
            incoming: vec![(0, 7)],
        });
        // Post-switch updates from both workers for the incoming key.
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 2,
            rows: vec![((0, 7), vec![10.0].into())],
            span: None,
        });
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 2,
            rows: vec![((0, 7), vec![1.0].into())],
            span: None,
        });
        // Every worker commits clock 2 — but the advance must be
        // withheld: the base row has not arrived.
        for w in 0..2 {
            shard.handle(ToShard::ClockTick { worker: w, clock: 2 });
        }
        assert!(
            shard.row(&(0, 7)).is_none(),
            "staged clock-2 updates applied before the base row arrived"
        );
        // A read for the in-flight key queues regardless of its floor.
        shard.handle(ToShard::Get {
            key: (0, 7),
            worker: 0,
            min_vclock: -1,
            span: None,
        });
        assert!(wrx.try_recv().is_err(), "GET served before the handoff");
        // The handoff lands: base row installs, the staged tail replays
        // on top in sorted order, the held commit releases, the queued
        // GET serves at the released clock.
        shard.handle(ToShard::RowHandoff {
            epoch: 1,
            key: (0, 7),
            vclock: 1,
            fresh: 1,
            exists: true,
            data: vec![5.0].into(),
            staged: vec![],
        });
        assert_eq!(&shard.row(&(0, 7)).unwrap().data[..], &[16.0]);
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Row { data, vclock, .. } => {
                assert_eq!(&data[..], &[16.0]);
                assert_eq!(vclock, 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(shard.stats().rows_migrated_in, 1);
    }

    #[test]
    fn staggered_staged_sums_cost_does_not_rescan_backlog() {
        // Straggler shape: worker 0 never commits while worker 1 races
        // ahead, growing the staged backlog to hundreds of batches (30k
        // rows). Deterministic VAP/AVAP waves preview touched keys via
        // staged_sums on EVERY inbound update; the old implementation
        // rescanned the whole backlog per preview (quadratic under a
        // straggler — this loop took minutes in a debug build), the
        // per-key generation index makes it O(straggle depth).
        let (mut shard, _wrx, _net) = det_shard(2, true);
        let hot: Key = (0, 0);
        shard.init_row(hot, vec![0.0]);
        let batches: usize = 300;
        let wide: usize = 100;
        for c in 0..batches as Clock {
            let mut rows: Vec<(Key, RowDelta)> = vec![(hot, vec![1.0].into())];
            for r in 0..wide as u64 {
                rows.push((
                    (1, c as u64 * wide as u64 + r),
                    RowDelta::sparse(16, vec![(3, 1.0)]),
                ));
            }
            shard.handle(ToShard::Update {
                worker: 1,
                clock: c,
                rows,
                span: None,
            });
        }
        let t0 = std::time::Instant::now();
        let mut last = 0.0f32;
        for _ in 0..2000 {
            let sums = shard.core().staged_sums(&[hot]);
            last = match &sums[&hot] {
                RowDelta::Dense(v) => v[0],
                other => panic!("dense accumulation expected, got {other:?}"),
            };
        }
        assert_eq!(last, batches as f32, "preview lost staged mass");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "staged preview is rescanning the backlog: {:?}",
            t0.elapsed()
        );
        // Replay drains the index with nothing lost (no float
        // subtraction anywhere: the commit applies the original deltas).
        shard.handle(ToShard::ClockTick {
            worker: 0,
            clock: batches as Clock - 1,
        });
        shard.handle(ToShard::ClockTick {
            worker: 1,
            clock: batches as Clock - 1,
        });
        assert_eq!(shard.row(&hot).unwrap().data[0], batches as f32);
    }

    #[test]
    fn shutdown_returns_final_state() {
        let (mut shard, _wrx, _net) = fixture(1, false);
        shard.init_row((0, 1), vec![3.0]);
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        assert!(!shard.handle(ToShard::Shutdown));
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[4.0]);
    }

    fn dur_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("esspt-shard-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crash_recovery_is_bit_identical_mid_run() {
        // Non-associative sum (see deterministic_mode_applies_updates_in_
        // worker_order): any deviation in recovery's fold order would
        // change the bits, so equality here is a real replay check.
        let dir = dur_dir("crash");
        let (mut shard, _wrx, _net) = det_shard(2, true);
        shard.init_row((0, 0), vec![1e8]);
        let recovered = shard.enable_durability(DurabilityConfig::new(&dir)).unwrap();
        assert!(!recovered, "fresh directory must not claim prior state");
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 0,
            rows: vec![((0, 0), vec![-1e8].into())],
            span: None,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), vec![1.0].into())],
            span: None,
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        // A staged tail beyond the table clock must survive the crash too.
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 0), vec![2.5].into())],
            span: None,
        });
        let before = shard.row(&(0, 0)).unwrap().data.to_vec();
        assert_eq!(before, vec![0.0], "sorted replay absorbs worker 0's +1");
        shard.crash_and_recover().unwrap();
        assert_eq!(shard.row(&(0, 0)).unwrap().data.to_vec(), before);
        assert_eq!(shard.row(&(0, 0)).unwrap().fresh, 0);
        assert_eq!(shard.table_clock(), 0);
        shard.handle(ToShard::ClockTick { worker: 0, clock: 1 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 1 });
        assert_eq!(shard.row(&(0, 0)).unwrap().data.to_vec(), vec![2.5]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn compaction_rolls_generations_and_purges_old_pairs() {
        let dir = dur_dir("compact");
        let (mut shard, _wrx, _net) = det_shard(1, true);
        shard.init_row((0, 0), vec![0.0]);
        let mut cfg = DurabilityConfig::new(&dir);
        cfg.compact_every = 2;
        shard.enable_durability(cfg).unwrap();
        assert_eq!(durability::latest_generation(&dir, 0), Some(0));
        for c in 0..4 {
            shard.handle(ToShard::Update {
                worker: 0,
                clock: c,
                rows: vec![((0, 0), vec![1.0].into())],
                span: None,
            });
            shard.handle(ToShard::ClockTick { worker: 0, clock: c });
        }
        // Two compactions (one per two commits); only the newest pair may
        // remain on disk.
        assert_eq!(durability::latest_generation(&dir, 0), Some(2));
        assert!(!durability::ckpt_path(&dir, 0, 0).exists());
        assert!(!durability::wal_path(&dir, 0, 1).exists());
        let before = shard.row(&(0, 0)).unwrap().data.to_vec();
        shard.crash_and_recover().unwrap();
        assert_eq!(shard.row(&(0, 0)).unwrap().data.to_vec(), before);
        assert_eq!(durability::latest_generation(&dir, 0), Some(3));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn promotion_swaps_logical_identity_and_policy() {
        // Replica node 1 of logical shard 0 under ESSP: pull-only until
        // the Promote lands, then full clock waves stamped with the dead
        // primary's logical id.
        let (wtx, wrx) = channel();
        let (stx0, _srx0) = channel();
        let (stx1, _srx1) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![wtx], vec![stx0, stx1]);
        let mut shard = Shard::replica(
            1,
            1,
            Consistency::Essp { s: 1 },
            TransportHandle::new(net.handle()),
            HashMap::new(),
            false,
        );
        shard.init_row((0, 1), vec![7.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 0 });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        assert!(wrx.try_recv().is_err(), "replicas never push");
        let delta = PlacementDelta {
            epoch: 9,
            at_clock: 1,
            grow_active: None,
            promote: Some((0, 1)),
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        shard.handle(ToShard::Promote {
            delta: delta.clone(),
        });
        // The promotion relays the placement delta to every worker...
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Placement { delta: d } => assert_eq!(d, delta),
            other => panic!("unexpected {other:?}"),
        }
        // ...and the next commit fires a full wave re-certifying ALL rows,
        // carrying the logical shard id so clients fold it into the right
        // partition's guarantees.
        shard.handle(ToShard::ClockTick { worker: 0, clock: 1 });
        match wrx.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToWorker::Push { shard: s, vclock, rows, .. } => {
                assert_eq!(s, 0, "wave must carry the logical shard id");
                assert_eq!(vclock, 1);
                assert_eq!(rows.len(), 1);
                assert_eq!(&rows[0].snapshot_data()[..], &[8.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
