//! Durability plane: crash-atomic checkpoints ([`checkpoint`]), the
//! per-shard write-ahead delta log ([`wal`]), and the generation-paired
//! file layout that binds the two.
//!
//! ## File layout
//!
//! A durable shard owns one *generation* `g` of paired files inside the
//! configured directory:
//!
//! ```text
//! shard-<id>.gen<g>.ckpt   row snapshot taken at a commit boundary
//! shard-<id>.gen<g>.wal    wire-encoded ToShard frames appended since
//! ```
//!
//! Compaction at a commit boundary writes generation `g+1` (checkpoint
//! first, then a seed WAL carrying the not-yet-committed staged tail),
//! each file crash-atomically, and only then deletes generation `g` — so
//! a crash at any instant leaves at least one complete pair on disk.
//! Recovery loads the highest generation for which BOTH files exist and
//! replays the WAL through the shard's normal deterministic
//! (clock, worker)-sorted staged replay, which makes the recovered state
//! bit-identical to the uncrashed run (see `ps::server`, *Durability &
//! Failover*).

pub mod checkpoint;
pub mod wal;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// When the write-ahead log calls `fsync`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Sync after every appended frame. Maximum durability, maximum
    /// latency — an OS crash loses nothing that `append` returned for.
    Always,
    /// Sync once per committed table clock (the default). The durable
    /// prefix always ends at a commit boundary, so recovery never sees a
    /// half-committed clock; an OS crash can lose at most the clock in
    /// progress.
    Commit,
    /// Never sync; the OS page cache decides. Survives process crashes
    /// (the kernel still holds the writes) but not power loss — the
    /// honest baseline for WAL-overhead benchmarks.
    Off,
}

impl FsyncPolicy {
    /// Parse a `--fsync` flag value: `always` | `commit` | `off`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "always" => Ok(Self::Always),
            "commit" => Ok(Self::Commit),
            "off" => Ok(Self::Off),
            other => Err(format!(
                "unknown fsync policy {other:?} (expected always|commit|off)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Always => "always",
            Self::Commit => "commit",
            Self::Off => "off",
        }
    }
}

/// Per-shard durability configuration (the `--wal` / `--fsync` /
/// `--wal-compact-every` flags).
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Directory holding the generation-paired files of every shard.
    pub dir: PathBuf,
    /// When WAL appends become durable.
    pub fsync: FsyncPolicy,
    /// Compact the log into a fresh checkpoint every this many table-clock
    /// commits; `0` disables periodic compaction (the log only truncates
    /// on shutdown).
    pub compact_every: u64,
}

impl DurabilityConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            fsync: FsyncPolicy::Commit,
            compact_every: 64,
        }
    }
}

/// Checkpoint path of `shard`'s generation `generation`.
pub fn ckpt_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("shard-{shard}.gen{generation}.ckpt"))
}

/// WAL path of `shard`'s generation `generation`.
pub fn wal_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("shard-{shard}.gen{generation}.wal"))
}

/// Highest generation for which BOTH the checkpoint and the WAL exist —
/// the one recovery must load. An orphan half (a compaction that crashed
/// between its two writes) is ignored; `None` means no durable state.
pub fn latest_generation(dir: &Path, shard: usize) -> Option<u64> {
    let (ckpts, wals) = scan_generations(dir, shard)?;
    ckpts.into_iter().filter(|g| wals.contains(g)).max()
}

/// Best-effort removal of every generation of `shard`'s files strictly
/// below `keep` (called after a compaction has produced generation
/// `keep`). Leftovers are harmless — recovery always picks the highest
/// complete pair — so deletion errors are ignored.
pub fn purge_generations_below(dir: &Path, shard: usize, keep: u64) {
    let Some((ckpts, wals)) = scan_generations(dir, shard) else {
        return;
    };
    for g in ckpts.into_iter().filter(|&g| g < keep) {
        let _ = std::fs::remove_file(ckpt_path(dir, shard, g));
    }
    for g in wals.into_iter().filter(|&g| g < keep) {
        let _ = std::fs::remove_file(wal_path(dir, shard, g));
    }
}

/// All generation numbers present for `shard`, split by file kind.
fn scan_generations(dir: &Path, shard: usize) -> Option<(Vec<u64>, Vec<u64>)> {
    let prefix = format!("shard-{shard}.gen");
    let mut ckpts = Vec::new();
    let mut wals = Vec::new();
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(&prefix) else {
            continue;
        };
        if let Some(g) = rest.strip_suffix(".ckpt").and_then(|s| s.parse().ok()) {
            ckpts.push(g);
        } else if let Some(g) = rest.strip_suffix(".wal").and_then(|s| s.parse().ok()) {
            wals.push(g);
        }
    }
    Some((ckpts, wals))
}

/// Crash-atomic file replacement: stream into `<path>.tmp`, flush and
/// fsync it, rename over `path`, then fsync the parent directory so the
/// rename itself survives power loss. If the write closure (or any I/O
/// step before the rename) fails, the temp file is removed and the
/// previous contents of `path`, if any, are untouched — a reader never
/// observes a torn file under this helper.
pub fn write_atomic(
    path: &Path,
    write: impl FnOnce(&mut BufWriter<File>) -> Result<()>,
) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    if let Some(d) = dir {
        std::fs::create_dir_all(d).with_context(|| format!("create dir {d:?}"))?;
    }
    let name = path
        .file_name()
        .with_context(|| format!("atomic write target {path:?} has no file name"))?;
    let tmp = path.with_file_name(format!("{}.tmp", name.to_string_lossy()));
    let written = (|| -> Result<()> {
        let file = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = BufWriter::new(file);
        write(&mut w)?;
        w.flush().with_context(|| format!("flush {tmp:?}"))?;
        w.get_ref()
            .sync_all()
            .with_context(|| format!("fsync {tmp:?}"))?;
        Ok(())
    })();
    if let Err(e) = written {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    if let Some(d) = dir {
        // Directory fsync makes the rename durable; best-effort on
        // filesystems that refuse to open directories.
        if let Ok(f) = File::open(d) {
            let _ = f.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("esspt-dur-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn fsync_policy_parses_and_labels() {
        for s in ["always", "commit", "off"] {
            assert_eq!(FsyncPolicy::parse(s).unwrap().label(), s);
        }
        assert!(FsyncPolicy::parse("sometimes").unwrap_err().contains("sometimes"));
    }

    #[test]
    fn latest_generation_requires_a_complete_pair() {
        let dir = tmp_dir("gens");
        assert_eq!(latest_generation(&dir, 0), None);
        std::fs::write(ckpt_path(&dir, 0, 1), b"x").unwrap();
        std::fs::write(wal_path(&dir, 0, 1), b"x").unwrap();
        std::fs::write(ckpt_path(&dir, 0, 2), b"x").unwrap();
        std::fs::write(wal_path(&dir, 0, 2), b"x").unwrap();
        // Generation 3's compaction "crashed" between its two writes.
        std::fs::write(ckpt_path(&dir, 0, 3), b"x").unwrap();
        // Another shard's files must not leak into shard 0's scan.
        std::fs::write(ckpt_path(&dir, 1, 9), b"x").unwrap();
        std::fs::write(wal_path(&dir, 1, 9), b"x").unwrap();
        assert_eq!(latest_generation(&dir, 0), Some(2));
        assert_eq!(latest_generation(&dir, 1), Some(9));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn purge_keeps_the_named_generation() {
        let dir = tmp_dir("purge");
        for g in 1..=3 {
            std::fs::write(ckpt_path(&dir, 0, g), b"x").unwrap();
            std::fs::write(wal_path(&dir, 0, g), b"x").unwrap();
        }
        purge_generations_below(&dir, 0, 3);
        assert_eq!(latest_generation(&dir, 0), Some(3));
        assert!(!ckpt_path(&dir, 0, 1).exists());
        assert!(!wal_path(&dir, 0, 2).exists());
        assert!(ckpt_path(&dir, 0, 3).exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn failed_atomic_write_leaves_original_intact() {
        let dir = tmp_dir("atomic");
        let path = dir.join("state.bin");
        write_atomic(&path, |w| {
            w.write_all(b"good state")?;
            Ok(())
        })
        .unwrap();
        let err = write_atomic(&path, |w| {
            w.write_all(b"half-writ")?;
            bail!("disk exploded mid-write");
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("disk exploded"));
        assert_eq!(std::fs::read(&path).unwrap(), b"good state");
        // The torn temp file must not linger.
        assert!(!dir.join("state.bin.tmp").exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
