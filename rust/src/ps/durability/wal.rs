//! Per-shard write-ahead delta log.
//!
//! The on-disk format is a 22-byte header followed by a stream of
//! wire-encoded [`ToShard`] frames — the *same* `transport::wire` codec
//! that frames the TCP stream. There is exactly one row encoding in the
//! system, and the log reader inherits the codec's defensive decoding
//! (bounded lengths, strictly validated sparse pairs) for free. Frames
//! are written with `src = Coordinator, dst = Shard(logical)` as a fixed
//! convention; the addressing bytes are part of the frame layout but are
//! not consulted on replay.
//!
//! The log is append-only. [`FsyncPolicy`] decides when appends become
//! durable: per frame (`always`), per committed table clock (`commit`),
//! or never (`off`). Reading comes in two flavors: [`replay`] is lenient
//! — a torn tail, the expected artifact of a crash mid-append, truncates
//! the log at the last whole frame and reports the dropped byte count —
//! while [`replay_strict`] treats any trailing garbage as an error.
//! Neither allocates from an attacker-controlled length: a frame whose
//! declared length overruns the file is rejected *before* any buffer is
//! sized to it.

use std::fs::File;
use std::io::{BufWriter, Cursor, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::ps::msg::ToShard;
use crate::transport::{wire, NodeId, Packet};

use super::FsyncPolicy;

/// Magic prefix of every shard WAL.
pub const WAL_MAGIC: &[u8; 8] = b"ESSPWAL1";
/// On-disk format version (frames inside follow `wire::VERSION`).
pub const WAL_VERSION: u16 = 1;
/// Header layout: magic (8) | version u16 | shard u32 | generation u64.
pub const WAL_HEADER_LEN: usize = 8 + 2 + 4 + 8;

/// Decoded WAL header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalHeader {
    pub version: u16,
    pub shard: u32,
    pub generation: u64,
}

/// Append side of one shard's log for one generation.
pub struct WalWriter {
    w: BufWriter<File>,
    path: PathBuf,
    shard: usize,
    fsync: FsyncPolicy,
    fsync_stall: Option<Duration>,
    frames: u64,
}

impl WalWriter {
    /// Create (truncating) the generation file and write its header. The
    /// header is synced immediately under `always`/`commit` so recovery
    /// can never find a zero-byte latest generation.
    pub fn create(
        path: &Path,
        shard: usize,
        generation: u64,
        fsync: FsyncPolicy,
    ) -> Result<Self> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).with_context(|| format!("create dir {dir:?}"))?;
        }
        let file = File::create(path).with_context(|| format!("create WAL {path:?}"))?;
        let mut w = BufWriter::new(file);
        w.write_all(WAL_MAGIC)?;
        w.write_all(&WAL_VERSION.to_le_bytes())?;
        w.write_all(&(shard as u32).to_le_bytes())?;
        w.write_all(&generation.to_le_bytes())?;
        let mut this = Self {
            w,
            path: path.to_path_buf(),
            shard,
            fsync,
            fsync_stall: None,
            frames: 0,
        };
        if this.fsync != FsyncPolicy::Off {
            this.sync()?;
        }
        Ok(this)
    }

    /// Install a fault-injected fsync stall (a slow disk): every
    /// subsequent sync sleeps this long before the real fsync.
    pub fn set_fsync_stall(&mut self, stall: Option<Duration>) {
        self.fsync_stall = stall;
    }

    /// Frames appended so far (excluding the header).
    pub fn frames(&self) -> u64 {
        self.frames
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one message. Durable immediately under
    /// [`FsyncPolicy::Always`], at the next [`Self::commit`] under
    /// `Commit`, whenever the OS flushes under `Off`.
    pub fn append(&mut self, m: &ToShard) -> Result<()> {
        wire::write_to_shard_frame(
            &mut self.w,
            NodeId::Coordinator,
            NodeId::Shard(self.shard),
            m,
        )
        .with_context(|| format!("append frame {} to {:?}", self.frames, self.path))?;
        self.frames += 1;
        if self.fsync == FsyncPolicy::Always {
            self.sync()?;
        }
        Ok(())
    }

    /// Commit boundary (a table-clock advance): make the appended prefix
    /// durable per policy.
    pub fn commit(&mut self) -> Result<()> {
        match self.fsync {
            FsyncPolicy::Always => Ok(()), // every append already synced
            FsyncPolicy::Commit => self.sync(),
            FsyncPolicy::Off => self.flush(),
        }
    }

    fn flush(&mut self) -> Result<()> {
        self.w.flush().with_context(|| format!("flush {:?}", self.path))
    }

    fn sync(&mut self) -> Result<()> {
        self.flush()?;
        if let Some(stall) = self.fsync_stall {
            std::thread::sleep(stall);
        }
        self.w
            .get_ref()
            .sync_data()
            .with_context(|| format!("fsync {:?}", self.path))
    }
}

/// Result of reading a log back.
#[derive(Debug)]
pub struct WalReplay {
    pub header: WalHeader,
    /// Whole frames, in append order.
    pub records: Vec<ToShard>,
    /// Bytes discarded from a torn tail (lenient mode only; 0 = the log
    /// ended cleanly at a frame boundary).
    pub dropped_bytes: u64,
}

/// Lenient read: decode whole frames until the first torn/corrupt one,
/// report the dropped tail. This is the recovery path — a crash
/// mid-append legitimately leaves a partial final frame.
pub fn replay(path: &Path) -> Result<WalReplay> {
    replay_impl(path, false)
}

/// Strict read: any undecodable tail is an error naming the offending
/// frame. For integrity checks and tests.
pub fn replay_strict(path: &Path) -> Result<WalReplay> {
    replay_impl(path, true)
}

fn replay_impl(path: &Path, strict: bool) -> Result<WalReplay> {
    let bytes = std::fs::read(path).with_context(|| format!("read WAL {path:?}"))?;
    let header = decode_header(&bytes).with_context(|| format!("{path:?}: bad WAL header"))?;
    let mut cur = Cursor::new(&bytes[..]);
    cur.set_position(WAL_HEADER_LEN as u64);
    let mut records = Vec::new();
    let mut scratch = Vec::new();
    loop {
        let pos = cur.position() as usize;
        // Reject a declared frame length that overruns the file BEFORE
        // wire::read_frame sizes a buffer to it: a corrupt length field
        // must cost an error, not a giant allocation.
        let rem = &bytes[pos..];
        let overrun = rem.len() >= 4 && {
            let len = u32::from_le_bytes(rem[..4].try_into().unwrap()) as usize;
            len > rem.len() - 4
        };
        let err = if overrun {
            let len = u32::from_le_bytes(rem[..4].try_into().unwrap());
            anyhow!(
                "frame {}: declared length {len} overruns the log ({} bytes remain)",
                records.len(),
                rem.len() - 4
            )
        } else {
            match wire::read_frame(&mut cur, &mut scratch) {
                Ok(None) => {
                    return Ok(WalReplay {
                        header,
                        records,
                        dropped_bytes: 0,
                    })
                }
                Ok(Some((_, _, Packet::ToShard(m)))) => {
                    records.push(m);
                    continue;
                }
                Ok(Some((_, _, Packet::ToWorker(_)))) => anyhow!(
                    "frame {}: a ToWorker frame has no business in a shard WAL",
                    records.len()
                ),
                Err(e) => e,
            }
        };
        let dropped = (bytes.len() - pos) as u64;
        if strict {
            return Err(err.context(format!(
                "{path:?}: corrupt tail after {} whole frames ({dropped} trailing bytes)",
                records.len()
            )));
        }
        return Ok(WalReplay {
            header,
            records,
            dropped_bytes: dropped,
        });
    }
}

fn decode_header(bytes: &[u8]) -> Result<WalHeader> {
    ensure!(
        bytes.len() >= WAL_HEADER_LEN,
        "truncated before header end ({} of {WAL_HEADER_LEN} bytes)",
        bytes.len()
    );
    if &bytes[..8] != WAL_MAGIC {
        bail!("bad magic (not an ESSPTable WAL)");
    }
    let version = u16::from_le_bytes(bytes[8..10].try_into().unwrap());
    ensure!(
        version == WAL_VERSION,
        "unsupported WAL version {version} (this binary speaks {WAL_VERSION})"
    );
    Ok(WalHeader {
        version,
        shard: u32::from_le_bytes(bytes[10..14].try_into().unwrap()),
        generation: u64::from_le_bytes(bytes[14..22].try_into().unwrap()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::placement::PlacementDelta;
    use crate::ps::types::RowDelta;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("esspt-wal-{}-{name}", std::process::id()))
    }

    fn sample_records() -> Vec<ToShard> {
        vec![
            ToShard::Update {
                worker: 1,
                clock: 4,
                rows: vec![
                    ((0, 7), vec![1.0f32, -2.5, 3.25].into()),
                    ((0, 9), RowDelta::sparse(1024, vec![(3, 1.0), (900, -2.25)])),
                ],
                span: None,
            },
            ToShard::ClockTick { worker: 1, clock: 4 },
            ToShard::MigrateCommit { epoch: 2 },
            ToShard::Promote {
                delta: PlacementDelta {
                    epoch: 3,
                    at_clock: 5,
                    grow_active: None,
                    promote: Some((1, 4)),
                    attach: None,
                    dead: vec![1],
                    moves: vec![],
                },
            },
        ]
    }

    fn write_log(path: &Path, fsync: FsyncPolicy) -> Vec<ToShard> {
        let records = sample_records();
        let mut w = WalWriter::create(path, 1, 7, fsync).unwrap();
        for m in &records {
            w.append(m).unwrap();
        }
        w.commit().unwrap();
        records
    }

    #[test]
    fn roundtrips_every_frame_kind() {
        let path = tmp("roundtrip.wal");
        let records = write_log(&path, FsyncPolicy::Commit);
        for read in [replay(&path).unwrap(), replay_strict(&path).unwrap()] {
            assert_eq!(
                read.header,
                WalHeader {
                    version: WAL_VERSION,
                    shard: 1,
                    generation: 7
                }
            );
            assert_eq!(read.records, records);
            assert_eq!(read.dropped_bytes, 0);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fsync_off_still_readable_after_commit_flush() {
        let path = tmp("off.wal");
        let records = write_log(&path, FsyncPolicy::Off);
        assert_eq!(replay(&path).unwrap().records, records);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_log_replays_to_zero_records() {
        let path = tmp("empty.wal");
        let mut w = WalWriter::create(&path, 3, 0, FsyncPolicy::Always).unwrap();
        w.commit().unwrap();
        assert_eq!(w.frames(), 0);
        drop(w);
        let read = replay_strict(&path).unwrap();
        assert!(read.records.is_empty());
        assert_eq!(read.header.shard, 3);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn torn_tail_truncates_leniently_and_fails_strictly() {
        let path = tmp("torn.wal");
        let records = write_log(&path, FsyncPolicy::Commit);
        let full = std::fs::read(&path).unwrap();
        // Chop into the final frame: a crash mid-append.
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let read = replay(&path).unwrap();
        assert_eq!(read.records, records[..records.len() - 1]);
        assert!(read.dropped_bytes > 0);
        let err = format!("{:#}", replay_strict(&path).unwrap_err());
        assert!(err.contains("corrupt tail after 3 whole frames"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_length_field_is_rejected_without_allocating() {
        // Header + a 4-byte prefix claiming a near-MAX_FRAME body that the
        // file does not hold: the reader must refuse before sizing any
        // buffer to the lie.
        let path = tmp("hugelen.wal");
        {
            let w = WalWriter::create(&path, 0, 1, FsyncPolicy::Off);
            drop(w.unwrap());
        }
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&0x0FFF_FFF0u32.to_le_bytes());
        bytes.extend_from_slice(b"stub");
        std::fs::write(&path, &bytes).unwrap();
        let read = replay(&path).unwrap();
        assert!(read.records.is_empty());
        assert_eq!(read.dropped_bytes, 8);
        let err = format!("{:#}", replay_strict(&path).unwrap_err());
        assert!(err.contains("overruns the log"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn header_corruption_is_an_error_in_both_modes() {
        let path = tmp("hdr.wal");
        write_log(&path, FsyncPolicy::Off);
        let good = std::fs::read(&path).unwrap();

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        std::fs::write(&path, &bad_magic).unwrap();
        let err = format!("{:#}", replay(&path).unwrap_err());
        assert!(err.contains("bad magic"), "{err}");

        let mut bad_version = good.clone();
        bad_version[8] = 99;
        std::fs::write(&path, &bad_version).unwrap();
        let err = format!("{:#}", replay(&path).unwrap_err());
        assert!(err.contains("unsupported WAL version 99"), "{err}");

        std::fs::write(&path, &good[..10]).unwrap();
        let err = format!("{:#}", replay_strict(&path).unwrap_err());
        assert!(err.contains("truncated before header end"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mid_log_bitflip_keeps_the_prefix() {
        let path = tmp("flip.wal");
        let records = write_log(&path, FsyncPolicy::Commit);
        let mut bytes = std::fs::read(&path).unwrap();
        // Corrupt the SECOND frame's length prefix (first frame starts at
        // the header end; its length prefix tells us where frame 2 begins).
        let f1_len =
            u32::from_le_bytes(bytes[WAL_HEADER_LEN..WAL_HEADER_LEN + 4].try_into().unwrap());
        let f2_at = WAL_HEADER_LEN + 4 + f1_len as usize;
        bytes[f2_at + 3] = 0xFF; // declared length now > MAX_FRAME
        std::fs::write(&path, &bytes).unwrap();
        let read = replay(&path).unwrap();
        assert_eq!(read.records, records[..1]);
        assert!(read.dropped_bytes > 0);
        assert!(replay_strict(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
