//! Table checkpointing: snapshot/restore the PS state to/from disk.
//!
//! A production PS needs durable state (the paper's related-work section
//! concedes fault tolerance to Hadoop/Spark; a real release closes that
//! gap). Two formats share one loader:
//!
//! * **v1** (`ESSPCKP1`): per row, key (table u32, row u64), length u32,
//!   f32 payload — the final-dump format `main.rs` merges.
//! * **v2** (`ESSPCKP2`): v1 plus a per-row `fresh` clock (best-effort
//!   freshness) between the key and the length — the compaction snapshot
//!   the WAL recovery path loads, so a recovered shard answers freshness
//!   queries identically to the uncrashed one.
//!
//! All fields little-endian, written via buffered I/O. Every save is
//! crash-atomic ([`super::write_atomic`]): a reader can observe the old
//! checkpoint or the new one, never a torn hybrid. Snapshots are taken
//! from a `RunReport`'s final tables or injected into a `TableSpec`
//! initializer to resume a run.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ps::server::TableSpec;
use crate::ps::types::{Clock, Key, RowId, TableId, NEVER};

const MAGIC: &[u8; 8] = b"ESSPCKP1";
const MAGIC2: &[u8; 8] = b"ESSPCKP2";

/// Write a v1 checkpoint of `rows` to `path`, crash-atomically.
pub fn save(path: &Path, rows: &HashMap<Key, Vec<f32>>) -> Result<()> {
    // Sort keys for deterministic output (useful for diffing checkpoints).
    let mut keys: Vec<&Key> = rows.keys().collect();
    keys.sort();
    super::write_atomic(path, |w| {
        w.write_all(MAGIC)?;
        w.write_all(&(rows.len() as u64).to_le_bytes())?;
        for key in keys {
            let data = &rows[key];
            w.write_all(&key.0.to_le_bytes())?;
            w.write_all(&key.1.to_le_bytes())?;
            w.write_all(&(data.len() as u32).to_le_bytes())?;
            for x in data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    })
}

/// Write a v2 checkpoint (rows with their `fresh` clocks), crash-atomically
/// and in deterministic key order.
pub fn save_v2(path: &Path, rows: &[(Key, Vec<f32>, Clock)]) -> Result<()> {
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by_key(|&i| rows[i].0);
    super::write_atomic(path, |w| {
        w.write_all(MAGIC2)?;
        w.write_all(&(rows.len() as u64).to_le_bytes())?;
        for &i in &order {
            let (key, data, fresh) = &rows[i];
            w.write_all(&key.0.to_le_bytes())?;
            w.write_all(&key.1.to_le_bytes())?;
            w.write_all(&fresh.to_le_bytes())?;
            w.write_all(&(data.len() as u32).to_le_bytes())?;
            for x in data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    })
}

/// Read a checkpoint back, dropping freshness (v1 or v2 on disk).
pub fn load(path: &Path) -> Result<HashMap<Key, Vec<f32>>> {
    Ok(load_rows(path)?
        .into_iter()
        .map(|(key, data, _)| (key, data))
        .collect())
}

/// Read a checkpoint back with per-row `fresh` clocks. A v1 file loads
/// with `fresh = NEVER` for every row.
pub fn load_v2(path: &Path) -> Result<Vec<(Key, Vec<f32>, Clock)>> {
    load_rows(path)
}

/// Shared loader, hardened against corrupt/truncated files: the declared
/// row count and every per-row payload length are validated against the
/// file's actual size *before* any allocation, so a bad header yields a
/// context-rich error instead of a multi-GB preallocation attempt.
fn load_rows(path: &Path) -> Result<Vec<(Key, Vec<f32>, Clock)>> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = file
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated before magic"))?;
    let v2 = &magic == MAGIC2;
    if !v2 && &magic != MAGIC {
        bail!("{path:?} is not an ESSPTable checkpoint (bad magic)");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let n = u64::from_le_bytes(buf8);
    // Minimum bytes per row: key (u32 + u64) + length u32, plus the fresh
    // clock (i64) in v2. A count the file cannot possibly hold is a
    // corrupt header.
    let min_row = if v2 { 24 } else { 16 };
    let body_len = file_len.saturating_sub(16);
    if n > body_len / min_row {
        bail!(
            "{path:?}: header claims {n} rows but only {body_len} bytes of row data \
             follow — corrupt or truncated checkpoint"
        );
    }
    let mut rows = Vec::with_capacity(n as usize);
    let mut buf4 = [0u8; 4];
    let mut payload = Vec::new();
    for i in 0..n {
        let row_ctx = |what: &str| format!("{path:?}: row {i}/{n}: truncated {what}");
        r.read_exact(&mut buf4).with_context(|| row_ctx("table id"))?;
        let table = TableId::from_le_bytes(buf4);
        r.read_exact(&mut buf8).with_context(|| row_ctx("row id"))?;
        let row = RowId::from_le_bytes(buf8);
        let fresh = if v2 {
            r.read_exact(&mut buf8).with_context(|| row_ctx("fresh clock"))?;
            Clock::from_le_bytes(buf8)
        } else {
            NEVER
        };
        r.read_exact(&mut buf4).with_context(|| row_ctx("length"))?;
        let len = u32::from_le_bytes(buf4) as usize;
        if len as u64 * 4 > body_len {
            bail!(
                "{path:?}: row {i} (table {table}, row {row}) claims a {len}-element \
                 payload, larger than the whole file — corrupt length field"
            );
        }
        payload.clear();
        payload.resize(len * 4, 0u8);
        r.read_exact(&mut payload).with_context(|| {
            format!("{path:?}: row {i} (table {table}, row {row}): truncated payload")
        })?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        rows.push(((table, row), data, fresh));
    }
    Ok(rows)
}

/// Build a `TableSpec` that initializes table `table` from a checkpoint
/// (rows missing from the checkpoint fall back to zeros of `row_len`).
pub fn table_from_checkpoint(
    table: TableId,
    rows: RowId,
    row_len: usize,
    snapshot: HashMap<Key, Vec<f32>>,
) -> TableSpec {
    TableSpec {
        table,
        rows,
        row_len,
        init: Box::new(move |r, _| {
            snapshot
                .get(&(table, r))
                .cloned()
                .unwrap_or_else(|| vec![0.0; row_len])
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::client::PsClient;
    use crate::ps::consistency::Consistency;
    use crate::ps::server::{Cluster, ClusterConfig, PsApp};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("esspt-ckp-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rows = HashMap::new();
        rows.insert((0u32, 7u64), vec![1.0f32, -2.5, 3.25]);
        rows.insert((1, 0), vec![0.0; 5]);
        rows.insert((1, 9), vec![f32::MIN_POSITIVE, f32::MAX]);
        let path = tmp("roundtrip.bin");
        save(&path, &rows).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(rows, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_roundtrips_fresh_clocks() {
        let rows = vec![
            ((0u32, 7u64), vec![1.0f32, -2.5], 42i64),
            ((0, 2), vec![0.5; 4], NEVER),
            ((3, 0), vec![], 0),
        ];
        let path = tmp("v2rt.bin");
        save_v2(&path, &rows).unwrap();
        let mut back = load_v2(&path).unwrap();
        back.sort_by_key(|r| r.0);
        let mut want = rows.clone();
        want.sort_by_key(|r| r.0);
        assert_eq!(back, want);
        // The clock-less loader reads the same file.
        let flat = load(&path).unwrap();
        assert_eq!(flat[&(0, 7)], vec![1.0, -2.5]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_loads_through_v2_with_never_freshness() {
        let mut rows = HashMap::new();
        rows.insert((0u32, 1u64), vec![2.0f32]);
        let path = tmp("v1v2.bin");
        save(&path, &rows).unwrap();
        let back = load_v2(&path).unwrap();
        assert_eq!(back, vec![((0, 1), vec![2.0], NEVER)]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_is_crash_atomic_over_a_previous_checkpoint() {
        // A leftover temp file from a save that "crashed" before its
        // rename must be invisible: the target file still loads the old
        // state, and the next successful save simply replaces the temp.
        let mut old = HashMap::new();
        old.insert((0u32, 0u64), vec![1.0f32]);
        let path = tmp("atomic.bin");
        save(&path, &old).unwrap();
        let tmp_path = path.with_file_name(format!(
            "{}.tmp",
            path.file_name().unwrap().to_string_lossy()
        ));
        std::fs::write(&tmp_path, b"torn half-written junk").unwrap();
        assert_eq!(load(&path).unwrap(), old);
        let mut new = HashMap::new();
        new.insert((0u32, 0u64), vec![2.0f32]);
        save(&path, &new).unwrap();
        assert_eq!(load(&path).unwrap(), new);
        assert!(!tmp_path.exists());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_absurd_row_count_without_allocating() {
        // Valid magic, then a row count the 0-byte body cannot hold: must
        // fail fast on the header check (a naive with_capacity here would
        // try to reserve for u64::MAX entries). Same check for v2.
        for magic in [MAGIC, MAGIC2] {
            let path = tmp("hugecount.bin");
            let mut bytes = Vec::new();
            bytes.extend_from_slice(magic);
            bytes.extend_from_slice(&u64::MAX.to_le_bytes());
            std::fs::write(&path, &bytes).unwrap();
            let err = format!("{:#}", load(&path).unwrap_err());
            assert!(err.contains("corrupt or truncated"), "{err}");
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn rejects_lying_payload_length() {
        // One row whose length field claims far more f32s than the file
        // holds: must fail on the bounds check, naming the row.
        let path = tmp("hugelen.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // table
        bytes.extend_from_slice(&9u64.to_le_bytes()); // row
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // payload length lie
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("corrupt length field"), "{err}");
        assert!(err.contains("table 3"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_payload_errors_with_row_context() {
        // A checkpoint cut off mid-payload: the error must say which row.
        let mut rows = HashMap::new();
        rows.insert((0u32, 0u64), vec![1.0f32; 8]);
        let path = tmp("truncpay.bin");
        save(&path, &rows).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("truncated payload"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn resume_continues_training() {
        // Run 5 clocks, checkpoint, resume in a fresh cluster for 5 more:
        // final counter must equal a straight 10-clock run.
        let run = |spec: TableSpec, clocks: u64| {
            let mut cluster = Cluster::new(ClusterConfig {
                workers: 2,
                shards: 2,
                consistency: Consistency::Bsp,
                ..Default::default()
            });
            cluster.add_table(spec);
            let apps: Vec<Box<dyn PsApp>> = (0..2)
                .map(|_| {
                    Box::new(|ps: &mut PsClient, _c: Clock| {
                        let _ = ps.get((0, 0));
                        ps.inc((0, 0), &[1.0]);
                        None
                    }) as Box<dyn PsApp>
                })
                .collect();
            cluster.run(apps, clocks)
        };
        let first = run(crate::ps::server::TableSpec::zeros(0, 2, 1), 5);
        let path = tmp("resume.bin");
        save(&path, &first.table_rows).unwrap();
        let snapshot = load(&path).unwrap();
        let second = run(table_from_checkpoint(0, 2, 1, snapshot), 5);
        assert_eq!(second.table_rows[&(0, 0)][0], 20.0); // 2 workers x 10 clocks
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn deterministic_bytes() {
        let mut rows = HashMap::new();
        for i in 0..20u64 {
            rows.insert((0u32, i), vec![i as f32; 3]);
        }
        let (p1, p2) = (tmp("det1.bin"), tmp("det2.bin"));
        save(&p1, &rows).unwrap();
        save(&p2, &rows).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();

        let rows2 = vec![((0u32, 1u64), vec![1.0f32], 5i64), ((0, 0), vec![2.0], 3)];
        let (p3, p4) = (tmp("det3.bin"), tmp("det4.bin"));
        save_v2(&p3, &rows2).unwrap();
        let mut reversed = rows2.clone();
        reversed.reverse();
        save_v2(&p4, &reversed).unwrap();
        assert_eq!(std::fs::read(&p3).unwrap(), std::fs::read(&p4).unwrap());
        std::fs::remove_file(p3).ok();
        std::fs::remove_file(p4).ok();
    }
}
