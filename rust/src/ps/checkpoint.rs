//! Table checkpointing: snapshot/restore the PS state to/from disk.
//!
//! A production PS needs durable state (the paper's related-work section
//! concedes fault tolerance to Hadoop/Spark; a real release closes that
//! gap). Format: a small header, then per row: key (table u32, row u64),
//! length u32, f32 payload — all little-endian, written via buffered I/O.
//! Snapshots are taken from a `RunReport`'s final tables or injected into
//! a `TableSpec` initializer to resume a run.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::server::TableSpec;
use super::types::{Key, RowId, TableId};

const MAGIC: &[u8; 8] = b"ESSPCKP1";

/// Write a checkpoint of `rows` to `path`.
pub fn save(path: &Path, rows: &HashMap<Key, Vec<f32>>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&(rows.len() as u64).to_le_bytes())?;
    // Sort keys for deterministic output (useful for diffing checkpoints).
    let mut keys: Vec<&Key> = rows.keys().collect();
    keys.sort();
    for key in keys {
        let data = &rows[key];
        w.write_all(&key.0.to_le_bytes())?;
        w.write_all(&key.1.to_le_bytes())?;
        w.write_all(&(data.len() as u32).to_le_bytes())?;
        for x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a checkpoint back.
///
/// Hardened against corrupt/truncated files: the declared row count and
/// every per-row payload length are validated against the file's actual
/// size *before* any allocation, so a bad header yields a context-rich
/// error instead of a multi-GB preallocation attempt.
pub fn load(path: &Path) -> Result<HashMap<Key, Vec<f32>>> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = file
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated before magic"))?;
    if &magic != MAGIC {
        bail!("{path:?} is not an ESSPTable checkpoint (bad magic)");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let n = u64::from_le_bytes(buf8);
    // Each row takes at least 16 bytes (table u32 + row u64 + length u32):
    // a count the file cannot possibly hold is a corrupt header.
    let body_len = file_len.saturating_sub(16);
    if n > body_len / 16 {
        bail!(
            "{path:?}: header claims {n} rows but only {body_len} bytes of row data \
             follow — corrupt or truncated checkpoint"
        );
    }
    let mut rows = HashMap::with_capacity(n as usize);
    let mut buf4 = [0u8; 4];
    let mut payload = Vec::new();
    for i in 0..n {
        let row_ctx = |what: &str| format!("{path:?}: row {i}/{n}: truncated {what}");
        r.read_exact(&mut buf4).with_context(|| row_ctx("table id"))?;
        let table = TableId::from_le_bytes(buf4);
        r.read_exact(&mut buf8).with_context(|| row_ctx("row id"))?;
        let row = RowId::from_le_bytes(buf8);
        r.read_exact(&mut buf4).with_context(|| row_ctx("length"))?;
        let len = u32::from_le_bytes(buf4) as usize;
        if len as u64 * 4 > body_len {
            bail!(
                "{path:?}: row {i} (table {table}, row {row}) claims a {len}-element \
                 payload, larger than the whole file — corrupt length field"
            );
        }
        payload.clear();
        payload.resize(len * 4, 0u8);
        r.read_exact(&mut payload).with_context(|| {
            format!("{path:?}: row {i} (table {table}, row {row}): truncated payload")
        })?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        rows.insert((table, row), data);
    }
    Ok(rows)
}

/// Build a `TableSpec` that initializes table `table` from a checkpoint
/// (rows missing from the checkpoint fall back to zeros of `row_len`).
pub fn table_from_checkpoint(
    table: TableId,
    rows: RowId,
    row_len: usize,
    snapshot: HashMap<Key, Vec<f32>>,
) -> TableSpec {
    TableSpec {
        table,
        rows,
        row_len,
        init: Box::new(move |r, _| {
            snapshot
                .get(&(table, r))
                .cloned()
                .unwrap_or_else(|| vec![0.0; row_len])
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::client::PsClient;
    use crate::ps::consistency::Consistency;
    use crate::ps::server::{Cluster, ClusterConfig, PsApp};
    use crate::ps::types::Clock;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("esspt-ckp-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rows = HashMap::new();
        rows.insert((0u32, 7u64), vec![1.0f32, -2.5, 3.25]);
        rows.insert((1, 0), vec![0.0; 5]);
        rows.insert((1, 9), vec![f32::MIN_POSITIVE, f32::MAX]);
        let path = tmp("roundtrip.bin");
        save(&path, &rows).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(rows, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_absurd_row_count_without_allocating() {
        // Valid magic, then a row count the 0-byte body cannot hold: must
        // fail fast on the header check (a naive with_capacity here would
        // try to reserve for u64::MAX entries).
        let path = tmp("hugecount.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("corrupt or truncated"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_lying_payload_length() {
        // One row whose length field claims far more f32s than the file
        // holds: must fail on the bounds check, naming the row.
        let path = tmp("hugelen.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // table
        bytes.extend_from_slice(&9u64.to_le_bytes()); // row
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // payload length lie
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("corrupt length field"), "{err}");
        assert!(err.contains("table 3"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_payload_errors_with_row_context() {
        // A checkpoint cut off mid-payload: the error must say which row.
        let mut rows = HashMap::new();
        rows.insert((0u32, 0u64), vec![1.0f32; 8]);
        let path = tmp("truncpay.bin");
        save(&path, &rows).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("truncated payload"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn resume_continues_training() {
        // Run 5 clocks, checkpoint, resume in a fresh cluster for 5 more:
        // final counter must equal a straight 10-clock run.
        let run = |spec: TableSpec, clocks: u64| {
            let mut cluster = Cluster::new(ClusterConfig {
                workers: 2,
                shards: 2,
                consistency: Consistency::Bsp,
                ..Default::default()
            });
            cluster.add_table(spec);
            let apps: Vec<Box<dyn PsApp>> = (0..2)
                .map(|_| {
                    Box::new(|ps: &mut PsClient, _c: Clock| {
                        let _ = ps.get((0, 0));
                        ps.inc((0, 0), &[1.0]);
                        None
                    }) as Box<dyn PsApp>
                })
                .collect();
            cluster.run(apps, clocks)
        };
        let first = run(crate::ps::server::TableSpec::zeros(0, 2, 1), 5);
        let path = tmp("resume.bin");
        save(&path, &first.table_rows).unwrap();
        let snapshot = load(&path).unwrap();
        let second = run(table_from_checkpoint(0, 2, 1, snapshot), 5);
        assert_eq!(second.table_rows[&(0, 0)][0], 20.0); // 2 workers x 10 clocks
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn deterministic_bytes() {
        let mut rows = HashMap::new();
        for i in 0..20u64 {
            rows.insert((0u32, i), vec![i as f32; 3]);
        }
        let (p1, p2) = (tmp("det1.bin"), tmp("det2.bin"));
        save(&p1, &rows).unwrap();
        save(&p2, &rows).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }
}
