//! Table checkpointing: snapshot/restore the PS state to/from disk.
//!
//! A production PS needs durable state (the paper's related-work section
//! concedes fault tolerance to Hadoop/Spark; a real release closes that
//! gap). Format: a small header, then per row: key (table u32, row u64),
//! length u32, f32 payload — all little-endian, written via buffered I/O.
//! Snapshots are taken from a `RunReport`'s final tables or injected into
//! a `TableSpec` initializer to resume a run.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::server::TableSpec;
use super::types::{Key, RowId, TableId};

const MAGIC: &[u8; 8] = b"ESSPCKP1";

/// Write a checkpoint of `rows` to `path`.
pub fn save(path: &Path, rows: &HashMap<Key, Vec<f32>>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&(rows.len() as u64).to_le_bytes())?;
    // Sort keys for deterministic output (useful for diffing checkpoints).
    let mut keys: Vec<&Key> = rows.keys().collect();
    keys.sort();
    for key in keys {
        let data = &rows[key];
        w.write_all(&key.0.to_le_bytes())?;
        w.write_all(&key.1.to_le_bytes())?;
        w.write_all(&(data.len() as u32).to_le_bytes())?;
        for x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a checkpoint back.
pub fn load(path: &Path) -> Result<HashMap<Key, Vec<f32>>> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not an ESSPTable checkpoint (bad magic)");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8);
    let mut rows = HashMap::with_capacity(n as usize);
    let mut buf4 = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut buf4)?;
        let table = TableId::from_le_bytes(buf4);
        r.read_exact(&mut buf8)?;
        let row = RowId::from_le_bytes(buf8);
        r.read_exact(&mut buf4)?;
        let len = u32::from_le_bytes(buf4) as usize;
        let mut data = vec![0f32; len];
        for x in &mut data {
            r.read_exact(&mut buf4)?;
            *x = f32::from_le_bytes(buf4);
        }
        rows.insert((table, row), data);
    }
    Ok(rows)
}

/// Build a `TableSpec` that initializes table `table` from a checkpoint
/// (rows missing from the checkpoint fall back to zeros of `row_len`).
pub fn table_from_checkpoint(
    table: TableId,
    rows: RowId,
    row_len: usize,
    snapshot: HashMap<Key, Vec<f32>>,
) -> TableSpec {
    TableSpec {
        table,
        rows,
        row_len,
        init: Box::new(move |r, _| {
            snapshot
                .get(&(table, r))
                .cloned()
                .unwrap_or_else(|| vec![0.0; row_len])
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::client::PsClient;
    use crate::ps::consistency::Consistency;
    use crate::ps::server::{Cluster, ClusterConfig, PsApp};
    use crate::ps::types::Clock;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("esspt-ckp-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rows = HashMap::new();
        rows.insert((0u32, 7u64), vec![1.0f32, -2.5, 3.25]);
        rows.insert((1, 0), vec![0.0; 5]);
        rows.insert((1, 9), vec![f32::MIN_POSITIVE, f32::MAX]);
        let path = tmp("roundtrip.bin");
        save(&path, &rows).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(rows, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn resume_continues_training() {
        // Run 5 clocks, checkpoint, resume in a fresh cluster for 5 more:
        // final counter must equal a straight 10-clock run.
        let run = |spec: TableSpec, clocks: u64| {
            let mut cluster = Cluster::new(ClusterConfig {
                workers: 2,
                shards: 2,
                consistency: Consistency::Bsp,
                ..Default::default()
            });
            cluster.add_table(spec);
            let apps: Vec<Box<dyn PsApp>> = (0..2)
                .map(|_| {
                    Box::new(|ps: &mut PsClient, _c: Clock| {
                        let _ = ps.get((0, 0));
                        ps.inc((0, 0), &[1.0]);
                        None
                    }) as Box<dyn PsApp>
                })
                .collect();
            cluster.run(apps, clocks)
        };
        let first = run(crate::ps::server::TableSpec::zeros(0, 2, 1), 5);
        let path = tmp("resume.bin");
        save(&path, &first.table_rows).unwrap();
        let snapshot = load(&path).unwrap();
        let second = run(table_from_checkpoint(0, 2, 1, snapshot), 5);
        assert_eq!(second.table_rows[&(0, 0)][0], 20.0); // 2 workers x 10 clocks
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn deterministic_bytes() {
        let mut rows = HashMap::new();
        for i in 0..20u64 {
            rows.insert((0u32, i), vec![i as f32; 3]);
        }
        let (p1, p2) = (tmp("det1.bin"), tmp("det2.bin"));
        save(&p1, &rows).unwrap();
        save(&p2, &rows).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }
}
