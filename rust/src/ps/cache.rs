//! Client-side parameter cache with per-row clocks and LRU eviction.
//!
//! Mirrors the paper's ESSPTable client library: "the client library caches
//! locally accessed parameters … cold parameters are evicted using an
//! approximate LRU policy". Row payloads are shared immutable snapshots
//! (`Arc<[f32]>`): inserting a pulled or pushed row stores the *same*
//! allocation the shard sent (zero-copy); local read-my-writes folding
//! copies-on-write, so a shared snapshot is never mutated in place.
//!
//! Each cached row carries two clocks and its source shard:
//!
//!   * `vclock` — the server table clock when this copy was produced; all
//!     updates with clock <= vclock are guaranteed reflected. This is the
//!     clock the SSP read condition tests, and the one the Fig. 1
//!     staleness histogram measures: the client records the differential
//!     `vclock - worker clock` (the *guaranteed* clock, per the paper's
//!     "all updates generated before clock x have been applied"), with
//!     `vclock` effectively raised by newer empty-wave announcements.
//!   * `fresh`  — the max update clock actually reflected (best-effort
//!     in-window updates). Advisory only: it never enters the staleness
//!     histogram, which would otherwise overstate guarantees.
//!   * `source` — the shard that served this copy. A shard's wave
//!     announcements ("rows absent from my waves are unchanged through
//!     T") are claims about *its own* serving history, so the client
//!     applies `shard_announced` only to copies whose source matches the
//!     key's current owner. Without the tag, a copy pulled from a key's
//!     *previous* owner (live migration) or from a replica could inherit
//!     the new owner's blanket certification and be admitted while
//!     missing updates the new owner already holds.

use std::sync::Arc;

use super::types::{Clock, Key, RowDelta, NEVER};
use crate::util::hash::FxHashMap;

/// `source` value for a copy whose serving shard is unknown (e.g. a pull
/// reply with no in-flight record): never equal to a real shard id, so
/// blanket announcements are never applied to it.
pub const NO_SOURCE: usize = usize::MAX;

#[derive(Debug, Clone)]
pub struct CachedRow {
    pub data: Arc<[f32]>,
    pub vclock: Clock,
    pub fresh: Clock,
    /// Shard that served this copy (see module docs; [`NO_SOURCE`] if
    /// unknown).
    pub source: usize,
    /// Delta-chain token (wire v7): the wave id at which this copy last
    /// matched the serving shard's row bit-for-bit — the table vclock of
    /// the last ESSP wave folded/installed, or the sequence number of the
    /// last VAP preview. [`NEVER`] means the chain is broken (the copy
    /// came from a pull, or a wave was missed): the next wave for this
    /// key must be a full snapshot, and an arriving delta whose `base`
    /// does not equal this token is discarded (the row is dropped and
    /// re-pulled) rather than folded onto the wrong base.
    pub wave: Clock,
    /// LRU tick of the last access.
    last_used: u64,
}

/// Row cache with capacity-bounded approximate LRU.
#[derive(Debug)]
pub struct RowCache {
    rows: FxHashMap<Key, CachedRow>,
    capacity: usize,
    tick: u64,
    evictions: u64,
}

impl RowCache {
    /// `capacity` in rows (0 = unbounded).
    pub fn new(capacity: usize) -> Self {
        Self {
            rows: FxHashMap::default(),
            capacity,
            tick: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Look up a row, bumping its LRU position.
    pub fn get(&mut self, key: &Key) -> Option<&CachedRow> {
        self.tick += 1;
        let tick = self.tick;
        self.rows.get_mut(key).map(|r| {
            r.last_used = tick;
            &*r
        })
    }

    /// Peek without touching LRU order (used by metrics / invariant checks).
    pub fn peek(&self, key: &Key) -> Option<&CachedRow> {
        self.rows.get(key)
    }

    /// Insert or replace a row copy, evicting the LRU row if over capacity.
    ///
    /// Replacement keeps the *newer* clock pair: an in-flight pull reply
    /// must not clobber a fresher pushed copy that arrived first.
    ///
    /// Pull-path installs break the delta chain (`wave = NEVER`): the
    /// shard clears its seeded bit when it serves a pull, so the next
    /// wave arrives as a snapshot and re-seeds the chain. A stale
    /// arrival that keeps the existing copy keeps its token too — the
    /// data is unchanged, so the token still describes it exactly.
    pub fn insert(
        &mut self,
        key: Key,
        data: impl Into<Arc<[f32]>>,
        vclock: Clock,
        fresh: Clock,
        source: usize,
    ) {
        self.insert_with_wave(key, data, vclock, fresh, source, NEVER);
    }

    /// [`RowCache::insert`] for push-wave snapshot installs: on install
    /// the chain token is set to `wave` (the wave's table vclock for
    /// ESSP pushes), arming the row for delta folds on later waves.
    pub fn insert_pushed(
        &mut self,
        key: Key,
        data: impl Into<Arc<[f32]>>,
        vclock: Clock,
        fresh: Clock,
        source: usize,
        wave: Clock,
    ) {
        self.insert_with_wave(key, data, vclock, fresh, source, wave);
    }

    fn insert_with_wave(
        &mut self,
        key: Key,
        data: impl Into<Arc<[f32]>>,
        vclock: Clock,
        fresh: Clock,
        source: usize,
        wave: Clock,
    ) {
        self.tick += 1;
        match self.rows.get_mut(&key) {
            Some(existing) if existing.vclock > vclock => {
                // Stale arrival: keep the existing copy, but merge `fresh`
                // (monotone) so the metric never goes backwards.
                existing.fresh = existing.fresh.max(fresh);
                return;
            }
            _ => {}
        }
        self.rows.insert(
            key,
            CachedRow {
                data: data.into(),
                vclock,
                fresh,
                source,
                wave,
                last_used: self.tick,
            },
        );
        if self.capacity > 0 && self.rows.len() > self.capacity {
            self.evict_lru();
        }
    }

    /// Fold a push-wave delta chain onto the cached copy (wire v7).
    ///
    /// Succeeds only when the chain certifiably continues this copy: the
    /// row is cached, was served by `source`, and its token equals the
    /// wave's `base` (with `base != NEVER` — a chainless base certifies
    /// nothing). The deltas are then folded **in wire order** — the exact
    /// ordered sequence the shard applied, never a coalesced sum — so
    /// the result is bit-identical to the shard row, and the token
    /// advances to `wave`. `vclock` is `Some(v)` for clock-carrying
    /// waves (ESSP pushes: the copy is now guaranteed through `v`) and
    /// `None` for VAP previews (fresher data, no new clock guarantee).
    ///
    /// Returns `false` without touching the row when the chain does not
    /// continue; the caller discards the copy and re-pulls.
    pub fn fold_wave(
        &mut self,
        key: &Key,
        source: usize,
        base: Clock,
        deltas: &[RowDelta],
        wave: Clock,
        vclock: Option<Clock>,
        fresh: Clock,
    ) -> bool {
        self.tick += 1;
        let tick = self.tick;
        let Some(r) = self.rows.get_mut(key) else {
            return false;
        };
        if r.source != source || base == NEVER || r.wave != base {
            return false;
        }
        if Arc::get_mut(&mut r.data).is_none() {
            let detached: Arc<[f32]> = r.data.iter().copied().collect();
            r.data = detached;
        }
        let data = Arc::get_mut(&mut r.data).expect("unique after copy-on-write");
        for d in deltas {
            d.add_into(data);
        }
        if let Some(v) = vclock {
            if v > r.vclock {
                r.vclock = v;
            }
        }
        r.wave = wave;
        r.fresh = r.fresh.max(fresh);
        r.last_used = tick;
        true
    }

    /// Apply a local delta to the cached copy (read-my-writes support).
    /// Copies-on-write: a snapshot shared with an in-flight message or the
    /// shard is detached before mutation. Sparse deltas fold in place,
    /// touching only their nnz indices.
    ///
    /// Breaks the delta chain (`wave = NEVER`): the copy no longer equals
    /// the shard row at any wave, and the *next* wave's deltas will
    /// include this worker's own update once the shard applies it —
    /// folding them onto a copy that already contains it would
    /// double-count. The mismatch makes the client discard and re-pull
    /// instead.
    pub fn apply_delta(&mut self, key: &Key, delta: &RowDelta) {
        if let Some(r) = self.rows.get_mut(key) {
            if Arc::get_mut(&mut r.data).is_none() {
                let detached: Arc<[f32]> = r.data.iter().copied().collect();
                r.data = detached;
            }
            let data = Arc::get_mut(&mut r.data).expect("unique after copy-on-write");
            delta.add_into(data);
            r.wave = NEVER;
        }
    }

    /// Raise a row's best-effort freshness (monotone). Used when the
    /// worker folds its *own* clock-`c` updates into the cached copy: the
    /// data now reflects updates of clock c, and the staleness metric must
    /// account for that.
    pub fn bump_fresh(&mut self, key: &Key, clock: Clock) {
        if let Some(r) = self.rows.get_mut(key) {
            r.fresh = r.fresh.max(clock);
        }
    }

    /// Raise a row's *guaranteed* clock (monotone). Used when a push wave
    /// announces a new table clock and this row was NOT in the wave —
    /// i.e. the shard certifies it is unchanged through `vclock`.
    pub fn bump_vclock(&mut self, key: &Key, vclock: Clock) {
        if let Some(r) = self.rows.get_mut(key) {
            if vclock > r.vclock {
                r.vclock = vclock;
                r.fresh = r.fresh.max(vclock);
            }
        }
    }

    /// Snapshot of cached keys (used by push-wave processing).
    pub fn keys(&self) -> Vec<Key> {
        self.rows.keys().copied().collect()
    }

    /// Replace a row's *contents* without touching its guaranteed clock
    /// (VAP eager waves: the data is fresher, but no new clock guarantee
    /// is implied). Inserts with no guarantee if the row is not cached.
    /// `wave` is the new chain token (the VAP wave's sequence number);
    /// pass [`NEVER`] when no delta chain should continue from this copy.
    pub fn force_data(
        &mut self,
        key: Key,
        data: impl Into<Arc<[f32]>>,
        fresh: Clock,
        source: usize,
        wave: Clock,
    ) {
        self.tick += 1;
        match self.rows.get_mut(&key) {
            Some(r) => {
                r.data = data.into();
                r.fresh = r.fresh.max(fresh);
                r.source = source;
                r.wave = wave;
                r.last_used = self.tick;
            }
            None => {
                self.insert_with_wave(key, data, NEVER, fresh, source, wave);
            }
        }
    }

    pub fn remove(&mut self, key: &Key) -> Option<CachedRow> {
        self.rows.remove(key)
    }

    fn evict_lru(&mut self) {
        if let Some((&key, _)) = self.rows.iter().min_by_key(|(_, r)| r.last_used) {
            self.rows.remove(&key);
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(i: u64) -> Key {
        (0, i)
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0, 2.0], 5, 7, 0);
        let r = c.get(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[1.0, 2.0]);
        assert_eq!((r.vclock, r.fresh), (5, 7));
        assert!(c.get(&k(2)).is_none());
    }

    #[test]
    fn insert_shares_the_arc_zero_copy() {
        let mut c = RowCache::new(0);
        let payload: Arc<[f32]> = vec![1.0, 2.0].into();
        c.insert(k(1), Arc::clone(&payload), 0, 0, 0);
        assert!(
            Arc::ptr_eq(&payload, &c.peek(&k(1)).unwrap().data),
            "insert must store the shared snapshot, not a deep copy"
        );
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = RowCache::new(2);
        c.insert(k(1), vec![1.0], 0, 0, 0);
        c.insert(k(2), vec![2.0], 0, 0, 0);
        c.get(&k(1)); // bump 1; key 2 is now LRU
        c.insert(k(3), vec![3.0], 0, 0, 0);
        assert!(c.peek(&k(2)).is_none(), "LRU row should be evicted");
        assert!(c.peek(&k(1)).is_some());
        assert!(c.peek(&k(3)).is_some());
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn eviction_counter_tracks_every_overflow() {
        let mut c = RowCache::new(3);
        for i in 0..10 {
            c.insert(k(i), vec![i as f32], 0, 0, 0);
            assert!(c.len() <= 3, "capacity exceeded at insert {i}");
        }
        assert_eq!(c.evictions(), 7, "10 inserts into capacity 3");
        // The three newest keys survive.
        for i in 7..10 {
            assert!(c.peek(&k(i)).is_some(), "recent key {i} evicted");
        }
    }

    #[test]
    fn stale_arrival_does_not_clobber() {
        // A pull reply that raced a fresher push must not replace it: the
        // newer clock pair wins, and `fresh` merges monotonically.
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![9.0], 10, 12, 0);
        c.insert(k(1), vec![1.0], 4, 4, 0); // late pull reply
        let r = c.peek(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[9.0]);
        assert_eq!(r.vclock, 10);
        assert_eq!(r.fresh, 12);
    }

    #[test]
    fn stale_arrival_still_merges_fresh_forward() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![9.0], 10, 10, 0);
        // Older guarantee but higher best-effort freshness: keep data and
        // vclock, advance fresh.
        c.insert(k(1), vec![1.0], 4, 15, 0);
        let r = c.peek(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[9.0]);
        assert_eq!((r.vclock, r.fresh), (10, 15));
    }

    #[test]
    fn newer_arrival_replaces() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0], 4, 4, 0);
        c.insert(k(1), vec![9.0], 10, 11, 0);
        let r = c.peek(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[9.0]);
        assert_eq!((r.vclock, r.fresh), (10, 11));
    }

    #[test]
    fn apply_delta_mutates_copy() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0, 1.0], 0, 0, 0);
        c.apply_delta(&k(1), &vec![0.5, -0.5].into());
        assert_eq!(&c.peek(&k(1)).unwrap().data[..], &[1.5, 0.5]);
    }

    #[test]
    fn apply_sparse_delta_touches_only_its_indices() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0, 2.0, 3.0, 4.0], 0, 0, 0);
        c.apply_delta(&k(1), &RowDelta::sparse(4, vec![(1, 10.0), (3, -4.0)]));
        assert_eq!(&c.peek(&k(1)).unwrap().data[..], &[1.0, 12.0, 3.0, 0.0]);
    }

    #[test]
    fn apply_delta_detaches_shared_snapshot() {
        let mut c = RowCache::new(0);
        let shared: Arc<[f32]> = vec![1.0, 1.0].into();
        c.insert(k(1), Arc::clone(&shared), 0, 0, 0);
        c.apply_delta(&k(1), &vec![1.0, 0.0].into());
        // The external holder's view is untouched (copy-on-write).
        assert_eq!(&shared[..], &[1.0, 1.0]);
        assert_eq!(&c.peek(&k(1)).unwrap().data[..], &[2.0, 1.0]);
    }

    #[test]
    fn apply_sparse_delta_detaches_shared_snapshot() {
        let mut c = RowCache::new(0);
        let shared: Arc<[f32]> = vec![1.0, 1.0].into();
        c.insert(k(1), Arc::clone(&shared), 0, 0, 0);
        c.apply_delta(&k(1), &RowDelta::sparse(2, vec![(0, 1.0)]));
        assert_eq!(&shared[..], &[1.0, 1.0]);
        assert_eq!(&c.peek(&k(1)).unwrap().data[..], &[2.0, 1.0]);
    }

    #[test]
    fn source_tag_tracks_the_serving_shard() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0], 5, 5, 2);
        assert_eq!(c.peek(&k(1)).unwrap().source, 2);
        // A newer copy retags; a stale arrival keeps the winning copy's.
        c.insert(k(1), vec![2.0], 7, 7, 3);
        assert_eq!(c.peek(&k(1)).unwrap().source, 3);
        c.insert(k(1), vec![9.0], 6, 6, 0);
        assert_eq!(
            c.peek(&k(1)).unwrap().source,
            3,
            "stale arrival must not retag"
        );
        // force_data retags: the contents are now the pushing shard's.
        c.force_data(k(1), vec![4.0], 8, 1, NEVER);
        assert_eq!(c.peek(&k(1)).unwrap().source, 1);
        assert_eq!(NO_SOURCE, usize::MAX);
    }

    #[test]
    fn fold_wave_continues_a_seeded_chain() {
        let mut c = RowCache::new(0);
        c.insert_pushed(k(1), vec![1.0, 2.0], 5, 5, 0, 5);
        assert_eq!(c.peek(&k(1)).unwrap().wave, 5);
        // Chain continues: two ordered deltas fold, token advances, the
        // guaranteed clock rises.
        let folded = c.fold_wave(
            &k(1),
            0,
            5,
            &[
                RowDelta::Dense(vec![0.5, 0.0]),
                RowDelta::sparse(2, vec![(1, -1.0)]),
            ],
            7,
            Some(7),
            7,
        );
        assert!(folded);
        let r = c.peek(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[1.5, 1.0]);
        assert_eq!((r.vclock, r.fresh, r.wave), (7, 7, 7));
    }

    #[test]
    fn fold_wave_rejects_broken_or_mismatched_chains() {
        let mut c = RowCache::new(0);
        // Missing row.
        assert!(!c.fold_wave(&k(9), 0, 5, &[], 7, Some(7), 7));
        // Pull-installed row: wave = NEVER, never continues a chain.
        c.insert(k(1), vec![1.0], 5, 5, 0);
        assert!(!c.fold_wave(&k(1), 0, 5, &[], 7, Some(7), 7));
        // A lying base of NEVER must not match the broken token either.
        assert!(!c.fold_wave(&k(1), 0, super::NEVER, &[], 7, Some(7), 7));
        // Wrong source shard.
        c.insert_pushed(k(2), vec![1.0], 5, 5, 0, 5);
        assert!(!c.fold_wave(&k(2), 3, 5, &[], 7, Some(7), 7));
        // Wrong base token.
        assert!(!c.fold_wave(&k(2), 0, 4, &[], 7, Some(7), 7));
        // Rejections leave the row untouched.
        let r = c.peek(&k(2)).unwrap();
        assert_eq!((r.vclock, r.wave), (5, 5));
        assert_eq!(&r.data[..], &[1.0]);
    }

    #[test]
    fn fold_wave_detaches_shared_snapshots() {
        let mut c = RowCache::new(0);
        let shared: Arc<[f32]> = vec![1.0].into();
        c.insert_pushed(k(1), Arc::clone(&shared), 5, 5, 0, 5);
        assert!(c.fold_wave(&k(1), 0, 5, &[RowDelta::Dense(vec![1.0])], 6, Some(6), 6));
        assert_eq!(&shared[..], &[1.0], "copy-on-write must protect sharers");
        assert_eq!(&c.peek(&k(1)).unwrap().data[..], &[2.0]);
    }

    #[test]
    fn local_fold_breaks_the_chain() {
        // A read-my-writes fold makes the copy diverge from the shard row
        // (and the next wave will re-ship this worker's own update): the
        // token must drop to NEVER so the delta path cannot double-count.
        let mut c = RowCache::new(0);
        c.insert_pushed(k(1), vec![1.0], 5, 5, 0, 5);
        c.apply_delta(&k(1), &vec![0.25].into());
        assert_eq!(c.peek(&k(1)).unwrap().wave, super::NEVER);
        assert!(!c.fold_wave(&k(1), 0, 5, &[], 7, Some(7), 7));
    }

    #[test]
    fn vap_fold_leaves_the_guarantee_alone() {
        let mut c = RowCache::new(0);
        c.insert(k(1), vec![1.0], 3, 3, 1);
        // Seed the chain via a VAP preview snapshot (seq 10), then fold
        // the next preview's delta: vclock must stay at the pull's 3.
        c.force_data(k(1), vec![2.0], 4, 1, 10);
        assert!(c.fold_wave(&k(1), 1, 10, &[RowDelta::Dense(vec![1.0])], 11, None, 5));
        let r = c.peek(&k(1)).unwrap();
        assert_eq!(&r.data[..], &[3.0]);
        assert_eq!((r.vclock, r.fresh, r.wave), (3, 5, 11));
    }

    #[test]
    fn unbounded_never_evicts() {
        let mut c = RowCache::new(0);
        for i in 0..1000 {
            c.insert(k(i), vec![0.0], 0, 0, 0);
        }
        assert_eq!(c.len(), 1000);
        assert_eq!(c.evictions(), 0);
    }
}
