//! Theory instruments: the paper's convergence bounds, evaluated on
//! *measured* staleness profiles.
//!
//! Theorem 5 (SGD under SSP, convergence in probability) bounds
//!
//! ```text
//! P[ R[X]/T - (1/sqrt(T)) (ηL² + F²/η + 2ηL²μ_γ) >= τ ]
//!   <= exp( -Tτ² / (2·η̄_T·σ_γ + (2/3)·ηL²(2s+1)P·τ) )
//! ```
//!
//! with η̄_T = η²L⁴(ln T + 1)/T, where μ_γ and σ_γ are the mean and
//! variance of the staleness distribution γ_t. The paper's argument for
//! ESSP is exactly that eager communication shrinks μ_γ and σ_γ, which
//! tightens both the expected-regret gap term (2ηL²μ_γ/√T) and the
//! exponential tail. This module computes those quantities from a
//! [`StalenessHist`] measured during a run, so each experiment can report
//! "theory-predicted" alongside "measured" — the bridge between the
//! paper's Theorems and its Figures.
//!
//! Units note: γ_t in the theory is ||γ_t||₂ of the missing-update vector,
//! bounded by P(2s+1); our measured clock differentials are a 1-D proxy.
//! We map differential d -> γ = P * (d - (-1)).abs() (number of missing
//! update *waves* times workers), the same scaling the Lemma 4 bound uses.

use crate::metrics::staleness::StalenessHist;

/// Problem constants for the bound (paper notation).
#[derive(Debug, Clone, Copy)]
pub struct BoundParams {
    /// Lipschitz constant L of the component losses.
    pub lipschitz: f64,
    /// Diameter bound F² >= D(x||x').
    pub f_sq: f64,
    /// Step-size scale η (η_t = η/√t).
    pub eta: f64,
    /// Workers P.
    pub workers: usize,
    /// Staleness bound s.
    pub staleness: i64,
    /// Horizon T (total updates).
    pub horizon: u64,
}

/// Staleness moments extracted from a measured histogram, mapped to the
/// theory's γ scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GammaMoments {
    pub mu: f64,
    pub sigma_sq: f64,
    /// Hard bound P(2s+1) from Lemma 4.
    pub gamma_max: f64,
}

/// Map a measured clock-differential histogram to γ moments.
///
/// Differential -1 (fully fresh) maps to γ = 0; each additional clock of
/// staleness contributes P missing updates.
pub fn gamma_moments(hist: &StalenessHist, workers: usize, staleness: i64) -> GammaMoments {
    let p = workers as f64;
    let total = hist.total().max(1) as f64;
    let mut mu = 0.0;
    for (d, c) in hist.buckets() {
        let gamma = p * ((d + 1).abs() as f64);
        mu += gamma * c as f64 / total;
    }
    let mut var = 0.0;
    for (d, c) in hist.buckets() {
        let gamma = p * ((d + 1).abs() as f64);
        var += (gamma - mu).powi(2) * c as f64 / total;
    }
    GammaMoments {
        mu,
        sigma_sq: var,
        gamma_max: p * (2 * staleness + 1) as f64,
    }
}

/// The deterministic part of Theorem 5: the expected-regret rate
/// (1/√T)(ηL² + F²/η + 2ηL²μ_γ). Lower is better; the μ_γ term is the
/// lever ESSP pulls.
pub fn expected_regret_rate(p: &BoundParams, g: &GammaMoments) -> f64 {
    let l2 = p.lipschitz * p.lipschitz;
    (p.eta * l2 + p.f_sq / p.eta + 2.0 * p.eta * l2 * g.mu) / (p.horizon as f64).sqrt()
}

/// The exponential tail of Theorem 5: probability that R[X]/T exceeds the
/// expected rate by τ.
pub fn tail_probability(p: &BoundParams, g: &GammaMoments, tau: f64) -> f64 {
    let t = p.horizon as f64;
    let l2 = p.lipschitz * p.lipschitz;
    let l4 = l2 * l2;
    let eta_bar = p.eta * p.eta * l4 * (t.ln() + 1.0) / t;
    let denom = 2.0 * eta_bar * g.sigma_sq
        + (2.0 / 3.0)
            * p.eta
            * l2
            * ((2 * p.staleness + 1) as f64)
            * (p.workers as f64)
            * tau;
    if denom <= 0.0 {
        return if tau > 0.0 { 0.0 } else { 1.0 };
    }
    (-t * tau * tau / denom).exp().min(1.0)
}

/// The η that minimizes the staleness-aware rate: balancing
/// ηL²(1 + 2μ_γ) against F²/η gives η* = F / (L √(1 + 2μ_γ)).
/// Fresh reads (μ_γ -> 0) permit larger steps — the theory's version of
/// the §Robustness observation that staleness effectively inflates the
/// step size.
pub fn optimal_eta(p: &BoundParams, g: &GammaMoments) -> f64 {
    (p.f_sq.sqrt()) / (p.lipschitz * (1.0 + 2.0 * g.mu).sqrt())
}

/// Side-by-side theory report for two measured runs (e.g. SSP vs ESSP).
pub fn compare_report(
    params: &BoundParams,
    label_a: &str,
    hist_a: &StalenessHist,
    label_b: &str,
    hist_b: &StalenessHist,
) -> String {
    let ga = gamma_moments(hist_a, params.workers, params.staleness);
    let gb = gamma_moments(hist_b, params.workers, params.staleness);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>10} {:>12} {:>14} {:>12}\n",
        "run", "mu_gamma", "sigma2", "regret rate", "P[tau=0.5]"
    ));
    for (label, g) in [(label_a, &ga), (label_b, &gb)] {
        out.push_str(&format!(
            "{:<10} {:>10.2} {:>12.2} {:>14.5} {:>12.3e}\n",
            label,
            g.mu,
            g.sigma_sq,
            expected_regret_rate(params, g),
            tail_probability(params, g, 0.5),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        BoundParams {
            lipschitz: 1.0,
            f_sq: 1.0,
            eta: 0.5,
            workers: 8,
            staleness: 3,
            horizon: 10_000,
        }
    }

    fn hist(entries: &[(i64, u64)]) -> StalenessHist {
        let mut h = StalenessHist::new();
        for &(d, c) in entries {
            for _ in 0..c {
                h.record(d);
            }
        }
        h
    }

    #[test]
    fn fresh_profile_has_zero_mu() {
        let h = hist(&[(-1, 100)]);
        let g = gamma_moments(&h, 8, 3);
        assert_eq!(g.mu, 0.0);
        assert_eq!(g.sigma_sq, 0.0);
        assert_eq!(g.gamma_max, 8.0 * 7.0);
    }

    #[test]
    fn staler_profile_has_larger_mu() {
        let fresh = gamma_moments(&hist(&[(-1, 80), (-2, 20)]), 8, 3);
        let stale = gamma_moments(&hist(&[(-1, 20), (-4, 80)]), 8, 3);
        assert!(stale.mu > fresh.mu);
    }

    #[test]
    fn regret_rate_monotone_in_mu() {
        let p = params();
        let fresh = gamma_moments(&hist(&[(-1, 100)]), p.workers, p.staleness);
        let stale = gamma_moments(&hist(&[(-4, 100)]), p.workers, p.staleness);
        assert!(expected_regret_rate(&p, &stale) > expected_regret_rate(&p, &fresh));
    }

    #[test]
    fn regret_rate_shrinks_with_horizon() {
        let g = gamma_moments(&hist(&[(-2, 100)]), 8, 3);
        let short = expected_regret_rate(&BoundParams { horizon: 100, ..params() }, &g);
        let long = expected_regret_rate(&BoundParams { horizon: 100_000, ..params() }, &g);
        assert!(long < short);
    }

    #[test]
    fn tail_probability_behaves() {
        let p = params();
        let g = gamma_moments(&hist(&[(-1, 50), (-3, 50)]), p.workers, p.staleness);
        let p_small = tail_probability(&p, &g, 0.1);
        let p_large = tail_probability(&p, &g, 1.0);
        assert!((0.0..=1.0).contains(&p_small));
        assert!(p_large <= p_small, "tail must decay in tau");
        // Lower-variance profile -> smaller tail at fixed tau.
        let tight = gamma_moments(&hist(&[(-2, 100)]), p.workers, p.staleness);
        // Same mu as the mixed profile above (both average one stale clock).
        assert!((tight.mu - g.mu).abs() < 1e-9);
        assert!(tail_probability(&p, &tight, 0.1) <= p_small);
    }

    #[test]
    fn optimal_eta_larger_when_fresh() {
        let p = params();
        let fresh = gamma_moments(&hist(&[(-1, 100)]), p.workers, p.staleness);
        let stale = gamma_moments(&hist(&[(-4, 100)]), p.workers, p.staleness);
        assert!(optimal_eta(&p, &fresh) > optimal_eta(&p, &stale));
    }

    #[test]
    fn compare_report_formats() {
        let p = params();
        let a = hist(&[(-1, 90), (-2, 10)]);
        let b = hist(&[(-1, 10), (-4, 90)]);
        let rep = compare_report(&p, "essp", &a, "ssp", &b);
        assert!(rep.contains("essp"));
        assert!(rep.contains("ssp"));
        assert_eq!(rep.lines().count(), 3);
    }
}
