//! Client-side update coalescing.
//!
//! Updates are additive (x += u), hence commutative and associative; the
//! paper's client library exploits this by summing all INCs to the same row
//! within a clock and shipping one delta per touched row per clock. This is
//! the main message-count reduction in the system (benchmarked in
//! `benches/ps_throughput.rs`).
//!
//! The INC path deliberately does *no* norm bookkeeping: the value-bounded
//! policies need per-shard *part* norms, which the client computes with one
//! scan over the routed batches at flush time — and only when the active
//! policy reports norms at all, so BSP/SSP/ESSP/Async pay nothing.

use super::types::{row_wire_bytes, Key};
use crate::util::hash::FxHashMap;

/// Coalesced pending updates for one clock tick.
#[derive(Debug)]
pub struct UpdateMap {
    rows: FxHashMap<Key, Vec<f32>>,
    /// Number of raw INC calls folded in (for coalescing-ratio metrics).
    raw_incs: u64,
}

impl Default for UpdateMap {
    fn default() -> Self {
        Self::new()
    }
}

impl UpdateMap {
    pub fn new() -> Self {
        Self {
            rows: FxHashMap::default(),
            raw_incs: 0,
        }
    }

    /// Fold one INC into the pending delta for `key`.
    pub fn inc(&mut self, key: Key, delta: &[f32]) {
        self.raw_incs += 1;
        match self.rows.get_mut(&key) {
            Some(acc) => {
                debug_assert_eq!(acc.len(), delta.len(), "row length mismatch on {key:?}");
                for (a, d) in acc.iter_mut().zip(delta) {
                    *a += d;
                }
            }
            None => {
                self.rows.insert(key, delta.to_vec());
            }
        }
    }

    /// Fold a sparse INC (index/value pairs) into the pending delta.
    /// The row must already exist or `row_len` is used to create it.
    pub fn inc_sparse(&mut self, key: Key, row_len: usize, pairs: &[(usize, f32)]) {
        self.raw_incs += 1;
        let acc = self.rows.entry(key).or_insert_with(|| vec![0.0; row_len]);
        for &(i, v) in pairs {
            acc[i] += v;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn raw_incs(&self) -> u64 {
        self.raw_incs
    }

    /// Peek at the pending delta for a row (read-my-writes support).
    pub fn pending(&self, key: &Key) -> Option<&[f32]> {
        self.rows.get(key).map(|v| v.as_slice())
    }

    /// Keys with pending deltas (arbitrary order).
    pub fn keys(&self) -> Vec<Key> {
        self.rows.keys().copied().collect()
    }

    /// ∞-norm (max |element|) over all pending rows, by full scan. The
    /// client's flush path computes per-shard part norms from the routed
    /// batches instead; this is the whole-batch variant for tests and
    /// metrics.
    pub fn inf_norm(&self) -> f32 {
        self.rows
            .values()
            .flat_map(|v| v.iter())
            .fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Drain into per-destination batches, keyed by `route(key)`.
    /// Returns (destination -> rows) and resets the map.
    pub fn drain_routed<F: Fn(&Key) -> usize>(
        &mut self,
        n_dests: usize,
        route: F,
    ) -> Vec<Vec<(Key, Vec<f32>)>> {
        let mut out: Vec<Vec<(Key, Vec<f32>)>> = (0..n_dests).map(|_| Vec::new()).collect();
        for (key, delta) in self.rows.drain() {
            out[route(&key)].push((key, delta));
        }
        self.raw_incs = 0;
        out
    }

    /// Wire size estimate of the pending batch.
    pub fn wire_bytes(&self) -> usize {
        self.rows.values().map(|v| row_wire_bytes(v.len())).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: Key = (0, 7);

    #[test]
    fn coalesces_additively() {
        let mut m = UpdateMap::new();
        m.inc(K, &[1.0, 2.0]);
        m.inc(K, &[0.5, -1.0]);
        assert_eq!(m.pending(&K).unwrap(), &[1.5, 1.0]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.raw_incs(), 2);
    }

    #[test]
    fn sparse_and_dense_mix() {
        let mut m = UpdateMap::new();
        m.inc_sparse(K, 4, &[(0, 1.0), (3, 2.0)]);
        m.inc(K, &[1.0, 1.0, 0.0, 0.0]);
        assert_eq!(m.pending(&K).unwrap(), &[2.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn inf_norm_over_all_rows() {
        let mut m = UpdateMap::new();
        m.inc((0, 1), &[0.5, -3.0]);
        m.inc((0, 2), &[1.0]);
        assert_eq!(m.inf_norm(), 3.0);
        assert_eq!(UpdateMap::new().inf_norm(), 0.0);
    }

    #[test]
    fn inf_norm_reflects_cancellation() {
        // +5 then -5 on the max element: the scan sees the summed state,
        // never a stale peak.
        let mut m = UpdateMap::new();
        m.inc(K, &[5.0, 1.0]);
        assert_eq!(m.inf_norm(), 5.0);
        m.inc(K, &[-5.0, 0.0]);
        assert_eq!(m.inf_norm(), 1.0);
    }

    #[test]
    fn drain_routes_and_resets() {
        let mut m = UpdateMap::new();
        m.inc((0, 0), &[1.0]);
        m.inc((0, 1), &[2.0]);
        m.inc((0, 2), &[3.0]);
        let routed = m.drain_routed(2, |k| (k.1 % 2) as usize);
        assert_eq!(routed[0].len(), 2); // rows 0, 2
        assert_eq!(routed[1].len(), 1); // row 1
        assert!(m.is_empty());
        assert_eq!(m.raw_incs(), 0);
        assert_eq!(m.inf_norm(), 0.0);
    }

    #[test]
    fn coalescing_is_lossless() {
        // Sum of drained batches equals the sum of raw updates.
        let mut m = UpdateMap::new();
        let mut expect = vec![0.0f32; 3];
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..100 {
            let d: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
            for (e, x) in expect.iter_mut().zip(&d) {
                *e += x;
            }
            m.inc(K, &d);
        }
        let routed = m.drain_routed(1, |_| 0);
        let got = &routed[0][0].1;
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4);
        }
    }
}
