//! Client-side update coalescing.
//!
//! Updates are additive (x += u), hence commutative and associative; the
//! paper's client library exploits this by summing all INCs to the same row
//! within a clock and shipping one delta per touched row per clock. This is
//! the main message-count reduction in the system (benchmarked in
//! `benches/ps_throughput.rs`).

use super::types::{row_wire_bytes, Key};
use crate::util::hash::FxHashMap;

/// Coalesced pending updates for one clock tick.
#[derive(Debug)]
pub struct UpdateMap {
    rows: FxHashMap<Key, Vec<f32>>,
    /// Number of raw INC calls folded in (for coalescing-ratio metrics).
    raw_incs: u64,
    /// Running max |element| over all pending rows, maintained by
    /// `inc`/`inc_sparse`. Exact while `norm_exact`; an element that held
    /// the max and then shrank (sign cancellation) flips `norm_exact`, and
    /// the next `inf_norm()` call falls back to a rescan. This keeps
    /// `inf_norm()` O(1) on the common SGD path (each element written once
    /// per clock, magnitudes grow monotonically within a batch) instead of
    /// rescanning every pending element on every `tick()`.
    max_abs: f32,
    norm_exact: bool,
}

impl Default for UpdateMap {
    fn default() -> Self {
        Self::new()
    }
}

impl UpdateMap {
    pub fn new() -> Self {
        Self {
            rows: FxHashMap::default(),
            raw_incs: 0,
            max_abs: 0.0,
            norm_exact: true,
        }
    }

    /// Fold one INC into the pending delta for `key`.
    pub fn inc(&mut self, key: Key, delta: &[f32]) {
        self.raw_incs += 1;
        match self.rows.get_mut(&key) {
            Some(acc) => {
                debug_assert_eq!(acc.len(), delta.len(), "row length mismatch on {key:?}");
                let mut max_abs = self.max_abs;
                let mut exact = self.norm_exact;
                for (a, d) in acc.iter_mut().zip(delta) {
                    let old = *a;
                    *a += d;
                    let new_abs = a.abs();
                    if new_abs >= max_abs {
                        max_abs = new_abs;
                    } else if old.abs() >= max_abs {
                        exact = false;
                    }
                }
                self.max_abs = max_abs;
                self.norm_exact = exact;
            }
            None => {
                for d in delta {
                    let a = d.abs();
                    if a > self.max_abs {
                        self.max_abs = a;
                    }
                }
                self.rows.insert(key, delta.to_vec());
            }
        }
    }

    /// Fold a sparse INC (index/value pairs) into the pending delta.
    /// The row must already exist or `row_len` is used to create it.
    pub fn inc_sparse(&mut self, key: Key, row_len: usize, pairs: &[(usize, f32)]) {
        self.raw_incs += 1;
        let acc = self.rows.entry(key).or_insert_with(|| vec![0.0; row_len]);
        let mut max_abs = self.max_abs;
        let mut exact = self.norm_exact;
        for &(i, v) in pairs {
            let old = acc[i];
            acc[i] += v;
            let new_abs = acc[i].abs();
            if new_abs >= max_abs {
                max_abs = new_abs;
            } else if old.abs() >= max_abs {
                exact = false;
            }
        }
        self.max_abs = max_abs;
        self.norm_exact = exact;
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn raw_incs(&self) -> u64 {
        self.raw_incs
    }

    /// Peek at the pending delta for a row (read-my-writes support).
    pub fn pending(&self, key: &Key) -> Option<&[f32]> {
        self.rows.get(key).map(|v| v.as_slice())
    }

    /// Keys with pending deltas (arbitrary order).
    pub fn keys(&self) -> Vec<Key> {
        self.rows.keys().copied().collect()
    }

    /// Max |delta| over all pending rows — the VAP in-transit magnitude
    /// contribution of this batch (∞-norm of the aggregated update).
    /// O(1) while the incrementally-tracked max is exact (the common
    /// case); falls back to a rescan only after sign cancellation shrank
    /// a maximal element.
    pub fn inf_norm(&self) -> f32 {
        if self.norm_exact {
            return self.max_abs;
        }
        self.rescan_inf_norm()
    }

    /// Ground-truth ∞-norm by full rescan (test oracle + fallback).
    pub fn rescan_inf_norm(&self) -> f32 {
        self.rows
            .values()
            .flat_map(|v| v.iter())
            .fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Drain into per-destination batches, keyed by `route(key)`.
    /// Returns (destination -> rows) and resets the map.
    pub fn drain_routed<F: Fn(&Key) -> usize>(
        &mut self,
        n_dests: usize,
        route: F,
    ) -> Vec<Vec<(Key, Vec<f32>)>> {
        let mut out: Vec<Vec<(Key, Vec<f32>)>> = (0..n_dests).map(|_| Vec::new()).collect();
        for (key, delta) in self.rows.drain() {
            out[route(&key)].push((key, delta));
        }
        self.raw_incs = 0;
        self.max_abs = 0.0;
        self.norm_exact = true;
        out
    }

    /// Wire size estimate of the pending batch.
    pub fn wire_bytes(&self) -> usize {
        self.rows.values().map(|v| row_wire_bytes(v.len())).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: Key = (0, 7);

    #[test]
    fn coalesces_additively() {
        let mut m = UpdateMap::new();
        m.inc(K, &[1.0, 2.0]);
        m.inc(K, &[0.5, -1.0]);
        assert_eq!(m.pending(&K).unwrap(), &[1.5, 1.0]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.raw_incs(), 2);
    }

    #[test]
    fn sparse_and_dense_mix() {
        let mut m = UpdateMap::new();
        m.inc_sparse(K, 4, &[(0, 1.0), (3, 2.0)]);
        m.inc(K, &[1.0, 1.0, 0.0, 0.0]);
        assert_eq!(m.pending(&K).unwrap(), &[2.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn inf_norm_over_all_rows() {
        let mut m = UpdateMap::new();
        m.inc((0, 1), &[0.5, -3.0]);
        m.inc((0, 2), &[1.0]);
        assert_eq!(m.inf_norm(), 3.0);
        assert_eq!(UpdateMap::new().inf_norm(), 0.0);
    }

    #[test]
    fn inf_norm_tracks_cancellation_exactly() {
        // +5 then -5 on the max element: the incremental max must not
        // report the stale peak — it falls back to a rescan and matches.
        let mut m = UpdateMap::new();
        m.inc(K, &[5.0, 1.0]);
        assert_eq!(m.inf_norm(), 5.0);
        m.inc(K, &[-5.0, 0.0]);
        assert_eq!(m.inf_norm(), 1.0);
        assert_eq!(m.inf_norm(), m.rescan_inf_norm());
    }

    #[test]
    fn inf_norm_matches_rescan_under_random_churn() {
        // Property check: whatever mix of dense/sparse, positive/negative
        // INCs, the O(1)-path answer always equals the ground truth.
        let mut rng = crate::util::rng::Rng::new(31);
        for _case in 0..20 {
            let mut m = UpdateMap::new();
            for _ in 0..200 {
                let key = (0, rng.below(8));
                if rng.f64() < 0.5 {
                    let d: Vec<f32> = (0..4).map(|_| rng.normal_f32() * 2.0).collect();
                    m.inc(key, &d);
                } else {
                    let idx = rng.usize_below(4);
                    m.inc_sparse(key, 4, &[(idx, rng.normal_f32() * 3.0)]);
                }
                assert_eq!(m.inf_norm(), m.rescan_inf_norm());
            }
            // Reset on drain.
            let _ = m.drain_routed(2, |k| (k.1 % 2) as usize);
            assert_eq!(m.inf_norm(), 0.0);
        }
    }

    #[test]
    fn drain_routes_and_resets() {
        let mut m = UpdateMap::new();
        m.inc((0, 0), &[1.0]);
        m.inc((0, 1), &[2.0]);
        m.inc((0, 2), &[3.0]);
        let routed = m.drain_routed(2, |k| (k.1 % 2) as usize);
        assert_eq!(routed[0].len(), 2); // rows 0, 2
        assert_eq!(routed[1].len(), 1); // row 1
        assert!(m.is_empty());
        assert_eq!(m.raw_incs(), 0);
        assert_eq!(m.inf_norm(), 0.0);
    }

    #[test]
    fn coalescing_is_lossless() {
        // Sum of drained batches equals the sum of raw updates.
        let mut m = UpdateMap::new();
        let mut expect = vec![0.0f32; 3];
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..100 {
            let d: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
            for (e, x) in expect.iter_mut().zip(&d) {
                *e += x;
            }
            m.inc(K, &d);
        }
        let routed = m.drain_routed(1, |_| 0);
        let got = &routed[0][0].1;
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4);
        }
    }
}
