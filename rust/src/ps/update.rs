//! Client-side update coalescing over hybrid dense/sparse row deltas.
//!
//! Updates are additive (x += u), hence commutative and associative; the
//! paper's client library exploits this by summing all INCs to the same row
//! within a clock and shipping one delta per touched row per clock. This is
//! the main message-count reduction in the system (benchmarked in
//! `benches/ps_throughput.rs`).
//!
//! ## Hybrid representation
//!
//! A pending row is a [`RowDelta`]. Sparse INCs (an LDA Gibbs token touches
//! 1–2 indices of a K-length word-topic row) accumulate as sorted
//! `(index, value)` pairs and ship on the wire as `len | nnz | (idx,val)*`
//! — O(nnz) bytes instead of O(K). A dense INC, or a sparse accumulation
//! whose fill passes the density threshold (`nnz > len / DENSIFY_DIV`,
//! i.e. len/3), switches the row to the flat f32 representation for the
//! rest of the clock — dense wins any mix. The threshold sits below the
//! wire break-even (8-byte pairs overtake 4-byte elements at nnz = len/2),
//! so densification never inflates the encoded size.
//!
//! [`UpdateMap::wire_bytes`] sums [`row_wire_bytes`] over the pending
//! rows; the `transport::wire` codec derives its Update frame size from
//! the *same* function, so the client's pending-bytes estimate, the
//! SimNet serialization-time model, and the real TCP framing agree
//! byte-for-byte.
//!
//! The INC path deliberately does *no* norm bookkeeping: the value-bounded
//! policies need per-shard *part* norms, which the client computes with one
//! scan over the routed batches at flush time — and only when the active
//! policy reports norms at all, so BSP/SSP/ESSP/Async pay nothing. For a
//! sparse part that scan touches only the stored pairs.

use super::types::{row_wire_bytes, Key, RowDelta};
use crate::util::hash::FxHashMap;

/// Coalesced pending updates for one clock tick.
#[derive(Debug)]
pub struct UpdateMap {
    rows: FxHashMap<Key, RowDelta>,
    /// Number of raw INC calls folded in (for coalescing-ratio metrics).
    raw_incs: u64,
}

impl Default for UpdateMap {
    fn default() -> Self {
        Self::new()
    }
}

impl UpdateMap {
    pub fn new() -> Self {
        Self {
            rows: FxHashMap::default(),
            raw_incs: 0,
        }
    }

    /// Fold one dense INC into the pending delta for `key`. A sparse
    /// accumulator densifies: the increment names every element.
    pub fn inc(&mut self, key: Key, delta: &[f32]) {
        self.raw_incs += 1;
        match self.rows.get_mut(&key) {
            Some(acc) => {
                debug_assert_eq!(acc.len(), delta.len(), "row length mismatch on {key:?}");
                acc.add_dense(delta);
            }
            None => {
                self.rows.insert(key, RowDelta::Dense(delta.to_vec()));
            }
        }
    }

    /// Fold a sparse INC (index/value pairs against a row of `row_len`
    /// elements) into the pending delta. A fresh row starts sparse and
    /// stays sparse until the density threshold; a dense accumulator
    /// absorbs the pairs in place.
    pub fn inc_sparse(&mut self, key: Key, row_len: usize, pairs: &[(usize, f32)]) {
        self.raw_incs += 1;
        let acc = self
            .rows
            .entry(key)
            .or_insert_with(|| RowDelta::sparse(row_len, Vec::new()));
        debug_assert_eq!(acc.len(), row_len, "row length mismatch on {key:?}");
        for &(i, v) in pairs {
            // Hard check in all builds (the dense path gets one for free
            // from slice indexing): a silently stored out-of-range pair
            // would either vanish at apply time or poison the wire frame
            // far from the buggy INC call.
            assert!(i < row_len, "sparse index {i} out of range on {key:?}");
            acc.add_pair(i as u32, v);
        }
        acc.maybe_densify();
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn raw_incs(&self) -> u64 {
        self.raw_incs
    }

    /// Peek at the pending delta for a row (read-my-writes support).
    pub fn pending(&self, key: &Key) -> Option<&RowDelta> {
        self.rows.get(key)
    }

    /// Borrow every pending (key, delta) pair (arbitrary order). The
    /// flush path folds these into the row cache in place — no per-row
    /// clone — right before [`Self::drain_routed`] moves them out.
    pub fn iter(&self) -> impl Iterator<Item = (&Key, &RowDelta)> {
        self.rows.iter()
    }

    /// Keys with pending deltas (arbitrary order).
    pub fn keys(&self) -> Vec<Key> {
        self.rows.keys().copied().collect()
    }

    /// ∞-norm (max |element|) over all pending rows. The client's flush
    /// path computes per-shard part norms from the routed batches
    /// instead; this is the whole-batch variant for tests and metrics.
    /// Sparse rows scan only their stored pairs.
    pub fn inf_norm(&self) -> f32 {
        self.rows
            .values()
            .map(RowDelta::inf_norm)
            .fold(0.0f32, |m, x| m.max(x))
    }

    /// Drain into per-destination batches, keyed by `route(key)`: each
    /// coalesced delta is *moved* into its batch (no payload clone) and
    /// the map resets.
    pub fn drain_routed<F: Fn(&Key) -> usize>(
        &mut self,
        n_dests: usize,
        route: F,
    ) -> Vec<Vec<(Key, RowDelta)>> {
        let mut out: Vec<Vec<(Key, RowDelta)>> = (0..n_dests).map(|_| Vec::new()).collect();
        for (key, delta) in self.rows.drain() {
            out[route(&key)].push((key, delta));
        }
        self.raw_incs = 0;
        out
    }

    /// Exact wire size of the pending batch (see module docs: same
    /// per-row accounting the codec uses).
    pub fn wire_bytes(&self) -> usize {
        self.rows.values().map(row_wire_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::types::densify_threshold;

    const K: Key = (0, 7);

    /// Densified view of a pending row (tests compare values, not repr).
    fn dense(m: &UpdateMap, key: &Key) -> Vec<f32> {
        m.pending(key).unwrap().clone().to_dense()
    }

    #[test]
    fn coalesces_additively() {
        let mut m = UpdateMap::new();
        m.inc(K, &[1.0, 2.0]);
        m.inc(K, &[0.5, -1.0]);
        assert_eq!(dense(&m, &K), vec![1.5, 1.0]);
        assert_eq!(m.len(), 1);
        assert_eq!(m.raw_incs(), 2);
    }

    #[test]
    fn sparse_and_dense_mix() {
        let mut m = UpdateMap::new();
        m.inc_sparse(K, 4, &[(0, 1.0), (3, 2.0)]);
        assert!(m.pending(&K).unwrap().is_sparse());
        m.inc(K, &[1.0, 1.0, 0.0, 0.0]);
        // One dense INC densifies the accumulator for the clock.
        assert!(!m.pending(&K).unwrap().is_sparse());
        assert_eq!(dense(&m, &K), vec![2.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn sparse_incs_stay_sparse_below_threshold() {
        // LDA-shaped: +/-1 on a few indices of a wide row never densifies.
        let mut m = UpdateMap::new();
        for _ in 0..50 {
            m.inc_sparse(K, 1024, &[(3, 1.0), (900, -1.0)]);
        }
        let d = m.pending(&K).unwrap();
        assert!(d.is_sparse());
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.len(), 1024);
    }

    #[test]
    fn sparse_densifies_past_threshold() {
        let len = 12; // threshold = 4
        let mut m = UpdateMap::new();
        for i in 0..densify_threshold(len) {
            m.inc_sparse(K, len, &[(i, 1.0)]);
            assert!(m.pending(&K).unwrap().is_sparse(), "below threshold at {i}");
        }
        m.inc_sparse(K, len, &[(len - 1, 1.0)]);
        assert!(!m.pending(&K).unwrap().is_sparse(), "crossed threshold");
        let mut want = vec![0.0f32; len];
        for w in want.iter_mut().take(densify_threshold(len)) {
            *w = 1.0;
        }
        want[len - 1] = 1.0;
        assert_eq!(dense(&m, &K), want);
    }

    #[test]
    fn inf_norm_over_all_rows() {
        let mut m = UpdateMap::new();
        m.inc((0, 1), &[0.5, -3.0]);
        m.inc((0, 2), &[1.0]);
        m.inc_sparse((0, 3), 64, &[(10, -2.0)]);
        assert_eq!(m.inf_norm(), 3.0);
        assert_eq!(UpdateMap::new().inf_norm(), 0.0);
    }

    #[test]
    fn inf_norm_reflects_cancellation() {
        // +5 then -5 on the max element: the scan sees the summed state,
        // never a stale peak — for both representations.
        let mut m = UpdateMap::new();
        m.inc(K, &[5.0, 1.0]);
        assert_eq!(m.inf_norm(), 5.0);
        m.inc(K, &[-5.0, 0.0]);
        assert_eq!(m.inf_norm(), 1.0);
        let mut s = UpdateMap::new();
        s.inc_sparse(K, 16, &[(2, 5.0)]);
        s.inc_sparse(K, 16, &[(2, -5.0)]);
        assert_eq!(s.inf_norm(), 0.0);
    }

    #[test]
    fn drain_routes_and_resets() {
        let mut m = UpdateMap::new();
        m.inc((0, 0), &[1.0]);
        m.inc((0, 1), &[2.0]);
        m.inc_sparse((0, 2), 8, &[(4, 3.0)]);
        let routed = m.drain_routed(2, |k| (k.1 % 2) as usize);
        assert_eq!(routed[0].len(), 2); // rows 0, 2
        assert_eq!(routed[1].len(), 1); // row 1
        assert!(m.is_empty());
        assert_eq!(m.raw_incs(), 0);
        assert_eq!(m.inf_norm(), 0.0);
        // The sparse row crossed drain without densifying.
        let sparse_row = routed[0].iter().find(|(k, _)| *k == (0, 2)).unwrap();
        assert!(sparse_row.1.is_sparse());
    }

    #[test]
    fn coalescing_is_lossless() {
        // Sum of drained batches equals the sum of raw updates.
        let mut m = UpdateMap::new();
        let mut expect = vec![0.0f32; 3];
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..100 {
            let d: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
            for (e, x) in expect.iter_mut().zip(&d) {
                *e += x;
            }
            m.inc(K, &d);
        }
        let routed = m.drain_routed(1, |_| 0);
        let got = routed[0][0].1.clone().to_dense();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4);
        }
    }

    #[test]
    fn wire_bytes_shrink_for_sparse_pending() {
        let mut sparse = UpdateMap::new();
        sparse.inc_sparse(K, 1024, &[(1, 1.0), (2, -1.0)]);
        let mut dense_m = UpdateMap::new();
        dense_m.inc(K, &[1.0f32; 1024]);
        assert!(sparse.wire_bytes() * 10 < dense_m.wire_bytes());
    }
}
