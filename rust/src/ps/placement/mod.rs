//! Elastic shard plane: epoch-versioned key placement, migration planning,
//! and replica addressing.
//!
//! Until this subsystem existed, key -> shard routing was a stateless hash
//! (`Router`, absorbed here as epoch 0's strategy): every client and shard
//! agreed on the mapping with zero coordination, but the mapping could
//! never change. [`PlacementMap`] keeps that zero-coordination property
//! *within* an epoch and makes the mapping itself a versioned object that
//! a coordinator can advance mid-run:
//!
//!   * **Epoch 0** routes `key -> hash(key) % active` over the initially
//!     active primaries (`active <= primaries` provisioned shard
//!     processes; the rest idle with advancing table clocks, ready to
//!     take load).
//!   * A [`PlacementDelta`] advances the map to epoch N+1: it may *grow*
//!     the active set (old count must divide the new one, so the modular
//!     hash re-homes exactly the keys that land on the new shards — see
//!     [`PlacementDelta::affects`]) and/or pin individual hot keys to
//!     explicit owners via `moves`. Deltas are **conservative** by
//!     construction: a key's owner changes only if the delta names it —
//!     property-tested in `tests/proptest_invariants.rs`.
//!   * **Replicas**: each primary `p` may have `replicas_per` replica
//!     shards (ids `primaries + p*replicas_per + r`). Replicas receive
//!     the same per-worker FIFO update/clock stream as their primary and
//!     serve reads under the same SSP wait condition, so a replica read
//!     carries exactly the model's staleness guarantee (see
//!     `ClientPolicy::replica_reads`).
//!
//! # Live migration protocol (state machine)
//!
//! The coordinator announces one delta to every node; shards then move the
//! affected rows between themselves while training continues:
//!
//! ```text
//!            ToShard::MigrateBegin{epoch, at_clock, outgoing, incoming}
//!            ToWorker::Placement{delta}                (coordinator, t0)
//!                     |
//!   CLIENT   pending --(flush clock reaches at_clock)--> active epoch:
//!            flushes with clock >= at_clock route via the new map;
//!            registered keys re-Register with their new owners.
//!                     |
//!   SOURCE   armed ----(table clock reaches at_clock-1)---> handed-off:
//!            replay staged updates through at_clock-1, then per migrated
//!            key send ToShard::RowHandoff{key, vclock, payload, staged}
//!            to the new owner and drop the row; finish with
//!            ToShard::MigrateCommit{epoch} per destination. Afterwards
//!            the key set becomes a *forward table*: late GETs and
//!            updates from clients that have not switched yet are relayed
//!            to the new owner (conserving; the deterministic split is
//!            exact whenever the announcement precedes at_clock, which
//!            the coordinator guarantees by sending at launch).
//!                     |
//!   DEST     awaiting --(last RowHandoff arrives)--> settled:
//!            until then the destination *fences* at table clock
//!            at_clock-1 — staged updates with clock >= at_clock are not
//!            replayed, GETs for in-flight keys are queued, and the
//!            policy's commit hook is withheld — so the handed-off row
//!            (the source's fold through at_clock-1) always lands before
//!            any clock->at_clock update applies on top of it.
//! ```
//!
//! # Invariants carried per consistency model
//!
//!   * **Clock models (BSP/SSP/ESSP)**: a served row always reflects
//!     exactly the updates with clock <= served vclock. The source hands
//!     off its fold through `at_clock-1`; the destination fences until it
//!     holds that fold; every update with clock >= `at_clock` applies on
//!     the destination in the same sorted (clock, worker) order the
//!     deterministic replay would have used on the source — so a
//!     migrated deterministic run is bit-identical to an unmigrated one.
//!   * **Read-my-writes**: the overlay is keyed by `Key` client-side and
//!     never consults the map; pending updates buffered across the epoch
//!     switch flush to whichever shard owns the key at flush clock.
//!   * **Value models (VAP/AVAP)**: visibility debt is per *wave*, not
//!     per key — in-flight waves (and their revokes) stay with the shard
//!     that issued them until acked/retired, and NormReports go to every
//!     primary each flush, so every ledger's decay clock t keeps counting
//!     every flush. Nothing per-key needs to move; post-switch updates
//!     open waves on the new owner. Σ per-shard bounds still imply the
//!     global bound.

use super::types::{Clock, Key};
use crate::util::hash::{FxHashMap, FxHashSet};

/// Epoch-versioned key -> shard placement. Cheap to clone at migration
/// planning time; every client and shard holds one and advances it by
/// applying the same deltas in epoch order.
#[derive(Debug, Clone)]
pub struct PlacementMap {
    epoch: u64,
    /// Provisioned primary shards (fixed for the life of the cluster).
    primaries: usize,
    /// Primaries the hash currently routes over (<= primaries).
    active: usize,
    /// Replica shards per primary.
    replicas_per: usize,
    /// Keys pinned away from their hash home (explicit moves).
    overrides: FxHashMap<Key, usize>,
    /// Failed-over primaries: logical primary -> shard node now serving
    /// it (a promoted replica, or a spare node re-built from the WAL).
    /// Logical routing (`shard_of`) is unchanged by promotion; only the
    /// node address (`node_of`) moves.
    promoted: FxHashMap<usize, usize>,
    /// Re-replication: logical primary -> extra replica nodes attached at
    /// runtime (spares caught up from the serving node). Attached nodes
    /// receive the same duplicated per-worker FIFO stream as configured
    /// replicas and join the read fan-out.
    attached: FxHashMap<usize, Vec<usize>>,
    /// Nodes the coordinator has declared dead. Dead nodes are excluded
    /// from the read fan-out and are never valid promotion/attach targets.
    dead: FxHashSet<usize>,
}

impl PlacementMap {
    /// A fresh epoch-0 map: hash routing over `active` of `primaries`
    /// provisioned primaries, `replicas_per` replicas each.
    pub fn new(primaries: usize, active: usize, replicas_per: usize) -> Self {
        assert!(primaries > 0, "need at least one shard");
        assert!(
            (1..=primaries).contains(&active),
            "active shard count {active} out of range 1..={primaries}"
        );
        Self {
            epoch: 0,
            primaries,
            active,
            replicas_per,
            overrides: FxHashMap::default(),
            promoted: FxHashMap::default(),
            attached: FxHashMap::default(),
            dead: FxHashSet::default(),
        }
    }

    /// Hash routing over all `n` shards, no elasticity — the drop-in for
    /// the old `Router::new(n)`.
    pub fn flat(n_shards: usize) -> Self {
        Self::new(n_shards, n_shards, 0)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn primaries(&self) -> usize {
        self.primaries
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn replicas_per(&self) -> usize {
        self.replicas_per
    }

    /// Total shard nodes: primaries plus every replica.
    pub fn total_shards(&self) -> usize {
        self.primaries * (1 + self.replicas_per)
    }

    /// splitmix-style avalanche over (table, row) — epoch 0's strategy,
    /// inherited verbatim from the absorbed hash `Router`.
    #[inline]
    pub fn hash(key: &Key) -> u64 {
        let mut z = (key.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ key.1;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// The key's hash home under the current active set (ignoring moves).
    #[inline]
    pub fn hash_home(&self, key: &Key) -> usize {
        (Self::hash(key) % self.active as u64) as usize
    }

    /// Primary shard owning `key` at this epoch. This is the *logical*
    /// owner — stable across replica promotion; resolve the serving node
    /// with [`node_of`](Self::node_of) before addressing a message.
    #[inline]
    pub fn shard_of(&self, key: &Key) -> usize {
        self.overrides
            .get(key)
            .copied()
            .unwrap_or_else(|| self.hash_home(key))
    }

    /// The shard node currently serving logical shard `shard`: itself,
    /// unless a promotion redirected the primary to its replica. Applied
    /// at the client's send boundary, so all logical routing (hashing,
    /// per-primary arrays, wave `shard` fields) stays promotion-agnostic.
    #[inline]
    pub fn node_of(&self, shard: usize) -> usize {
        self.promoted.get(&shard).copied().unwrap_or(shard)
    }

    /// True if any primary has failed over to a replica.
    pub fn has_promotions(&self) -> bool {
        !self.promoted.is_empty()
    }

    /// Every failover on record: (logical primary, serving node).
    pub fn promotions(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.promoted.iter().map(|(&p, &n)| (p, n))
    }

    /// Runtime-attached replica nodes of logical primary `p` (empty for a
    /// primary that never lost a replica).
    pub fn attached_of(&self, primary: usize) -> &[usize] {
        self.attached.get(&primary).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Has the coordinator declared `node` dead?
    #[inline]
    pub fn is_dead(&self, node: usize) -> bool {
        self.dead.contains(&node)
    }

    /// Shard id of replica `r` of primary `p`.
    #[inline]
    pub fn replica_of(&self, primary: usize, r: usize) -> usize {
        debug_assert!(primary < self.primaries && r < self.replicas_per);
        self.primaries + primary * self.replicas_per + r
    }

    /// The primary a shard id serves (itself for primaries).
    #[inline]
    pub fn primary_of(&self, shard: usize) -> usize {
        if shard < self.primaries {
            shard
        } else {
            (shard - self.primaries) / self.replicas_per
        }
    }

    #[inline]
    pub fn is_replica(&self, shard: usize) -> bool {
        shard >= self.primaries
    }

    /// Read target for `key` under fan-out: `pick` selects round-robin
    /// over the primary, its configured replicas, and any runtime-attached
    /// replicas. A configured replica the coordinator has declared dead
    /// falls back to the owner (whose address `node_of` redirects if the
    /// owner itself failed over). With no replicas this is `shard_of`.
    #[inline]
    pub fn read_target(&self, key: &Key, pick: u64) -> usize {
        let owner = self.shard_of(key);
        let extra = self.attached_of(owner);
        let total = 1 + self.replicas_per + extra.len();
        if total == 1 {
            return owner;
        }
        match (pick % total as u64) as usize {
            0 => owner,
            r if r <= self.replicas_per => {
                let rep = self.replica_of(owner, r - 1);
                if self.dead.contains(&rep) {
                    owner
                } else {
                    rep
                }
            }
            r => extra[r - 1 - self.replicas_per],
        }
    }

    /// Advance to the delta's epoch. Panics on a protocol violation
    /// (epoch gap, non-divisible growth, out-of-range move target) — all
    /// coordinator bugs, not runtime conditions.
    pub fn apply(&mut self, delta: &PlacementDelta) {
        assert_eq!(
            delta.epoch,
            self.epoch + 1,
            "placement delta epoch {} applied to map at epoch {}",
            delta.epoch,
            self.epoch
        );
        if let Some(new_active) = delta.grow_active {
            let new_active = new_active as usize;
            assert!(
                new_active >= self.active && new_active <= self.primaries,
                "grow_active {new_active} out of range {}..={}",
                self.active,
                self.primaries
            );
            assert!(
                new_active % self.active == 0,
                "grow_active {new_active} must be a multiple of the current \
                 active count {} (modular re-homing is only conservative for \
                 divisible growth)",
                self.active
            );
            self.active = new_active;
        }
        for &(key, dst) in &delta.moves {
            let dst = dst as usize;
            assert!(
                dst < self.primaries,
                "move of {key:?} targets shard {dst}, but only {} primaries exist",
                self.primaries
            );
            self.overrides.insert(key, dst);
        }
        for &node in &delta.dead {
            let node = node as usize;
            self.dead.insert(node);
            // A dead node stops serving attached reads immediately.
            for nodes in self.attached.values_mut() {
                nodes.retain(|&n| n != node);
            }
        }
        if let Some((primary, node)) = delta.promote {
            let (primary, node) = (primary as usize, node as usize);
            assert!(
                !self.dead.contains(&node),
                "promotion of shard {primary} targets node {node}, which is dead"
            );
            // A configured node must be one of the primary's own replicas;
            // ids past the provisioned set are spares (WAL crash-recovery
            // fallback) and carry no chain constraint.
            assert!(
                node >= self.total_shards()
                    || (self.is_replica(node) && self.primary_of(node) == primary),
                "promotion of shard {primary} targets node {node}, which is not \
                 one of its replicas"
            );
            self.promoted.insert(primary, node);
        }
        if let Some((primary, node)) = delta.attach {
            let (primary, node) = (primary as usize, node as usize);
            assert!(
                primary < self.primaries,
                "attach names logical primary {primary}, but only {} exist",
                self.primaries
            );
            assert!(
                !self.dead.contains(&node),
                "attach of node {node} to shard {primary}: node is dead"
            );
            assert_ne!(
                node,
                self.node_of(primary),
                "attach of node {node} to shard {primary}: node already serves it"
            );
            let nodes = self.attached.entry(primary).or_default();
            if !nodes.contains(&node) {
                nodes.push(node);
            }
        }
        self.epoch = delta.epoch;
    }
}

/// One epoch advance: the unit the coordinator announces (wire:
/// `ToWorker::Placement`) and shards arm (`ToShard::MigrateBegin` carries
/// the same epoch/at_clock plus each shard's slice of the key movement).
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementDelta {
    /// The epoch this delta creates (previous + 1).
    pub epoch: u64,
    /// First worker clock whose flushes route via the new map. Clients
    /// switch exactly at this flush boundary; shards hand off once their
    /// table clock commits `at_clock - 1`.
    pub at_clock: Clock,
    /// Grow the hash-active primary set to this count (divisible growth).
    pub grow_active: Option<u32>,
    /// Fail logical primary `.0` over to node `.1` (one of its replicas,
    /// or a spare node past the provisioned set): all traffic for that
    /// primary re-addresses to the node, logical routing unchanged.
    pub promote: Option<(u32, u32)>,
    /// Attach node `.1` as a runtime replica of logical primary `.0`
    /// (re-replication). Fenced at `at_clock`: clients begin duplicating
    /// the per-worker FIFO stream to the node exactly at that flush
    /// boundary, matching the `ReplicaSync` cut the serving node ships.
    pub attach: Option<(u32, u32)>,
    /// Nodes the coordinator has confirmed dead (excluded from fan-out
    /// and from future promote/attach targets).
    pub dead: Vec<u32>,
    /// Explicit per-key moves (hot-key pinning / forced re-homing).
    pub moves: Vec<(Key, u32)>,
}

impl PlacementDelta {
    /// True when this delta needs no migration fence: it moves no keys
    /// between logical owners, only re-addresses a dead primary to its
    /// replica (and/or records deaths). Such a delta activates
    /// *immediately* on arrival — waiting for a fence clock could deadlock
    /// a client blocked reading from the dead node — and is safe
    /// fence-free because the replica has been fed the complete per-worker
    /// FIFO update/clock stream all along (there is no row state to move,
    /// hence nothing to fence). An `attach`, by contrast, is always fenced:
    /// clients must begin duplicating the update stream to the new replica
    /// exactly at `at_clock` so the `ReplicaSync` row cut (the serving
    /// node's fold through `at_clock - 1`) composes with the live stream
    /// without gaps or double-application.
    pub fn fence_free(&self) -> bool {
        self.moves.is_empty()
            && self.grow_active.is_none()
            && self.attach.is_none()
            && (self.promote.is_some() || !self.dead.is_empty())
    }

    /// Could this delta change `key`'s owner relative to `prev`? The
    /// conservativeness contract is the converse: an owner change implies
    /// `affects` (never the reverse — a move to the current owner is a
    /// no-op yet "affected").
    pub fn affects(&self, key: &Key, prev: &PlacementMap) -> bool {
        if self.moves.iter().any(|(k, _)| k == key) {
            return true;
        }
        match self.grow_active {
            // A key already pinned by an override ignores hash growth.
            Some(n) if !prev.overrides.contains_key(key) => {
                (PlacementMap::hash(key) % n as u64) as usize >= prev.active
            }
            _ => false,
        }
    }
}

/// One shard's slice of a migration: what it must send away and what it
/// must wait for (the payload of its `MigrateBegin`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardPlan {
    /// Keys leaving this shard, with their destination shard ids.
    pub outgoing: Vec<(Key, u32)>,
    /// Keys arriving at this shard (gate replay/read admission on these).
    pub incoming: Vec<Key>,
}

/// Plan a delta's row movement over an enumerable key universe: for every
/// key whose owner changes, records the (source -> destination) transfer
/// on the primary *and* on each replica chain (replica r of the old owner
/// hands its copy to replica r of the new owner — each chain's contents
/// stay internally consistent even in eager mode, where replica bits may
/// drift from the primary's by arrival order).
///
/// Returns one [`ShardPlan`] per shard id (indices `0..total_shards`),
/// empty plans included so every shard can be armed uniformly.
pub fn plan_shards(
    prev: &PlacementMap,
    delta: &PlacementDelta,
    keys: impl Iterator<Item = Key>,
) -> Vec<ShardPlan> {
    let mut next = prev.clone();
    next.apply(delta);
    let mut plans: Vec<ShardPlan> = vec![ShardPlan::default(); prev.total_shards()];
    for key in keys {
        let src = prev.shard_of(&key);
        let dst = next.shard_of(&key);
        if src == dst {
            continue;
        }
        plans[src].outgoing.push((key, dst as u32));
        plans[dst].incoming.push(key);
        for r in 0..prev.replicas_per() {
            let rsrc = prev.replica_of(src, r);
            let rdst = prev.replica_of(dst, r);
            plans[rsrc].outgoing.push((key, rdst as u32));
            plans[rdst].incoming.push(key);
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_map_is_deterministic_and_balanced() {
        let m = PlacementMap::flat(4);
        let mut counts = [0usize; 4];
        for t in 0..4u32 {
            for i in 0..1000u64 {
                let s = m.shard_of(&(t, i));
                assert!(s < 4);
                assert_eq!(s, m.shard_of(&(t, i)), "routing must be deterministic");
                counts[s] += 1;
            }
        }
        for &c in &counts {
            // 4000 keys over 4 shards: each within ±25% of fair share.
            assert!((750..=1250).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_shard_and_zero_rejected() {
        assert_eq!(PlacementMap::flat(1).shard_of(&(9, 1234)), 0);
        assert!(std::panic::catch_unwind(|| PlacementMap::flat(0)).is_err());
        assert!(std::panic::catch_unwind(|| PlacementMap::new(4, 0, 0)).is_err());
        assert!(std::panic::catch_unwind(|| PlacementMap::new(4, 5, 0)).is_err());
    }

    #[test]
    fn divisible_growth_rehomes_only_new_shard_keys() {
        let before = PlacementMap::new(4, 2, 0);
        let mut after = before.clone();
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 5,
            grow_active: Some(4),
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        after.apply(&delta);
        assert_eq!(after.epoch(), 1);
        assert_eq!(after.active(), 4);
        let mut moved = 0;
        for i in 0..4000u64 {
            let key = (0u32, i);
            let (a, b) = (before.shard_of(&key), after.shard_of(&key));
            if a != b {
                moved += 1;
                assert!(b >= 2, "re-homed key must land on a new shard, got {b}");
                assert!(delta.affects(&key, &before));
            } else {
                assert!(b < 2, "an unmoved key kept its old-active home");
            }
        }
        // Roughly half the keys land on the two new shards.
        assert!((1000..=3000).contains(&moved), "moved {moved} of 4000");
    }

    #[test]
    fn non_divisible_growth_is_rejected() {
        let mut m = PlacementMap::new(6, 2, 0);
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 1,
            grow_active: Some(3),
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        assert!(std::panic::catch_unwind(move || m.apply(&delta)).is_err());
    }

    #[test]
    fn explicit_moves_override_hash_and_persist_across_growth() {
        let mut m = PlacementMap::new(4, 2, 0);
        let key = (7u32, 42u64);
        m.apply(&PlacementDelta {
            epoch: 1,
            at_clock: 3,
            grow_active: None,
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![(key, 3)],
        });
        assert_eq!(m.shard_of(&key), 3);
        // Growth does not disturb a pinned key.
        m.apply(&PlacementDelta {
            epoch: 2,
            at_clock: 9,
            grow_active: Some(4),
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![],
        });
        assert_eq!(m.shard_of(&key), 3);
    }

    #[test]
    fn epoch_gap_is_rejected() {
        let mut m = PlacementMap::flat(2);
        let delta = PlacementDelta {
            epoch: 2, // map is at 0: epoch 1 is required next
            at_clock: 1,
            grow_active: None,
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        assert!(std::panic::catch_unwind(move || m.apply(&delta)).is_err());
    }

    #[test]
    fn replica_addressing_roundtrips() {
        let m = PlacementMap::new(3, 3, 2);
        assert_eq!(m.total_shards(), 9);
        for p in 0..3 {
            assert_eq!(m.primary_of(p), p);
            assert!(!m.is_replica(p));
            for r in 0..2 {
                let id = m.replica_of(p, r);
                assert!(m.is_replica(id));
                assert_eq!(m.primary_of(id), p);
            }
        }
        // Replica ids are distinct and cover primaries..total.
        let mut seen: Vec<usize> = (0..3)
            .flat_map(|p| (0..2).map(move |r| (p, r)))
            .map(|(p, r)| m.replica_of(p, r))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (3..9).collect::<Vec<_>>());
    }

    #[test]
    fn read_target_fans_over_primary_and_replicas() {
        let m = PlacementMap::new(2, 2, 2);
        let key = (0u32, 5u64);
        let owner = m.shard_of(&key);
        let targets: Vec<usize> = (0..6).map(|p| m.read_target(&key, p)).collect();
        assert_eq!(targets[0], owner);
        assert_eq!(targets[3], owner);
        assert_eq!(targets[1], m.replica_of(owner, 0));
        assert_eq!(targets[2], m.replica_of(owner, 1));
        // No replicas: always the owner.
        let flat = PlacementMap::flat(2);
        for p in 0..5 {
            assert_eq!(flat.read_target(&key, p), flat.shard_of(&key));
        }
    }

    #[test]
    fn promotion_redirects_node_but_not_logical_owner() {
        let mut m = PlacementMap::new(2, 2, 1);
        let key = (0u32, 5u64);
        let owner = m.shard_of(&key);
        assert_eq!(m.node_of(owner), owner);
        assert!(!m.has_promotions());
        let replica = m.replica_of(owner, 0);
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 0,
            grow_active: None,
            promote: Some((owner as u32, replica as u32)),
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        assert!(delta.fence_free());
        assert!(!delta.affects(&key, &m), "promotion moves no keys");
        m.apply(&delta);
        assert!(m.has_promotions());
        // Logical routing unchanged; the serving node moved.
        assert_eq!(m.shard_of(&key), owner);
        assert_eq!(m.node_of(owner), replica);
        // Other shards are untouched.
        assert_eq!(m.node_of(1 - owner), 1 - owner);
    }

    #[test]
    fn promotion_to_foreign_replica_is_rejected() {
        let mut m = PlacementMap::new(2, 2, 1);
        // Node 3 is shard 1's replica, not shard 0's.
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 0,
            grow_active: None,
            promote: Some((0, 3)),
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        assert!(std::panic::catch_unwind(move || m.apply(&delta)).is_err());
    }

    #[test]
    fn fence_free_only_for_pure_promotions() {
        let pure = PlacementDelta {
            epoch: 1,
            at_clock: 0,
            grow_active: None,
            promote: Some((0, 2)),
            attach: None,
            dead: vec![],
            moves: vec![],
        };
        assert!(pure.fence_free());
        let mixed = PlacementDelta {
            grow_active: Some(4),
            ..pure.clone()
        };
        assert!(!mixed.fence_free());
        let migration = PlacementDelta {
            promote: None,
            ..pure.clone()
        };
        assert!(!migration.fence_free());
        // Attach is always fenced, even alongside a promote.
        let attach = PlacementDelta {
            attach: Some((0, 4)),
            ..pure.clone()
        };
        assert!(!attach.fence_free());
        // A pure death record activates immediately.
        let death = PlacementDelta {
            promote: None,
            dead: vec![2],
            ..pure
        };
        assert!(death.fence_free());
    }

    fn delta(epoch: u64) -> PlacementDelta {
        PlacementDelta {
            epoch,
            at_clock: 0,
            grow_active: None,
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![],
        }
    }

    #[test]
    fn dead_replica_falls_back_to_owner_in_fanout() {
        let mut m = PlacementMap::new(2, 2, 1);
        let key = (0u32, 5u64);
        let owner = m.shard_of(&key);
        let rep = m.replica_of(owner, 0);
        assert_eq!(m.read_target(&key, 1), rep);
        m.apply(&PlacementDelta {
            dead: vec![rep as u32],
            ..delta(1)
        });
        assert!(m.is_dead(rep));
        // Fan-out degree is unchanged; the dead slot resolves to the owner.
        assert_eq!(m.read_target(&key, 0), owner);
        assert_eq!(m.read_target(&key, 1), owner);
    }

    #[test]
    fn attach_joins_read_fanout_and_survives_idempotent_reapply() {
        let mut m = PlacementMap::new(2, 2, 1);
        let key = (0u32, 5u64);
        let owner = m.shard_of(&key);
        let rep = m.replica_of(owner, 0);
        let spare = m.total_shards(); // first id past the provisioned set
        m.apply(&PlacementDelta {
            dead: vec![owner as u32],
            promote: Some((owner as u32, rep as u32)),
            ..delta(1)
        });
        m.apply(&PlacementDelta {
            attach: Some((owner as u32, spare as u32)),
            ..delta(2)
        });
        assert_eq!(m.attached_of(owner), &[spare]);
        // Round-robin now covers owner, configured replica, and the spare.
        let targets: Vec<usize> = (0..3).map(|p| m.read_target(&key, p)).collect();
        assert!(targets.contains(&spare));
        // The other primary's fan-out is untouched by the attach.
        let other_key = (0u32, (0..100).find(|i| m.shard_of(&(0, *i)) != owner).unwrap());
        for p in 0..4 {
            assert_ne!(m.read_target(&other_key, p), spare);
        }
        // Re-attaching the same node is idempotent.
        m.apply(&PlacementDelta {
            attach: Some((owner as u32, spare as u32)),
            ..delta(3)
        });
        assert_eq!(m.attached_of(owner), &[spare]);
    }

    #[test]
    fn spare_promotion_is_allowed_but_dead_target_is_rejected() {
        let mut m = PlacementMap::new(2, 2, 1);
        let spare = m.total_shards();
        // WAL crash-recovery fallback: promote shard 0 to a spare node.
        m.apply(&PlacementDelta {
            promote: Some((0, spare as u32)),
            ..delta(1)
        });
        assert_eq!(m.node_of(0), spare);
        // A node on the dead list can never be a promotion target.
        let mut m2 = PlacementMap::new(2, 2, 1);
        m2.apply(&PlacementDelta {
            dead: vec![2],
            ..delta(1)
        });
        let bad = PlacementDelta {
            promote: Some((0, 2)),
            ..delta(2)
        };
        assert!(std::panic::catch_unwind(move || m2.apply(&bad)).is_err());
    }

    #[test]
    fn death_detaches_a_previously_attached_node() {
        let mut m = PlacementMap::new(2, 2, 0);
        let spare = m.total_shards();
        m.apply(&PlacementDelta {
            attach: Some((0, spare as u32)),
            ..delta(1)
        });
        assert_eq!(m.attached_of(0), &[spare]);
        m.apply(&PlacementDelta {
            dead: vec![spare as u32],
            ..delta(2)
        });
        assert!(m.attached_of(0).is_empty());
        let key = (0u32, 5u64);
        for p in 0..4 {
            assert_ne!(m.read_target(&key, p), spare);
        }
    }

    #[test]
    fn plan_shards_pairs_sources_and_destinations() {
        let prev = PlacementMap::new(4, 2, 1);
        let forced = (9u32, 9u64);
        let forced_src = prev.shard_of(&forced);
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 4,
            grow_active: Some(4),
            promote: None,
            attach: None,
            dead: vec![],
            moves: vec![(forced, 1 - forced_src as u32)], // hop 0<->1: a move growth would not cause
        };
        let keys: Vec<Key> = (0..64u64).map(|i| (0, i)).chain([forced]).collect();
        let plans = plan_shards(&prev, &delta, keys.iter().copied());
        assert_eq!(plans.len(), prev.total_shards());
        let mut next = prev.clone();
        next.apply(&delta);
        // Every outgoing entry has a matching incoming entry, and the pair
        // agrees with the before/after maps — on primaries and replicas.
        let mut transfers = 0usize;
        for (src, plan) in plans.iter().enumerate() {
            for &(key, dst) in &plan.outgoing {
                transfers += 1;
                let dst = dst as usize;
                assert!(plans[dst].incoming.contains(&key), "{key:?} not expected at {dst}");
                assert_eq!(prev.primary_of(src), prev.shard_of(&key));
                assert_eq!(prev.primary_of(dst), next.shard_of(&key));
                // Replica chains map replica r -> replica r.
                assert_eq!(prev.is_replica(src), prev.is_replica(dst));
            }
        }
        assert!(transfers >= 2, "the forced move and its replica must transfer");
        // The forced key moved on both its primary and its replica chain.
        assert!(plans[forced_src].outgoing.iter().any(|(k, _)| *k == forced));
        assert!(plans[prev.replica_of(forced_src, 0)]
            .outgoing
            .iter()
            .any(|(k, _)| *k == forced));
    }
}
