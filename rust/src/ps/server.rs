//! Cluster assembly: builds shards + data plane + clients, runs an
//! application across P workers, and collects the run report.
//!
//! This is the launcher the paper's "each physical machine runs one
//! ESSPTable process" maps onto: here, shard threads play the server
//! processes, worker threads the computation threads, and a pluggable
//! transport the Ethernet between them.
//!
//! # Transport
//!
//! The paper's testbed boundary — processes exchanging bytes over
//! 1 Gbps Ethernet — is substituted by [`crate::transport`]:
//!
//! * `ClusterConfig::transport == TransportSel::Sim` (default) routes all
//!   traffic through the in-process `sim::net` router with modeled
//!   latency/bandwidth/FIFO links;
//! * `TransportSel::Tcp` runs the *same* worker and shard threads over
//!   real loopback TCP sockets: frames are `len:u32 | src | dst | kind |
//!   body`, little-endian, preceded per connection by an `"ESSPWIR1"`
//!   magic + version handshake (see `transport::wire` for the full
//!   layout). Byte accounting is identical in both modes because the
//!   SimNet model charges the codec's exact frame sizes; both planes also
//!   coalesce frames the same way (the TCP per-peer writer batches each
//!   queue drain into one vectored write, the SimNet router drains its
//!   intake in matching batches — see their module docs).
//!
//! # Delta push waves (wire v7)
//!
//! Eager pushes (`ToWorker::Push` / `VapPush`) are no longer
//! snapshot-only. Each shard keeps, per pushed key, a *chain token* per
//! reader — the vclock (ESSP) or wave seq (VAP) of the last wave that
//! carried the key to that worker — plus a `WaveLog` of the exact ordered
//! [`crate::ps::types::RowDelta`]s folded into the row since the last
//! wave consumed it. A reader holding an intact chain receives just those
//! deltas tagged with the base token; the client replays them onto its
//! cached copy in wire order, reproducing the shard row **bit-for-bit**
//! (the sequence is never coalesced — f32 addition is order-sensitive).
//! For sparse updates this ships `O(nnz)` instead of `O(row_len)` bytes,
//! which is the paper's LDA/MF regime.
//!
//! The downgrade rules keep the chain honest — any event that makes a
//! reader's cached copy unknowable breaks its chain (token reset), and
//! the next wave re-seeds it with a full snapshot:
//!
//! * first contact (no token yet), pull replies, fresh registrations,
//! * the wave's own *writers* (their read-my-writes fold already holds
//!   their update locally; a delta would double-count it),
//! * VAP waves that *skip* a reader (the skipped copy missed that wave's
//!   content, so a later delta base would be stale),
//! * migration departure/arrival of the key, crash rebuild, promotion.
//!
//! Clients certify each delta wave against the cached row's own chain
//! token and source-shard tag (the PR-5 placement tags); a mismatched or
//! missing base discards the cached copy and falls back to a primary
//! pull. Deterministic-mode per-update waves preview *staged* state and
//! always ship snapshots, so staged-replay bit-reproducibility is
//! untouched. `rows_pushed_delta` (per shard) and
//! `rows_delta_folded` / `rows_delta_discarded` (per client) count the
//! fast path and its fallbacks.
//!
//! Fully separate OS processes (one per shard / worker, the paper's
//! actual deployment shape) are launched via the `serve-shard` /
//! `run-worker` / `run-cluster` CLI subcommands, which reuse
//! [`init_rows`] / [`table_row_lens`] so every process derives identical
//! initial state. With `ClusterConfig::deterministic` (and the same
//! seed), a BSP run produces bit-identical final parameters whether it
//! runs in-process, over loopback TCP, or as a multi-process cluster.
//!
//! # Elasticity (the `ps::placement` shard plane)
//!
//! The assembled cluster is no longer a fixed shard set:
//!
//! * **Provisioned vs active.** `shards` primaries are launched; the
//!   initial placement hash-routes over `active_shards` of them (default
//!   all). Idle primaries still receive every ClockTick, so their table
//!   clocks track the cluster and they can take ownership mid-run.
//! * **Live migration.** `migration: Some(MigrationSpec)` schedules an
//!   epoch advance: the coordinator arms every node at launch (direct
//!   control-plane channels, like Shutdown), clients re-route at the
//!   `at_clock` flush boundary, and source shards hand rows + staged
//!   deltas to the new owners over the data plane once their table clock
//!   commits `at_clock - 1`. Under `deterministic`, a migrated run's
//!   final parameters are bit-identical to an unmigrated one's: each
//!   key's updates fold in the same global (clock, worker) order, merely
//!   on a different shard after the fence.
//! * **Replicas.** `replicas: N` attaches N pull-only replicas per
//!   primary (shard ids `shards..shards*(1+N)`), fed the same FIFO
//!   update/clock stream client-side. Policies whose read admission is
//!   the clock window fan pulls over primary + replicas
//!   (`RunReport::replica_hits` counts the fan-out); the replica holds
//!   each Get until its own table clock meets the model's bound, so the
//!   staleness guarantee is unchanged. Final `table_rows` merge the
//!   primaries only; `replica_rows` exposes the replica copies.
//!
//! # Durability & Failover (`ps::durability` + `sim::fault`)
//!
//! With `ClusterConfig::durability` set, every shard node — primaries and
//! replicas alike — owns a *generation pair* on disk: a crash-atomic row
//! checkpoint plus a write-ahead log of every state-bearing `ToShard`
//! message appended **before** it is processed. WAL frames use the
//! transport's wire codec verbatim, so the on-disk format and the
//! on-the-wire format are one source of truth (and the WAL reader
//! inherits the codec's defensive decoding). The fsync policy
//! (`always` | `commit` | `off`) decides when appends become durable;
//! `commit` (the default) syncs once per table-clock commit so the
//! durable prefix always ends at a commit boundary. Compaction every
//! `compact_every` commits folds the log into a fresh checkpoint
//! generation and deletes the old pair — a crash at any instant leaves at
//! least one complete pair to recover from.
//!
//! **Crash recovery.** A `crash=sI@C` fault (see [`crate::sim::fault`]
//! for the full `--fault-plan` grammar) makes shard `I` drop all volatile
//! state at table clock `C` and rebuild itself from checkpoint + WAL
//! tail. Under `deterministic`, replayed updates fold in the same global
//! (clock, worker) order as live ones, so a crashed-and-recovered run's
//! final parameters are bit-identical to an undisturbed run's — for every
//! consistency model. Each model's staleness bound is a property of the
//! *client* read gate and the server's clock bookkeeping, both of which
//! the log reconstructs exactly: BSP/SSP/ESSP window bounds, the Async
//! free-running contract, and the VAP/AVAP value bounds all hold across a
//! recovery (recovered rows re-enter VAP certification conservatively —
//! every row is re-pushed dirty, never silently under-certified).
//!
//! **Self-healing failover.** A `kill=sI@C` fault makes node `I` die
//! permanently at clock `C` *without* dumping — and without any dying
//! act. Recovery is driven entirely by the coordinator's failure
//! detector ([`crate::ps::failover`]), a control loop that subscribes to
//! the transport's `PeerEvent` stream and heartbeats every node with
//! `StatsPull` probes. Each node walks the detector's state machine:
//!
//! ```text
//!   healthy --(missed_k polls, suspect_after silent)--> suspected
//!   suspected --(unclean peer_down | 2x suspect_after)--> dead
//!   dead, was serving a partition --> promoted:
//!       a live configured replica (fence-free Promote delta), else a
//!       spare rebuilt from the dead node's WAL (double-failure path;
//!       clients re-send their `resend_window` tail), else the loud
//!       `failover_unreplicated` verdict (the partition is DOWN).
//!   promoted --(re_replicate && a spare is free)--> re-replicated:
//!       the spare gates (`ReplicaCatchUp`), clients start duplicating
//!       the FIFO stream at the fenced attach boundary, the serving
//!       node cuts its row copy (`ReplicaSync`) at the same clock, and
//!       the spare joins the read fan-out.
//! ```
//!
//! The promoted node adopts the dead primary's logical shard id, swaps
//! its pull-only policy for the model's real server policy, marks every
//! row dirty (conservative re-certification), and relays the delta to
//! all workers. Clients re-route the partition at the next inbox drain —
//! updates they duplicated to the replica all along mean the switch
//! loses nothing — and the promoted node's final dump is authoritative
//! for the partition. In-flight GETs against the dead node are cleared
//! and retried by the client (`failover_stall` counts them). Killing a
//! primary requires a reachable failover target (`replicas >= 1`, or
//! durability plus a provisioned spare) and no concurrent migration:
//! both planes advance the placement epoch and their fences are not
//! ordered against each other.
//!
//! # Observability (the `crate::telemetry` live plane)
//!
//! Every node carries a fixed-layout registry of relaxed atomic
//! counters/gauges/log2-histograms, updated inline on the hot paths
//! (one relaxed RMW per event, no locks, no allocation) and readable
//! from any thread:
//!
//! * **Shards** ([`crate::ps::shard::ShardMetrics`], node `shardN`):
//!   GETs served/queued/forwarded, updates applied/staged/forwarded,
//!   commits, push waves + `wave_fanout` histogram, migration row
//!   counts, promotions, the `queue_depth` gauge (staged batches +
//!   queued GETs, with high-water mark) and `wal_append_ns` /
//!   `wal_fsync_ns` latency histograms.
//! * **Workers** ([`crate::ps::client::ClientMetrics`], node
//!   `workerN`): GETs, cache hits/misses, pulls (replica fan-out
//!   included), pushes, the `read_latency_ns` histogram,
//!   `read_stall_ns` / `vap_stall_ns` blocked time, and the
//!   `staleness_violations` tripwire — reads *admitted* below the
//!   model's bound, provably zero for the clock-bounded models and
//!   asserted zero in the integration suites.
//! * **Transports**: per-link frame/byte counters, dial retries,
//!   writer-queue backpressure events, and fault-plan verdict counts.
//!
//! Snapshots are flattened to `(name, value)` pairs (histograms as
//! `name#count` / `name#sum` / `name#b<i>` buckets — see
//! `telemetry::registry`) and travel three ways: end-of-run into
//! [`RunReport`] (read-latency quantiles, per-shard queue high-water
//! marks), over the wire as `ToShard::StatsPull` →
//! `ToWorker::StatsReport` (wire v6) so `run-cluster` aggregates live
//! cluster-wide state, and through the `--metrics-addr` admin socket
//! (`GET /json`, Prometheus-style `GET /metrics`) that `ps-top` polls.
//!
//! **Causal request spans** (`telemetry::spans`, wire v9). With
//! `ClusterConfig::spans` attached and `span_sample = n`, one of every
//! `n` client-issued Get pulls and primary Update batches (plus
//! shard-originated push frames) carries a 12-byte span context
//! (`trace_id | parent`) on the wire; every hop appends a timed segment
//! to the shared [`crate::telemetry::spans::SpanRing`] —
//! `client_issue`, `transport_enqueue`, `transport_flush`,
//! `shard_queue`, `policy_admission`, `apply`/`serve`, `reply_decode`,
//! `cache_install` — giving a causal, cross-node timeline of where a
//! sampled request spent its life. Rings dump as Chrome trace-event
//! JSON (`--trace-spans`, viewable in `chrome://tracing` / Perfetto)
//! and fold into [`RunReport::span_segments`] as per-segment
//! histograms. Unsampled frames encode byte-identically to wire v8
//! (zero overhead), and sampling itself is a deterministic per-node
//! counter — never a protocol input.
//!
//! **Hot-key & staleness profiling.** `ClusterConfig::hot_key_k`
//! arms a space-saving top-K sketch per shard
//! ([`crate::telemetry::profile::HotKeySketch`]) counting per-key GET
//! and update-row traffic; the top keys ride the ordinary registry
//! snapshot (`hot.g.<t>:<r>` / `hot.u.<t>:<r>` entries) into
//! `StatsReport`, the admin scrape, and `ps-top`'s hot-key panel.
//! Client-side, every admitted read records its staleness *lag* (own
//! clock minus the served copy's guaranteed vclock, clamped at zero)
//! into a log2 histogram — [`RunReport::staleness_lag`] — so the per-
//! model staleness distribution is observable live, not only from the
//! end-of-run `StalenessHist`.
//!
//! The event-trace ring (`--trace-out`, `telemetry::trace`) is the
//! flight recorder for *rare* lifecycle events, JSONL-dumped at exit:
//!
//! | kind | node | meaning |
//! |------|------|---------|
//! | `placement_announced` / `placement_activate` | worker | epoch held / made live |
//! | `migrate_begin` / `migrate_handoff` / `migrate_release` | shard | fence armed / rows shipped / held commit released |
//! | `promotion` / `placement_relay` | shard | replica takeover / delta relayed to workers |
//! | `replica_sync` / `replica_sync_cut` | shard | re-replication source armed / rows copied |
//! | `replica_catchup` / `replica_catchup_done` | shard | spare gated (or WAL-grafted) / gate released |
//! | `failover_suspect` / `failover_dead` | coordinator | detector escalations |
//! | `failover_promote` / `failover_rereplicate` / `failover_unreplicated` | coordinator | recovery actions |
//! | `failover_stall` / `failover_resend` / `replica_attach` | worker | cleared GETs / WAL-gap resend / fan-out join |
//! | `wal_generation` / `crash_recover` | shard | log roll / rebuild from disk |
//! | `fault_pause` / `fault_crash` / `fault_kill` | shard | fault-plan firings |
//! | `peer_up` / `peer_down` / `backpressure` (debug) | transport | lifecycle (both backends emit `peer_down`) |
//!
//! **Determinism guarantee.** Telemetry is strictly out-of-band:
//! `StatsPull`/`StatsReport` are never WAL-logged, never staged, and
//! touch no protocol state; registries and traces only *observe*. A
//! deterministic run's final parameters are bit-identical with
//! telemetry and tracing enabled (proven by
//! `tests/integration_telemetry.rs` against the transport-matrix and
//! durability suites).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::client::{ClientConfig, ClientStats, PsClient};
use super::consistency::Consistency;
use super::durability::DurabilityConfig;
use super::failover::{Detector, FailoverConfig, FailoverReport};
use super::msg::{ToShard, ToWorker};
use super::placement::{plan_shards, PlacementDelta, PlacementMap};
use super::shard::{Shard, ShardFinal, ShardStats};
use super::types::{Clock, Key, RowId, TableId};
use crate::metrics::convergence::ConvergenceLog;
use crate::metrics::staleness::StalenessHist;
use crate::metrics::timeline::Timeline;
use crate::sim::fault::{FaultInjector, FaultPlan};
use crate::sim::net::NetConfig;
use crate::sim::straggler::StragglerModel;
use crate::telemetry::registry::HistSnapshot;
use crate::telemetry::spans::SpanRing;
use crate::telemetry::trace::TraceRing;
use crate::transport::{Fabric, TransportSel};
use crate::util::rng::Rng;

/// One application instance per worker. `run_clock` performs one clock of
/// work against the PS and optionally reports a local convergence metric.
pub trait PsApp: Send + 'static {
    fn run_clock(&mut self, ps: &mut PsClient, clock: Clock) -> Option<f64>;
}

impl<F> PsApp for F
where
    F: FnMut(&mut PsClient, Clock) -> Option<f64> + Send + 'static,
{
    fn run_clock(&mut self, ps: &mut PsClient, clock: Clock) -> Option<f64> {
        self(ps, clock)
    }
}

/// A mid-run placement change (`ClusterConfig::migration`): announced by
/// the coordinator at launch, it takes effect *live* — clients switch
/// their routing at the `at_clock` flush boundary while source shards
/// hand the affected rows (plus staged deltas and clock state) to their
/// new owners over the data plane. See `ps::placement` for the protocol.
#[derive(Debug, Clone)]
pub struct MigrationSpec {
    /// First worker clock owned by the new placement (must be >= 1).
    pub at_clock: Clock,
    /// Grow the hash-active primary set to this count (the current
    /// active count must divide it, e.g. 2 -> 4).
    pub grow_to: Option<usize>,
    /// Explicit per-key moves (hot-key pinning / forced re-homing).
    pub moves: Vec<(Key, usize)>,
}

/// Cluster-level configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    /// Provisioned primary shards. All of them run (and their table
    /// clocks advance) from launch; only the *active* ones own keys
    /// under the initial placement.
    pub shards: usize,
    /// Primaries the initial placement hash-routes over (0 = all). The
    /// rest idle until a migration grows the active set onto them.
    pub active_shards: usize,
    /// Replica shards per primary. Replicas receive the same per-worker
    /// FIFO update/clock stream (duplicated client-side) and serve pull
    /// reads for policies whose admission is the clock window
    /// (`ClientPolicy::replica_reads`) — hot-read fan-out at the model's
    /// own staleness bound.
    pub replicas: usize,
    /// A live migration to run mid-run, if any.
    pub migration: Option<MigrationSpec>,
    pub consistency: Consistency,
    pub net: NetConfig,
    pub straggler: StragglerModel,
    pub cache_capacity: usize,
    pub read_my_writes: bool,
    /// Virtual per-clock compute duration: each clock is padded (by
    /// sleeping) to at least this long. This emulates the paper's regime —
    /// long, *uniform* compute per clock on dedicated cores — on a
    /// timeshared testbed where raw CPU-bound clocks would otherwise have
    /// scheduler-driven duration noise with no analogue in the modeled
    /// cluster (DESIGN.md §Substitutions). `None` = run at raw speed.
    pub virtual_clock: Option<Duration>,
    /// Which data plane carries PS traffic (see module docs, § Transport).
    pub transport: TransportSel,
    /// Shards defer updates and replay them in (clock, worker) order at
    /// each table-clock commit, making final parameters bit-reproducible
    /// across runs and transports (float summation order is fixed) for
    /// *every* consistency model — value-bounded policies fire their
    /// eager waves at update receipt (with staged-sum preview contents),
    /// so visibility never gates on the commit. Off by default: eager
    /// application propagates uncommitted freshness, which the Async/VAP
    /// read dynamics prefer — for Async in particular, staging commits-
    /// gates all read freshness, so enable it there only when
    /// reproducibility genuinely outranks the Hogwild dynamics (the CLI
    /// cluster subcommands default it off for Async for this reason).
    pub deterministic: bool,
    /// Force every push wave to ship full row snapshots instead of
    /// wire-v7 delta chains (see module docs, § Delta push waves). A
    /// delta run must be bit-identical to a forced-snapshot run — this
    /// flag is the A/B control of that equivalence suite, and the escape
    /// hatch if a workload ever prefers snapshot traffic.
    pub snapshot_waves: bool,
    /// Durability plane: when set, every shard node (primaries and
    /// replicas) keeps a generation-paired checkpoint + write-ahead log
    /// under `dir` and can recover `crash` faults from it (see module
    /// docs, § Durability & Failover).
    pub durability: Option<DurabilityConfig>,
    /// Seeded, replayable fault schedule (`sim::fault`): link faults
    /// apply inside the data plane, shard faults fire at table-clock
    /// commit boundaries.
    pub faults: FaultPlan,
    /// Failure-detector tuning (heartbeat cadence, suspicion thresholds,
    /// re-replication). The detector thread only runs when the fault
    /// plan kills nodes or spares are provisioned — an undisturbed run
    /// carries zero heartbeat traffic.
    pub failover: FailoverConfig,
    /// Spare shard nodes provisioned beyond the placement (ids
    /// `total_shards..total_shards + spare_nodes`): empty, pull-only,
    /// durability-enabled, available to the detector as WAL-fallback
    /// promotion targets and re-replication attach targets. With
    /// `failover.re_replicate` and `spare_nodes == 0`, one spare is
    /// provisioned per kill fault.
    pub spare_nodes: usize,
    /// Clocks of flushed updates each client keeps re-sendable for
    /// WAL-fallback failover (0 = keep nothing). Set it at least one
    /// past the model's staleness bound when running with kill faults
    /// over spares — the client re-sends this tail to a promoted spare,
    /// whose replay floors drop whatever the disk already held.
    pub resend_window: Clock,
    pub seed: u64,
    /// Telemetry: every `n` CLOCKs each worker polls every live shard
    /// node with a `StatsPull` (0 = never). Out-of-band; see module
    /// docs, § Observability.
    pub stats_pull_every: Clock,
    /// Event-trace flight recorder shared by every node of this
    /// in-process cluster (`None` = tracing off); see § Observability.
    pub trace: Option<Arc<TraceRing>>,
    /// Request-span recorder shared by every node and both transports
    /// (`None` = spans off); see § Observability. Strictly out-of-band.
    pub spans: Option<Arc<SpanRing>>,
    /// Sample one of every `n` span-eligible frames (0 = none even with
    /// a ring attached). Deterministic per-node counters, so the same
    /// run samples the same frames every time.
    pub span_sample: u64,
    /// Track the top-K hottest keys per shard (space-saving sketch;
    /// 0 = off). See § Observability.
    pub hot_key_k: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            shards: 2,
            active_shards: 0,
            replicas: 0,
            migration: None,
            consistency: Consistency::Essp { s: 1 },
            net: NetConfig::instant(),
            straggler: StragglerModel::None,
            cache_capacity: 0,
            read_my_writes: true,
            virtual_clock: None,
            transport: TransportSel::Sim,
            deterministic: false,
            snapshot_waves: false,
            durability: None,
            faults: FaultPlan::default(),
            failover: FailoverConfig::default(),
            spare_nodes: 0,
            resend_window: 0,
            seed: 42,
            stats_pull_every: 0,
            trace: None,
            spans: None,
            span_sample: 0,
            hot_key_k: 0,
        }
    }
}

/// Declarative table spec; rows are initialized before launch.
pub struct TableSpec {
    pub table: TableId,
    pub rows: RowId,
    /// Uniform row length, or `usize::MAX` for variable-length rows (e.g.
    /// the LM parameter table where row r holds tensor r); variable-length
    /// tables cannot be used with `inc_sparse`.
    pub row_len: usize,
    /// Initializer: (row id, rng) -> payload.
    pub init: Box<dyn Fn(RowId, &mut Rng) -> Vec<f32>>,
}

impl TableSpec {
    pub fn zeros(table: TableId, rows: RowId, row_len: usize) -> Self {
        Self {
            table,
            rows,
            row_len,
            init: Box::new(move |_, _| vec![0.0; row_len]),
        }
    }

    pub fn random_normal(table: TableId, rows: RowId, row_len: usize, scale: f32) -> Self {
        Self {
            table,
            rows,
            row_len,
            init: Box::new(move |_, rng| (0..row_len).map(|_| scale * rng.normal_f32()).collect()),
        }
    }
}

/// Everything measured during a run.
pub struct RunReport {
    pub wall: Duration,
    pub staleness: StalenessHist,
    pub per_worker_staleness: Vec<StalenessHist>,
    pub timelines: Vec<Timeline>,
    pub convergence: ConvergenceLog,
    pub client_stats: Vec<ClientStats>,
    pub shard_stats: Vec<ShardStats>,
    pub net_messages: u64,
    pub net_bytes: u64,
    /// Final table contents (merged across the *primary* shards — the
    /// authoritative copies).
    pub table_rows: HashMap<Key, Vec<f32>>,
    /// Final contents of each replica shard (empty when `replicas == 0`;
    /// index = replica shard id - primaries). Under deterministic mode a
    /// replica's rows are bit-identical to its primary's.
    pub replica_rows: Vec<HashMap<Key, Vec<f32>>>,
    /// Pulls served by replica shards, summed over clients (replica read
    /// fan-out; 0 without replicas).
    pub replica_hits: u64,
    /// Value-bounded models (VAP/AVAP) only: total reader stall time and
    /// stalled read count, aggregated across the clients (the read gate
    /// is client-side; there is no process-global tracker).
    pub vap_stall: Option<(Duration, u64)>,
    /// Read-latency histogram merged across all clients (wall ns per
    /// admitted GET, miss round-trips included); p50/p99/p999 via
    /// [`HistSnapshot::quantile`]. See module docs, § Observability.
    pub read_latency: HistSnapshot,
    /// Staleness-lag histogram merged across all clients: per admitted
    /// read, this worker's clock minus the served copy's guaranteed
    /// vclock, clamped at zero (log2 buckets). The live-plane mirror of
    /// the signed `staleness` differential above, per consistency model.
    pub staleness_lag: HistSnapshot,
    /// Per-segment span-duration histograms (µs), name-sorted — present
    /// only when `ClusterConfig::spans` was attached. See module docs,
    /// § Observability.
    pub span_segments: Vec<(String, HistSnapshot)>,
    /// Staleness-bound tripwire, summed over clients — reads admitted
    /// below the model's bound. Provably zero for BSP/SSP/ESSP.
    pub staleness_violations: u64,
    /// Per shard node: high-water mark of the backlog gauge (staged
    /// batches + queued GETs). Killed nodes report 0 (they never dump).
    pub shard_queue_hwm: Vec<u64>,
    /// Per shard node: the full flattened end-of-run registry snapshot
    /// (`telemetry::registry` entry convention) — WAL latency
    /// histograms and the rest, for consumers beyond the summary line.
    pub shard_metrics: Vec<Vec<(String, u64)>>,
    /// First failover's window (ms from the victim's last proof of life
    /// to the promotion being emitted); `None` when nothing failed over.
    pub failover_ms: Option<u64>,
    /// The failure detector's full account of the run (`None` when no
    /// detector thread ran). `failover.unreplicated` being non-empty
    /// means a partition was lost — callers should treat that as a
    /// failed run.
    pub failover: Option<FailoverReport>,
}

impl RunReport {
    pub fn comm_fraction(&self) -> f64 {
        let comp: f64 = self.timelines.iter().map(|t| t.total_comp().as_secs_f64()).sum();
        let comm: f64 = self.timelines.iter().map(|t| t.total_comm().as_secs_f64()).sum();
        if comp + comm == 0.0 {
            0.0
        } else {
            comm / (comp + comm)
        }
    }

    /// Reassemble a table into a dense matrix (rows x row_len).
    pub fn table_matrix(&self, table: TableId, rows: RowId, row_len: usize) -> Vec<Vec<f32>> {
        (0..rows)
            .map(|r| {
                self.table_rows
                    .get(&(table, r))
                    .cloned()
                    .unwrap_or_else(|| vec![0.0; row_len])
            })
            .collect()
    }
}

/// Deterministically initialize table rows: calls each spec's `init` for
/// *every* row of every table in declaration order against one shared rng
/// stream, handing each `(key, payload)` to `sink`. Multi-process shards
/// must consume the stream identically regardless of which rows they own,
/// so a process filters inside `sink` rather than skipping calls.
pub fn init_rows(tables: &[TableSpec], seed: u64, mut sink: impl FnMut(Key, Vec<f32>)) {
    let mut rng = Rng::with_stream(seed, 0x7ab1e);
    for spec in tables {
        let variable = spec.row_len == usize::MAX;
        for r in 0..spec.rows {
            let data = (spec.init)(r, &mut rng);
            assert!(
                variable || data.len() == spec.row_len,
                "init length mismatch on table {} row {r}",
                spec.table
            );
            sink((spec.table, r), data);
        }
    }
}

/// Uniform row length per table (variable-length tables excluded) — the
/// registry shards use to serve GETs racing row materialization.
pub fn table_row_lens(tables: &[TableSpec]) -> HashMap<TableId, usize> {
    tables
        .iter()
        .filter(|s| s.row_len != usize::MAX)
        .map(|s| (s.table, s.row_len))
        .collect()
}

/// A configured-but-not-yet-running cluster.
pub struct Cluster {
    cfg: ClusterConfig,
    tables: Vec<TableSpec>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.workers > 0 && cfg.shards > 0);
        Self {
            cfg,
            tables: Vec::new(),
        }
    }

    pub fn add_table(&mut self, spec: TableSpec) -> &mut Self {
        self.tables.push(spec);
        self
    }

    /// Run `apps` (one per worker) for `clocks` ticks each; returns the
    /// report. Consumes the cluster.
    pub fn run(self, apps: Vec<Box<dyn PsApp>>, clocks: u64) -> RunReport {
        let cfg = self.cfg;
        assert_eq!(
            apps.len(),
            cfg.workers,
            "need exactly one app instance per worker"
        );
        let active = if cfg.active_shards == 0 {
            cfg.shards
        } else {
            cfg.active_shards
        };
        let placement = PlacementMap::new(cfg.shards, active, cfg.replicas);
        let total_shards = placement.total_shards();

        // Validate the fault schedule up front: a plan naming an unknown
        // shard is a configuration error, not a runtime surprise.
        for f in &cfg.faults.shards {
            assert!(
                f.shard < total_shards,
                "fault plan targets unknown shard {} ({} nodes)",
                f.shard,
                total_shards
            );
        }
        let killed = cfg.faults.killed_shards();
        // Spare pool: explicit, or (re-replication on) one per kill.
        let spares_n = if cfg.spare_nodes > 0 {
            cfg.spare_nodes
        } else if cfg.failover.re_replicate {
            killed.len()
        } else {
            0
        };
        let total_nodes = total_shards + spares_n;
        let killed_primaries = killed.iter().any(|&k| k < cfg.shards);
        if killed_primaries {
            assert!(
                cfg.replicas >= 1 || (cfg.durability.is_some() && spares_n > 0),
                "killing a primary needs a reachable failover target: \
                 replicas >= 1, or durability plus a provisioned spare \
                 (WAL-fallback promotion)"
            );
            assert!(
                cfg.migration.is_none(),
                "kill faults cannot combine with a migration: both advance the \
                 placement epoch and their fences are unordered"
            );
        }
        // The failure detector runs only when something can die or a
        // spare waits for work; undisturbed runs carry no heartbeats.
        let failover_active = !killed.is_empty() || spares_n > 0;

        // Channels: per-worker and per-shard-node inboxes (every
        // provisioned primary, every replica, and every spare is a live
        // node).
        let mut worker_tx: Vec<Sender<ToWorker>> = Vec::new();
        let mut worker_rx: Vec<Receiver<ToWorker>> = Vec::new();
        for _ in 0..cfg.workers {
            let (tx, rx) = channel();
            worker_tx.push(tx);
            worker_rx.push(rx);
        }
        let mut shard_tx: Vec<Sender<ToShard>> = Vec::new();
        let mut shard_rx: Vec<Receiver<ToShard>> = Vec::new();
        for _ in 0..total_nodes {
            let (tx, rx) = channel();
            shard_tx.push(tx);
            shard_rx.push(rx);
        }

        // Arm the scheduled migration BEFORE any traffic exists, so every
        // node holds the delta ahead of clock 0 and the epoch switch is a
        // pure function of worker clocks (the deterministic split). Like
        // Shutdown, arming uses the coordinator's direct control-plane
        // channels; the row handoffs themselves ride the data plane.
        if let Some(mig) = &cfg.migration {
            assert!(
                mig.at_clock >= 1,
                "migration at_clock must be >= 1 (clock-0 flushes route by epoch 0)"
            );
            let delta = PlacementDelta {
                epoch: 1,
                at_clock: mig.at_clock,
                grow_active: mig.grow_to.map(|n| n as u32),
                promote: None,
                attach: None,
                dead: vec![],
                moves: mig.moves.iter().map(|&(k, d)| (k, d as u32)).collect(),
            };
            // The key universe is enumerable from the declared tables —
            // exactly what the coordinator initializes rows from.
            let keys = self
                .tables
                .iter()
                .flat_map(|t| (0..t.rows).map(move |r| (t.table, r)));
            let plans = plan_shards(&placement, &delta, keys);
            for (id, plan) in plans.into_iter().enumerate() {
                let _ = shard_tx[id].send(ToShard::MigrateBegin {
                    epoch: delta.epoch,
                    at_clock: delta.at_clock,
                    outgoing: plan.outgoing,
                    incoming: plan.incoming,
                });
            }
            for tx in &worker_tx {
                let _ = tx.send(ToWorker::Placement {
                    delta: delta.clone(),
                });
            }
        }

        let injector = cfg
            .faults
            .has_link_faults()
            .then(|| Arc::new(FaultInjector::new(cfg.faults.clone())));
        // Control plane: when the detector runs, the fabric routes
        // `NodeId::Coordinator` packets (heartbeat replies) into its
        // inbox and surfaces dead-inbox peer events to it.
        let (coord_tx, coord_rx) = channel::<ToWorker>();
        let (ev_tx, ev_rx) = channel::<crate::transport::PeerEvent>();
        let fabric = Fabric::build_with_control(
            cfg.transport,
            cfg.net.clone(),
            worker_tx,
            shard_tx.clone(),
            injector,
            failover_active.then_some(coord_tx),
            failover_active.then_some(ev_tx),
        )
        .expect("transport bootstrap failed");
        // Span recorder: both transports hook it (enqueue/flush
        // segments + arrival marks), every node appends its own hops.
        if let Some(ring) = &cfg.spans {
            fabric.set_spans(Arc::clone(ring));
        }

        // Table row-length registry, shared with shards so a GET racing
        // ahead of row materialization can be served zeros (variable-
        // length tables are excluded: no uniform length to synthesize).
        let row_len = table_row_lens(&self.tables);

        // Build + initialize shards. Each primary derives its server
        // policy (clock-gated waves, per-update waves + visibility
        // ledger, or pull-only) from the consistency config; replicas run
        // the same core behind a pull-only policy. Replica chains start
        // from the same initial rows as their primary. Spares (ids past
        // the placement) start as empty pull-only nodes: a Promote or
        // re-replication catch-up gives them content and identity.
        let mut shards: Vec<Shard> = (0..total_nodes)
            .map(|id| {
                if placement.is_replica(id) {
                    Shard::replica(
                        id,
                        cfg.workers,
                        cfg.consistency,
                        fabric.shard_handle(),
                        row_len.clone(),
                        cfg.deterministic,
                    )
                } else {
                    Shard::new(
                        id,
                        cfg.workers,
                        cfg.consistency,
                        fabric.shard_handle(),
                        row_len.clone(),
                        cfg.deterministic,
                    )
                }
            })
            .collect();
        init_rows(&self.tables, cfg.seed, |key, data| {
            let owner = placement.shard_of(&key);
            for r in 0..placement.replicas_per() {
                let rep = placement.replica_of(owner, r);
                shards[rep].init_row(key, data.clone());
            }
            shards[owner].init_row(key, data);
        });

        // Durability comes up after row init so a fresh generation's base
        // checkpoint captures the initialized rows; fault schedules and
        // the fsync stall arm at the same point.
        for (id, shard) in shards.iter_mut().enumerate() {
            if cfg.snapshot_waves {
                shard.force_snapshot_waves();
            }
            // Hot-key sketches must be sized before the metrics handle
            // is ever shared (Arc::get_mut); this loop runs pre-launch.
            if cfg.hot_key_k > 0 {
                shard.set_hot_key_k(cfg.hot_key_k);
            }
            if let Some(ring) = &cfg.spans {
                shard.set_spans(Arc::clone(ring), cfg.span_sample);
            }
            if let Some(dur) = &cfg.durability {
                let recovered = shard
                    .enable_durability(dur.clone())
                    .expect("enable durability");
                if recovered {
                    eprintln!("shard {id}: recovered durable state from {:?}", dur.dir);
                }
            }
            let scheduled = cfg.faults.shard_faults(id);
            if !scheduled.is_empty() {
                shard.set_faults(scheduled);
            }
            shard.set_fsync_stall(cfg.faults.fsync_stall);
            if let Some(ring) = &cfg.trace {
                shard.set_trace(Arc::clone(ring));
            }
        }
        // The failure detector: no kill is pre-armed anywhere — the
        // coordinator thread observes peer events and heartbeat silence
        // and emits every recovery delta itself (see ps::failover).
        let stop = Arc::new(AtomicBool::new(false));
        let detector = failover_active.then(|| {
            let det = Detector::new(
                cfg.failover.clone(),
                placement.clone(),
                (total_shards..total_nodes).collect(),
                cfg.durability.is_some(),
                fabric.shard_handle(),
                ev_rx,
                coord_rx,
                cfg.trace.clone(),
                Arc::clone(&stop),
            );
            let resolved = det.resolved_handle();
            let handle = std::thread::Builder::new()
                .name("coordinator".into())
                .spawn(move || det.run())
                .expect("spawn coordinator");
            (handle, resolved)
        });

        // Launch shard threads.
        let (dump_tx, dump_rx) = channel::<ShardFinal>();
        let shard_handles: Vec<_> = shards
            .into_iter()
            .zip(shard_rx)
            .map(|(shard, rx)| super::shard::spawn(shard, rx, dump_tx.clone()))
            .collect();
        drop(dump_tx);

        // Launch worker threads.
        let started = Instant::now();
        let worker_handles: Vec<_> = apps
            .into_iter()
            .zip(worker_rx)
            .enumerate()
            .map(|(w, (mut app, inbox))| {
                let client_cfg = ClientConfig {
                    consistency: cfg.consistency,
                    cache_capacity: cfg.cache_capacity,
                    read_my_writes: cfg.read_my_writes,
                    virtual_clock: cfg.virtual_clock,
                    stats_pull_every: cfg.stats_pull_every,
                    resend_window: cfg.resend_window,
                    span_sample: cfg.span_sample,
                };
                let trace = cfg.trace.clone();
                let spans = cfg.spans.clone();
                let net_handle = fabric.worker_handle();
                let row_len = row_len.clone();
                let straggler = cfg.straggler.clone();
                let virtual_clock = cfg.virtual_clock;
                let seed = cfg.seed;
                let placement = placement.clone();
                std::thread::Builder::new()
                    .name(format!("worker-{w}"))
                    .spawn(move || {
                        crate::sim::priority::worker_thread();
                        let mut ps = PsClient::new(
                            w,
                            client_cfg,
                            placement,
                            net_handle,
                            inbox,
                            row_len,
                            started,
                        );
                        if let Some(ring) = trace {
                            ps.set_trace(ring);
                        }
                        if let Some(ring) = spans {
                            ps.set_spans(ring);
                        }
                        let mut log = ConvergenceLog::new();
                        let trace = std::env::var_os("ESSPTABLE_TRACE").is_some();
                        for c in 0..clocks as Clock {
                            if trace {
                                eprintln!(
                                    "[trace] worker {w} clock {c} t={:.3}s",
                                    started.elapsed().as_secs_f64()
                                );
                            }
                            let t0 = Instant::now();
                            let comm0 = ps.timeline.current_comm();
                            let metric = app.run_clock(&mut ps, c);
                            // Straggler injection: stretch this clock's
                            // *compute* time by the model's factor. Blocked
                            // (comm) time must not be multiplied — that
                            // would couple workers into a positive feedback
                            // loop (slow worker -> others wait longer ->
                            // they sleep longer -> ...).
                            let factor = straggler.factor(seed, w, c as u64);
                            let comm = ps.timeline.current_comm() - comm0;
                            let comp = t0.elapsed().saturating_sub(comm);
                            // Virtual clock: pad compute to the configured
                            // duration so per-clock compute is long and
                            // uniform (the paper's regime), then apply the
                            // straggler factor to the *virtual* duration.
                            let target = match virtual_clock {
                                Some(v) => v.max(comp).mul_f64(factor),
                                None => comp.mul_f64(factor),
                            };
                            if target > comp {
                                std::thread::sleep(target - comp);
                            }
                            if let Some(v) = metric {
                                log.report(c, ps.elapsed_seconds(), v);
                            }
                            ps.tick();
                        }
                        // A finished worker detaches (value-bounded
                        // policies only) so remaining readers don't wait
                        // forever for its acks.
                        ps.finish();
                        (ps, log)
                    })
                    .expect("spawn worker")
            })
            .collect();

        // Join workers, harvest metrics.
        let mut staleness = StalenessHist::new();
        let mut per_worker_staleness = Vec::new();
        let mut timelines = Vec::new();
        let mut convergence = ConvergenceLog::new();
        let mut client_stats = Vec::new();
        let mut read_latency = HistSnapshot::default();
        let mut staleness_lag = HistSnapshot::default();
        for h in worker_handles {
            let (ps, log) = h.join().expect("worker panicked");
            staleness.merge(&ps.staleness);
            per_worker_staleness.push(ps.staleness.clone());
            timelines.push(ps.timeline.clone());
            convergence.merge(&log);
            read_latency.merge(&ps.metrics().read_latency_ns.snapshot());
            staleness_lag.merge(&ps.metrics().staleness_lag.snapshot());
            client_stats.push(ps.stats.clone());
        }
        let wall = started.elapsed();

        // Drain the data plane so no in-flight update can race the direct-
        // path Shutdown below (mpsc inboxes are FIFO: once delivered,
        // messages queued before Shutdown are processed before it).
        fabric.flush();

        // Let the detector finish any in-flight recovery: a node killed
        // on the run's last clock may only be detected by a post-run
        // heartbeat, and its Promote must land before Shutdown (same FIFO
        // inbox) or the partition's authoritative dump is lost.
        let failover_report = detector.map(|(handle, resolved)| {
            let deadline = Instant::now() + Duration::from_secs(10);
            while resolved.load(Ordering::Acquire) < killed.len()
                && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(1));
            }
            fabric.flush();
            stop.store(true, Ordering::Release);
            handle.join().expect("coordinator panicked")
        });

        // Stop shards (direct control-plane path, bypassing the sim net).
        for tx in &shard_tx {
            let _ = tx.send(ToShard::Shutdown);
        }
        let mut shard_stats = vec![ShardStats::default(); total_nodes];
        let mut shard_queue_hwm = vec![0u64; total_nodes];
        let mut shard_metrics = vec![Vec::new(); total_nodes];
        let mut table_rows = HashMap::new();
        let mut replica_rows: Vec<HashMap<Key, Vec<f32>>> =
            vec![HashMap::new(); total_nodes - cfg.shards];
        // Killed shards die without dumping; the nodes the detector
        // promoted dump their partitions' authoritative rows instead.
        let promoted_nodes: HashMap<usize, usize> = failover_report
            .as_ref()
            .map(|r| r.promotions.iter().map(|&(p, n)| (n, p)).collect())
            .unwrap_or_default();
        for _ in 0..total_nodes - killed.len() {
            let fin = dump_rx.recv().expect("shard final state");
            shard_stats[fin.id] = fin.stats;
            shard_queue_hwm[fin.id] = fin
                .metrics
                .iter()
                .find(|(n, _)| n == "queue_hwm")
                .map_or(0, |&(_, v)| v);
            shard_metrics[fin.id] = fin.metrics;
            if fin.id < cfg.shards {
                // Primaries are authoritative; key sets are disjoint
                // (migration removes a handed-off row from its source).
                for (k, row) in fin.rows {
                    table_rows.insert(k, row.data.to_vec());
                }
            } else {
                let slot = fin.id - cfg.shards;
                let authoritative = promoted_nodes.contains_key(&fin.id);
                for (k, row) in fin.rows {
                    let data = row.data.to_vec();
                    if authoritative {
                        table_rows.insert(k, data.clone());
                    }
                    replica_rows[slot].insert(k, data);
                }
            }
        }
        for h in shard_handles {
            let _ = h.join();
        }
        if let Some(r) = &failover_report {
            if !r.unreplicated.is_empty() {
                eprintln!(
                    "cluster: partitions {:?} were lost unreplicated — results \
                     below exclude their updates",
                    r.unreplicated
                );
            }
        }
        let net_messages = fabric.messages();
        let net_bytes = fabric.bytes();
        fabric.shutdown();

        // Value-bound stall cost, aggregated from the client side (the
        // gate — and hence the stall — lives in the clients now that no
        // process-global tracker exists).
        let vap_stall = cfg.consistency.value_bound().map(|_| {
            (
                Duration::from_nanos(client_stats.iter().map(|s| s.vap_stall_ns).sum()),
                client_stats.iter().map(|s| s.vap_stalled_reads).sum(),
            )
        });

        let replica_hits = client_stats.iter().map(|s| s.replica_pulls).sum();
        let staleness_violations = client_stats.iter().map(|s| s.staleness_violations).sum();
        // Per-segment span breakdown: every node recorded into the one
        // shared ring, so this is already cluster-wide.
        let span_segments = cfg
            .spans
            .as_ref()
            .map(|ring| ring.segment_hists())
            .unwrap_or_default();

        RunReport {
            wall,
            staleness,
            per_worker_staleness,
            timelines,
            convergence,
            client_stats,
            shard_stats,
            net_messages,
            net_bytes,
            table_rows,
            replica_rows,
            replica_hits,
            vap_stall,
            read_latency,
            staleness_lag,
            span_segments,
            staleness_violations,
            shard_queue_hwm,
            shard_metrics,
            failover_ms: failover_report.as_ref().and_then(|r| r.failover_ms),
            failover: failover_report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// P workers each add 1.0 to the same row every clock; final value
    /// must be P * clocks regardless of the consistency model.
    fn counter_run(consistency: Consistency, workers: usize, clocks: u64) -> RunReport {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 4, 1));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|_| {
                Box::new(|ps: &mut PsClient, _c: Clock| {
                    let _ = ps.get((0, 0));
                    ps.inc((0, 0), &[1.0]);
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        cluster.run(apps, clocks)
    }

    #[test]
    fn no_update_lost_bsp() {
        let r = counter_run(Consistency::Bsp, 4, 10);
        assert_eq!(r.table_rows[&(0, 0)][0], 40.0);
    }

    #[test]
    fn no_update_lost_ssp() {
        let r = counter_run(Consistency::Ssp { s: 3 }, 4, 10);
        assert_eq!(r.table_rows[&(0, 0)][0], 40.0);
    }

    #[test]
    fn no_update_lost_essp() {
        let r = counter_run(Consistency::Essp { s: 3 }, 4, 10);
        assert_eq!(r.table_rows[&(0, 0)][0], 40.0);
        // ESSP must actually push.
        assert!(r.shard_stats.iter().any(|s| s.push_waves > 0));
    }

    #[test]
    fn no_update_lost_async() {
        let r = counter_run(Consistency::Async { refresh_every: 1 }, 4, 10);
        assert_eq!(r.table_rows[&(0, 0)][0], 40.0);
    }

    #[test]
    fn no_update_lost_vap() {
        let r = counter_run(Consistency::Vap { v0: 100.0 }, 2, 5);
        assert_eq!(r.table_rows[&(0, 0)][0], 10.0);
        assert!(r.vap_stall.is_some());
    }

    #[test]
    fn no_update_lost_avap() {
        // The composed model (value bound + SSP window) is pure policy:
        // the same cores must conserve updates under it too.
        let r = counter_run(Consistency::Avap { v0: 100.0, s: 1 }, 2, 5);
        assert_eq!(r.table_rows[&(0, 0)][0], 10.0);
        assert!(r.vap_stall.is_some());
    }

    #[test]
    fn bsp_staleness_is_exactly_minus_one() {
        let r = counter_run(Consistency::Bsp, 3, 8);
        // Paper, Fig. 1 caption: "on BSP the staleness is always -1". With
        // the clock-differential metric (c_param - c_worker, c_param = the
        // copy's guaranteed clock) a BSP read at clock c waits for table
        // clock c-1 and cannot see beyond it: exactly -1, every read.
        assert_eq!(r.staleness.min(), Some(-1), "{:?}", r.staleness.min());
        assert_eq!(r.staleness.max(), Some(-1), "{:?}", r.staleness.max());
    }

    #[test]
    fn convergence_log_collects() {
        let mut cluster = Cluster::new(ClusterConfig::default());
        cluster.add_table(TableSpec::zeros(0, 1, 1));
        let apps: Vec<Box<dyn PsApp>> = (0..4)
            .map(|_| {
                Box::new(|ps: &mut PsClient, c: Clock| {
                    let _ = ps.get((0, 0));
                    Some(c as f64)
                }) as Box<dyn PsApp>
            })
            .collect();
        let r = cluster.run(apps, 3);
        let s = r.convergence.summed();
        assert_eq!(s.len(), 3);
        assert_eq!(s[2].value, 4.0 * 2.0);
    }

    #[test]
    fn deterministic_mode_loses_no_updates() {
        // Every model — including the value-bounded ones, whose eager
        // waves fire at update receipt rather than commit — must conserve
        // updates under staged sorted replay.
        for consistency in [
            Consistency::Bsp,
            Consistency::Ssp { s: 2 },
            Consistency::Essp { s: 2 },
            Consistency::Async { refresh_every: 1 },
            Consistency::Vap { v0: 100.0 },
            Consistency::Avap { v0: 100.0, s: 2 },
        ] {
            let mut cluster = Cluster::new(ClusterConfig {
                workers: 4,
                shards: 2,
                consistency,
                deterministic: true,
                ..Default::default()
            });
            cluster.add_table(TableSpec::zeros(0, 4, 1));
            let apps: Vec<Box<dyn PsApp>> = (0..4)
                .map(|_| {
                    Box::new(|ps: &mut PsClient, _c: Clock| {
                        let _ = ps.get((0, 0));
                        ps.inc((0, 0), &[1.0]);
                        None
                    }) as Box<dyn PsApp>
                })
                .collect();
            let r = cluster.run(apps, 10);
            assert_eq!(
                r.table_rows[&(0, 0)][0],
                40.0,
                "{consistency:?} lost updates under deterministic replay"
            );
        }
    }

    #[test]
    fn replicated_counter_conserves_and_serves_replica_reads() {
        // BSP re-pulls every clock (the cached copy is always one clock
        // too stale), so the round-robin fan-out demonstrably reaches
        // the replicas; conservation must be unaffected, and the final
        // primaries must not include replica copies.
        let mut cluster = Cluster::new(ClusterConfig {
            workers: 3,
            shards: 2,
            replicas: 1,
            consistency: Consistency::Bsp,
            deterministic: true,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 4, 1));
        let apps: Vec<Box<dyn PsApp>> = (0..3)
            .map(|_| {
                Box::new(|ps: &mut PsClient, _c: Clock| {
                    let _ = ps.get((0, 0));
                    ps.inc((0, 0), &[1.0]);
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let r = cluster.run(apps, 8);
        assert_eq!(r.table_rows[&(0, 0)][0], 24.0);
        assert!(r.replica_hits > 0, "no pull was served by a replica");
        assert_eq!(r.shard_stats.len(), 4, "2 primaries + 2 replicas");
        // Deterministic mode: a replica's copy of every row it holds is
        // bit-identical to the primary's authoritative row.
        assert_eq!(r.replica_rows.len(), 2);
        let mut replicated = 0usize;
        for rows in &r.replica_rows {
            for (k, v) in rows {
                replicated += 1;
                let primary = &r.table_rows[k];
                for (a, b) in v.iter().zip(primary) {
                    assert_eq!(a.to_bits(), b.to_bits(), "replica row {k:?} diverged");
                }
            }
        }
        assert!(replicated > 0, "replicas held no rows");
    }

    #[test]
    fn migrated_counter_conserves_and_moves_rows() {
        // 4 provisioned primaries, 2 initially active; at clock 3 the
        // active set grows to 4 and one key is force-moved. Updates are
        // conserved and rows demonstrably crossed shards — in
        // deterministic mode (fenced replay) AND in eager mode, where a
        // destination may apply post-switch updates before the base row
        // arrives and the handoff must fold in, not overwrite.
        for deterministic in [true, false] {
            let mut cluster = Cluster::new(ClusterConfig {
                workers: 4,
                shards: 4,
                active_shards: 2,
                migration: Some(MigrationSpec {
                    at_clock: 3,
                    grow_to: Some(4),
                    moves: vec![((0, 0), 3)],
                }),
                consistency: Consistency::Bsp,
                deterministic,
                ..Default::default()
            });
            cluster.add_table(TableSpec::zeros(0, 8, 1));
            let apps: Vec<Box<dyn PsApp>> = (0..4)
                .map(|_| {
                    Box::new(|ps: &mut PsClient, _c: Clock| {
                        for row in 0..8u64 {
                            let _ = ps.get((0, row));
                            ps.inc((0, row), &[1.0]);
                        }
                        None
                    }) as Box<dyn PsApp>
                })
                .collect();
            let r = cluster.run(apps, 8);
            for row in 0..8u64 {
                assert_eq!(
                    r.table_rows[&(0, row)][0], 32.0,
                    "row {row} lost updates (deterministic={deterministic})"
                );
            }
            let moved_out: u64 = r.shard_stats.iter().map(|s| s.rows_migrated_out).sum();
            let moved_in: u64 = r.shard_stats.iter().map(|s| s.rows_migrated_in).sum();
            assert!(moved_out > 0, "migration moved nothing");
            assert_eq!(moved_out, moved_in, "handoffs lost in flight");
            // The forced move landed at shard 3.
            assert!(
                r.shard_stats[3].rows_migrated_in > 0,
                "forced move to shard 3 never arrived"
            );
        }
    }

    #[test]
    fn deterministic_bsp_runs_are_bit_identical() {
        // Thread/arrival-order noise must not leak into final parameters:
        // two identical deterministic BSP runs of a float workload (logreg
        // gradients — genuinely order-sensitive sums) match to the bit.
        let run = || {
            let (report, _) = crate::apps::logreg::run_logreg(
                ClusterConfig {
                    workers: 4,
                    shards: 2,
                    consistency: Consistency::Bsp,
                    deterministic: true,
                    ..Default::default()
                },
                crate::apps::logreg::LogRegConfig::default(),
                6,
            );
            report.table_rows
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), b.len());
        for (k, va) in &a {
            let vb = &b[k];
            assert_eq!(va.len(), vb.len());
            for (x, y) in va.iter().zip(vb) {
                assert_eq!(x.to_bits(), y.to_bits(), "row {k:?} differs: {x} vs {y}");
            }
        }
    }

    #[test]
    fn random_table_init_is_seeded() {
        let mk = || {
            let mut c = Cluster::new(ClusterConfig {
                workers: 1,
                ..Default::default()
            });
            c.add_table(TableSpec::random_normal(0, 8, 4, 0.1));
            let apps: Vec<Box<dyn PsApp>> =
                vec![Box::new(|_: &mut PsClient, _: Clock| None)];
            c.run(apps, 1)
        };
        let a = mk();
        let b = mk();
        for r in 0..8u64 {
            assert_eq!(a.table_rows[&(0, r)], b.table_rows[&(0, r)]);
        }
    }
}
