//! Unrolled apply/norm kernels for the hot fold loops.
//!
//! The three folds that dominate the serve path — dense delta apply
//! (`RowDelta::add_into` under `ShardCore::apply_rows` and the client
//! overlay), sparse scatter-add, and the ∞-norm reduction the
//! value-bounded policies report — are all memory-bound once the
//! allocator is out of the way. This module gives each a manually
//! unrolled multi-accumulator variant (8-wide adds, 4-way max trees)
//! that the compiler auto-vectorizes to SSE/AVX/NEON, plus the plain
//! scalar loop as both the fallback and the reference for the
//! equivalence property tests.
//!
//! Bit-identity is a hard requirement (the transport-matrix tests
//! compare runs elementwise as bits), so only reassociations that are
//! exact in IEEE-754 are used:
//!
//! * `out[i] += d[i]` is lane-independent — any evaluation order gives
//!   the same bits per lane.
//! * `fold(0.0, |m, x| m.max(x.abs()))` is association-independent:
//!   `abs` maps -0.0 to +0.0 so the operands are non-negative or NaN,
//!   `f32::max` drops NaN symmetrically, and max over non-negative
//!   values yields the same bit pattern under any tree shape (an
//!   all-NaN input returns the 0.0 seed either way). Note this relies
//!   on `f32::max` semantics — an explicit `_mm_max_ps` would NOT be
//!   bit-safe (it returns the second operand on NaN).
//!
//! The unrolled variants are gated behind the `unrolled-kernels` cargo
//! feature (on by default, zero dependencies); disabling it routes
//! every call through the scalar reference.

/// Scalar reference: `out[i] += d[i]` over the common prefix.
#[inline]
pub fn add_dense_scalar(out: &mut [f32], d: &[f32]) {
    for (a, b) in out.iter_mut().zip(d) {
        *a += b;
    }
}

/// Unrolled dense apply: 8 independent lanes per iteration so the
/// backend vectorizes without a reduction dependency.
#[inline]
pub fn add_dense_unrolled(out: &mut [f32], d: &[f32]) {
    let n = out.len().min(d.len());
    let (head, tail) = (n / 8 * 8, n);
    let mut i = 0;
    while i < head {
        // Safety-free unroll: indices are < head <= out.len(), d.len().
        out[i] += d[i];
        out[i + 1] += d[i + 1];
        out[i + 2] += d[i + 2];
        out[i + 3] += d[i + 3];
        out[i + 4] += d[i + 4];
        out[i + 5] += d[i + 5];
        out[i + 6] += d[i + 6];
        out[i + 7] += d[i + 7];
        i += 8;
    }
    while i < tail {
        out[i] += d[i];
        i += 1;
    }
}

/// Scalar reference for the dense ∞-norm fold.
#[inline]
pub fn inf_norm_dense_scalar(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Unrolled dense ∞-norm: four independent accumulators, merged by a
/// max tree (exact under reassociation — see module docs).
#[inline]
pub fn inf_norm_dense_unrolled(v: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = v.chunks_exact(4);
    let rest = chunks.remainder();
    for c in chunks {
        acc[0] = acc[0].max(c[0].abs());
        acc[1] = acc[1].max(c[1].abs());
        acc[2] = acc[2].max(c[2].abs());
        acc[3] = acc[3].max(c[3].abs());
    }
    let mut m = acc[0].max(acc[1]).max(acc[2].max(acc[3]));
    for x in rest {
        m = m.max(x.abs());
    }
    m
}

/// Scalar reference for the sparse-pair ∞-norm fold.
#[inline]
pub fn inf_norm_pairs_scalar(pairs: &[(u32, f32)]) -> f32 {
    pairs.iter().fold(0.0f32, |m, (_, x)| m.max(x.abs()))
}

/// Unrolled sparse-pair ∞-norm (two accumulators: pair lists are short).
#[inline]
pub fn inf_norm_pairs_unrolled(pairs: &[(u32, f32)]) -> f32 {
    let mut a = 0.0f32;
    let mut b = 0.0f32;
    let chunks = pairs.chunks_exact(2);
    let rest = chunks.remainder();
    for c in chunks {
        a = a.max(c[0].1.abs());
        b = b.max(c[1].1.abs());
    }
    for (_, x) in rest {
        a = a.max(x.abs());
    }
    a.max(b)
}

/// Dense apply dispatch: unrolled when the feature is on, scalar otherwise.
#[inline]
pub fn add_dense(out: &mut [f32], d: &[f32]) {
    #[cfg(feature = "unrolled-kernels")]
    add_dense_unrolled(out, d);
    #[cfg(not(feature = "unrolled-kernels"))]
    add_dense_scalar(out, d);
}

/// Dense ∞-norm dispatch.
#[inline]
pub fn inf_norm_dense(v: &[f32]) -> f32 {
    #[cfg(feature = "unrolled-kernels")]
    return inf_norm_dense_unrolled(v);
    #[cfg(not(feature = "unrolled-kernels"))]
    return inf_norm_dense_scalar(v);
}

/// Sparse-pair ∞-norm dispatch.
#[inline]
pub fn inf_norm_pairs(pairs: &[(u32, f32)]) -> f32 {
    #[cfg(feature = "unrolled-kernels")]
    return inf_norm_pairs_unrolled(pairs);
    #[cfg(not(feature = "unrolled-kernels"))]
    return inf_norm_pairs_scalar(pairs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Adversarial f32 generator: NaNs (varied payloads), ±0.0,
    /// denormals, ±inf, and ordinary magnitudes.
    fn gen_f32(rng: &mut Rng) -> f32 {
        match rng.next_u64() % 8 {
            0 => f32::from_bits(0x7fc0_0000 | (rng.next_u64() as u32 & 0x003f_ffff)), // NaN
            1 => -0.0,
            2 => 0.0,
            3 => f32::from_bits(rng.next_u64() as u32 & 0x007f_ffff), // +denormal
            4 => f32::from_bits(0x8000_0001 | (rng.next_u64() as u32 & 0x007f_ffff)), // -denormal
            5 => f32::INFINITY,
            6 => f32::NEG_INFINITY,
            _ => (rng.next_u64() as i32 as f32) * 1e-3,
        }
    }

    fn gen_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| gen_f32(rng)).collect()
    }

    #[test]
    fn unrolled_add_matches_scalar_bitwise() {
        let mut rng = Rng::new(0xadd5_eed);
        for case in 0..200 {
            let n = (case % 67) as usize; // covers 0, sub-unroll, odd tails
            let base = gen_vec(&mut rng, n);
            let d = gen_vec(&mut rng, n);
            let mut a = base.clone();
            let mut b = base.clone();
            add_dense_scalar(&mut a, &d);
            add_dense_unrolled(&mut b, &d);
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "dense apply diverged at n={n}");
        }
    }

    #[test]
    fn unrolled_inf_norm_matches_scalar_bitwise() {
        let mut rng = Rng::new(0x1f2e_3d4c);
        for case in 0..200 {
            let n = (case % 67) as usize;
            let v = gen_vec(&mut rng, n);
            assert_eq!(
                inf_norm_dense_scalar(&v).to_bits(),
                inf_norm_dense_unrolled(&v).to_bits(),
                "dense norm diverged at n={n} ({v:?})"
            );
            let pairs: Vec<(u32, f32)> =
                v.iter().enumerate().map(|(i, x)| (i as u32, *x)).collect();
            assert_eq!(
                inf_norm_pairs_scalar(&pairs).to_bits(),
                inf_norm_pairs_unrolled(&pairs).to_bits(),
                "pair norm diverged at n={n}"
            );
        }
    }

    #[test]
    fn all_nan_input_returns_the_zero_seed() {
        let v = vec![f32::NAN; 9];
        assert_eq!(inf_norm_dense_scalar(&v).to_bits(), 0.0f32.to_bits());
        assert_eq!(inf_norm_dense_unrolled(&v).to_bits(), 0.0f32.to_bits());
    }
}
