//! Key -> shard routing.
//!
//! Deterministic hash routing; every client and every shard agree on the
//! mapping with zero coordination. FxHash-style multiply-xor keeps the hot
//! path to a handful of cycles.

use super::types::Key;

/// Routes keys to `n_shards` server shards.
#[derive(Debug, Clone, Copy)]
pub struct Router {
    n_shards: usize,
}

impl Router {
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards > 0, "need at least one shard");
        Self { n_shards }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shard owning `key`.
    #[inline]
    pub fn shard_of(&self, key: &Key) -> usize {
        let h = Self::hash(key);
        (h % self.n_shards as u64) as usize
    }

    #[inline]
    fn hash(key: &Key) -> u64 {
        // splitmix-style avalanche over (table, row).
        let mut z = (key.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ key.1;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let r = Router::new(8);
        for i in 0..100u64 {
            assert_eq!(r.shard_of(&(1, i)), r.shard_of(&(1, i)));
        }
    }

    #[test]
    fn in_range_and_roughly_balanced() {
        let r = Router::new(4);
        let mut counts = [0usize; 4];
        for t in 0..4u32 {
            for i in 0..1000u64 {
                let s = r.shard_of(&(t, i));
                assert!(s < 4);
                counts[s] += 1;
            }
        }
        for &c in &counts {
            // 4000 keys over 4 shards: each within ±25% of fair share.
            assert!((750..=1250).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_shard() {
        let r = Router::new(1);
        assert_eq!(r.shard_of(&(9, 1234)), 0);
    }

    #[test]
    #[should_panic]
    fn zero_shards_rejected() {
        Router::new(0);
    }
}
