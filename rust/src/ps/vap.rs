//! VAP enforcement: the global in-transit update-magnitude tracker.
//!
//! The VAP condition (paper, "VAP"): whenever any worker computes on the
//! model, every worker p's aggregated in-transit updates must satisfy
//! ||u_p||_inf <= v_t, with v_t = v0 / sqrt(t) decaying in the global
//! update count t. "In transit" = produced but not yet seen by *all*
//! workers that read the touched rows.
//!
//! Enforcing this needs *eager value propagation with per-update
//! acknowledgment* — visibility cannot be gated on clock advances (a
//! blocked reader would deadlock waiting for commits it is itself
//! holding up). So in VAP mode the shards push touched rows to registered
//! readers immediately on every update application, each wave tagged with
//! a global sequence number; a batch retires once every addressed reader
//! acked its waves. The paper's point — that this amounts to strong-
//! consistency-grade synchronization — shows up directly as the per-update
//! round trips and the reader stall time this tracker measures (the
//! VAPSIM experiment). The tracker itself is a process-global object that
//! only a simulated cluster can have.
//!
//! We track the ∞-norm of each flushed batch and sum per worker — an upper
//! bound on the ∞-norm of the aggregated in-transit update (triangle
//! inequality), i.e. a *conservative* enforcement of the condition.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::types::{Clock, WorkerId};

/// One flushed-but-not-globally-seen batch.
#[derive(Debug)]
struct Transit {
    inf_norm: f32,
    /// Shard-parts of the batch whose waves are not yet fully acked.
    parts_left: u32,
}

#[derive(Debug)]
struct Wave {
    origin: (WorkerId, Clock),
    awaiting: HashSet<WorkerId>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Per worker: clock -> in-transit batch state.
    in_transit: Vec<HashMap<Clock, Transit>>,
    /// Outstanding eager-push waves by sequence number.
    waves: HashMap<u64, Wave>,
    /// Workers that finished their run (treated as seeing everything).
    detached: HashSet<WorkerId>,
}

/// Global VAP state shared by all clients and shards (simulation-only).
#[derive(Debug)]
pub struct VapTracker {
    v0: f32,
    inner: Mutex<Inner>,
    /// Global update-count t for the v_t = v0/sqrt(t) schedule.
    global_t: AtomicU64,
    next_seq: AtomicU64,
    /// Total reader stall time, ns (the cost of the VAP condition).
    stall_ns: AtomicU64,
    /// Number of reads that had to stall at least once.
    stalled_reads: AtomicU64,
}

impl VapTracker {
    pub fn new(v0: f32, workers: usize) -> Self {
        Self {
            v0,
            inner: Mutex::new(Inner {
                in_transit: (0..workers).map(|_| HashMap::new()).collect(),
                waves: HashMap::new(),
                detached: HashSet::new(),
            }),
            global_t: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            stall_ns: AtomicU64::new(0),
            stalled_reads: AtomicU64::new(0),
        }
    }

    /// Current value bound v_t = v0 / sqrt(max(t, 1)).
    pub fn v_t(&self) -> f32 {
        let t = self.global_t.load(Ordering::Relaxed).max(1);
        self.v0 / (t as f32).sqrt()
    }

    /// Register a flushed batch (client, at CLOCK time, *before* sending
    /// the Update messages). `parts` = number of shards receiving a
    /// non-empty part of this batch.
    pub fn add_batch(&self, worker: WorkerId, clock: Clock, inf_norm: f32, parts: u32) {
        if inf_norm > 0.0 && parts > 0 {
            let mut g = self.inner.lock().unwrap();
            g.in_transit[worker].insert(
                clock,
                Transit {
                    inf_norm,
                    parts_left: parts,
                },
            );
        }
        self.global_t.fetch_add(1, Ordering::Relaxed);
    }

    /// Shard applied one part of batch `origin` and pushed its rows to
    /// `awaiting`. Returns the wave's sequence number. An empty (or fully
    /// detached) awaiting set resolves the part immediately.
    pub fn assign_wave(
        &self,
        origin: (WorkerId, Clock),
        awaiting: HashSet<WorkerId>,
    ) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        let awaiting: HashSet<WorkerId> = awaiting
            .into_iter()
            .filter(|w| !g.detached.contains(w))
            .collect();
        if awaiting.is_empty() {
            Self::part_seen(&mut g, origin);
        } else {
            g.waves.insert(seq, Wave { origin, awaiting });
        }
        seq
    }

    /// A reader acked wave `seq`.
    pub fn on_wave_ack(&self, worker: WorkerId, seq: u64) {
        let mut g = self.inner.lock().unwrap();
        let resolved = match g.waves.get_mut(&seq) {
            Some(wave) => {
                wave.awaiting.remove(&worker);
                wave.awaiting.is_empty()
            }
            None => false,
        };
        if resolved {
            let origin = g.waves.remove(&seq).unwrap().origin;
            Self::part_seen(&mut g, origin);
        }
    }

    fn part_seen(g: &mut Inner, origin: (WorkerId, Clock)) {
        if let Some(t) = g.in_transit[origin.0].get_mut(&origin.1) {
            t.parts_left = t.parts_left.saturating_sub(1);
            if t.parts_left == 0 {
                g.in_transit[origin.0].remove(&origin.1);
            }
        }
    }

    /// A worker finished its run: it will never ack again, and its own
    /// in-transit updates are final. Treat it as having seen everything —
    /// otherwise the remaining workers deadlock waiting for its acks.
    pub fn detach(&self, worker: WorkerId) {
        let mut g = self.inner.lock().unwrap();
        g.detached.insert(worker);
        g.in_transit[worker].clear();
        let resolved: Vec<u64> = g
            .waves
            .iter_mut()
            .filter_map(|(&seq, wave)| {
                wave.awaiting.remove(&worker);
                wave.awaiting.is_empty().then_some(seq)
            })
            .collect();
        for seq in resolved {
            let origin = g.waves.remove(&seq).unwrap().origin;
            Self::part_seen(&mut g, origin);
        }
    }

    /// Is the VAP condition currently satisfied (all workers' aggregated
    /// in-transit norms within v_t)?
    pub fn is_bounded(&self) -> bool {
        let v_t = self.v_t();
        let g = self.inner.lock().unwrap();
        g.in_transit
            .iter()
            .all(|m| m.values().map(|t| t.inf_norm).sum::<f32>() <= v_t)
    }

    /// Max per-worker aggregated in-transit norm (for metrics/tests).
    pub fn max_in_transit(&self) -> f32 {
        let g = self.inner.lock().unwrap();
        g.in_transit
            .iter()
            .map(|m| m.values().map(|t| t.inf_norm).sum::<f32>())
            .fold(0.0, f32::max)
    }

    pub fn record_stall(&self, ns: u64, first: bool) {
        self.stall_ns.fetch_add(ns, Ordering::Relaxed);
        if first {
            self.stalled_reads.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stall_ns(&self) -> u64 {
        self.stall_ns.load(Ordering::Relaxed)
    }

    pub fn stalled_reads(&self) -> u64 {
        self.stalled_reads.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(ws: &[WorkerId]) -> HashSet<WorkerId> {
        ws.iter().copied().collect()
    }

    #[test]
    fn bound_decays_with_t() {
        let v = VapTracker::new(1.0, 2);
        assert!((v.v_t() - 1.0).abs() < 1e-6);
        for c in 0..4 {
            v.add_batch(0, c, 0.0, 0);
        }
        assert!((v.v_t() - 0.5).abs() < 1e-6); // 1/sqrt(4)
    }

    #[test]
    fn batch_retires_when_all_readers_ack() {
        let v = VapTracker::new(0.1, 3);
        v.add_batch(0, 0, 5.0, 1);
        assert!(!v.is_bounded());
        let seq = v.assign_wave((0, 0), set(&[1, 2]));
        v.on_wave_ack(1, seq);
        assert!(!v.is_bounded(), "worker 2 has not seen it");
        v.on_wave_ack(2, seq);
        assert!(v.is_bounded());
        assert_eq!(v.max_in_transit(), 0.0);
    }

    #[test]
    fn multi_part_batch_needs_all_parts() {
        let v = VapTracker::new(0.1, 2);
        v.add_batch(0, 0, 3.0, 2); // spans two shards
        let s1 = v.assign_wave((0, 0), set(&[1]));
        let s2 = v.assign_wave((0, 0), set(&[1]));
        v.on_wave_ack(1, s1);
        assert!(!v.is_bounded(), "second part still in transit");
        v.on_wave_ack(1, s2);
        assert!(v.is_bounded());
    }

    #[test]
    fn empty_awaiting_resolves_immediately() {
        let v = VapTracker::new(0.1, 2);
        v.add_batch(0, 0, 9.0, 1);
        let _ = v.assign_wave((0, 0), set(&[]));
        assert!(v.is_bounded(), "no reader to wait for");
    }

    #[test]
    fn aggregates_norms_per_worker() {
        let v = VapTracker::new(10.0, 2);
        v.add_batch(0, 0, 4.0, 1);
        v.add_batch(0, 1, 4.0, 1);
        assert_eq!(v.max_in_transit(), 8.0);
        // After two batches t=2: v_t = 10/sqrt(2) ~ 7.07 < 8.
        assert!(!v.is_bounded());
    }

    #[test]
    fn detach_resolves_pending_waves() {
        let v = VapTracker::new(0.1, 3);
        v.add_batch(0, 0, 5.0, 1);
        let _seq = v.assign_wave((0, 0), set(&[1, 2]));
        v.detach(1);
        assert!(!v.is_bounded(), "worker 2 still owes an ack");
        v.detach(2);
        assert!(v.is_bounded());
        // Future waves never wait on detached workers.
        v.add_batch(0, 1, 5.0, 1);
        let _ = v.assign_wave((0, 1), set(&[1, 2]));
        assert!(v.is_bounded());
    }

    #[test]
    fn zero_norm_batches_only_advance_t() {
        let v = VapTracker::new(1.0, 1);
        v.add_batch(0, 0, 0.0, 1);
        assert!(v.is_bounded());
        assert_eq!(v.max_in_transit(), 0.0);
    }
}
