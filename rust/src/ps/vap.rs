//! Shard-local VAP visibility accounting: the ledger behind the
//! value-bounded policies in [`crate::ps::policy`].
//!
//! The VAP condition (paper, "VAP"): whenever any worker computes on the
//! model, every worker p's aggregated in-transit updates must satisfy
//! ||u_p||_inf <= v_t, with v_t = v0 / sqrt(t) decaying in the global
//! update count t. "In transit" = produced but not yet seen by *all*
//! workers that read the touched rows.
//!
//! Up to PR 2 this was enforced by a process-global `Mutex`-protected
//! tracker — realizable only in a simulated cluster, which is why VAP was
//! rejected on the TCP data plane. This module is the distributable
//! replacement: one `ShardVisibility` ledger per shard, fed entirely by
//! wire messages, no shared memory.
//!
//! The decomposition that makes shard-local accounting *sound*: rows are
//! partitioned across shards, so the aggregated in-transit update of
//! worker p restricted to shard s's rows has ∞-norm bounded by the sum of
//! p's in-transit *part* norms at s (triangle inequality), and the global
//! ∞-norm is the max over shards of those restrictions. Hence
//!
//! > for every shard s and worker p: Σ in-transit part norms of p at s
//! > <= v_t   ⟹   the global VAP condition holds.
//!
//! Each shard therefore enforces its local inequality independently and
//! broadcasts grant/revoke transitions to workers (`ToWorker::Bound`);
//! a client may read only while every shard has granted. This is in fact
//! *less* conservative than the old global tracker, which charged every
//! shard-part the full batch norm.
//!
//! The decay clock t is also derived locally without coordination:
//! every worker sends a `ToShard::NormReport` to **every** shard on every
//! CLOCK flush (zero-norm parts included), so each shard's count of
//! received reports equals the global tick count — all shards agree on
//! v_t exactly, with no extra round trips.
//!
//! Protocol (all per shard, driven by `policy::value::ValueServer`):
//!   * `on_report`    — a flushed batch part enters the in-transit set
//!     (the report precedes the Update on the same FIFO link);
//!   * `assign_wave`  — the part was applied and eagerly pushed to the
//!     registered readers; the returned sequence number tags the wave;
//!   * `on_ack`       — a reader acked the wave; when the last reader
//!     acks, the part retires;
//!   * `detach`       — a finished worker will never ack again: drop it
//!     from every awaiting set and finalize its own parts.
//!
//! The per-update round trip to every reader — the synchronization cost
//! the paper argues makes value bounds impractical — is unchanged; it is
//! now simply paid over a real network as well.

use std::collections::{HashMap, HashSet};

use super::types::{Clock, WorkerId};

#[derive(Debug)]
struct Wave {
    origin: (WorkerId, Clock),
    awaiting: HashSet<WorkerId>,
}

/// One shard's view of the value-bound state: in-transit part norms per
/// source worker, outstanding eager-push waves, and the locally derived
/// global tick count. Owned by the shard thread — no locks.
#[derive(Debug)]
pub struct ShardVisibility {
    v0: f32,
    /// Per source worker: clock -> in-transit part ∞-norm at this shard
    /// (at most one part per (worker, clock): updates are coalesced per
    /// CLOCK flush).
    in_transit: Vec<HashMap<Clock, f32>>,
    /// Outstanding eager-push waves by sequence number.
    waves: HashMap<u64, Wave>,
    /// Workers that finished their run (treated as seeing everything).
    detached: Vec<bool>,
    /// Locally observed tick count == global update count t (every worker
    /// reports every flush to every shard).
    t: u64,
    next_seq: u64,
}

impl ShardVisibility {
    pub fn new(v0: f32, workers: usize) -> Self {
        Self {
            v0,
            in_transit: (0..workers).map(|_| HashMap::new()).collect(),
            waves: HashMap::new(),
            detached: vec![false; workers],
            t: 0,
            next_seq: 0,
        }
    }

    /// Current value bound v_t = v0 / sqrt(max(t, 1)).
    pub fn v_t(&self) -> f32 {
        self.v0 / (self.t.max(1) as f32).sqrt()
    }

    /// Observed tick count (the locally derived global t).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// A worker flushed a batch whose part routed to this shard has the
    /// given ∞-norm (0.0 for an empty part — still advances t).
    pub fn on_report(&mut self, worker: WorkerId, clock: Clock, inf_norm: f32) {
        self.t += 1;
        if inf_norm > 0.0 && !self.detached[worker] {
            self.in_transit[worker].insert(clock, inf_norm);
        }
    }

    /// The part from `origin` was applied and its rows pushed to
    /// `awaiting`. Returns the wave's sequence number. An empty (or fully
    /// detached) awaiting set retires the part immediately.
    pub fn assign_wave(&mut self, origin: (WorkerId, Clock), awaiting: HashSet<WorkerId>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let awaiting: HashSet<WorkerId> = awaiting
            .into_iter()
            .filter(|&w| !self.detached[w])
            .collect();
        if awaiting.is_empty() {
            self.retire(origin);
        } else {
            self.waves.insert(seq, Wave { origin, awaiting });
        }
        seq
    }

    /// A reader acked wave `seq`.
    pub fn on_ack(&mut self, worker: WorkerId, seq: u64) {
        let resolved = match self.waves.get_mut(&seq) {
            Some(wave) => {
                wave.awaiting.remove(&worker);
                wave.awaiting.is_empty()
            }
            None => false,
        };
        if resolved {
            let origin = self.waves.remove(&seq).unwrap().origin;
            self.retire(origin);
        }
    }

    fn retire(&mut self, origin: (WorkerId, Clock)) {
        self.in_transit[origin.0].remove(&origin.1);
    }

    /// A worker finished its run: it will never ack again, and its own
    /// in-transit parts are final. Treat it as having seen everything —
    /// otherwise the remaining workers stall forever on its acks.
    pub fn detach(&mut self, worker: WorkerId) {
        self.detached[worker] = true;
        self.in_transit[worker].clear();
        let resolved: Vec<u64> = self
            .waves
            .iter_mut()
            .filter_map(|(&seq, wave)| {
                wave.awaiting.remove(&worker);
                wave.awaiting.is_empty().then_some(seq)
            })
            .collect();
        for seq in resolved {
            let origin = self.waves.remove(&seq).unwrap().origin;
            self.retire(origin);
        }
    }

    pub fn is_detached(&self, worker: WorkerId) -> bool {
        self.detached[worker]
    }

    /// Is this shard's local inequality satisfied for every worker
    /// (Σ in-transit part norms <= v_t)? All shards granting implies the
    /// global VAP condition (see module docs).
    pub fn is_bounded(&self) -> bool {
        let v_t = self.v_t();
        self.in_transit
            .iter()
            .all(|m| m.values().sum::<f32>() <= v_t)
    }

    /// Max per-worker aggregated in-transit part norm (metrics/tests).
    pub fn max_in_transit(&self) -> f32 {
        self.in_transit
            .iter()
            .map(|m| m.values().sum::<f32>())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(ws: &[WorkerId]) -> HashSet<WorkerId> {
        ws.iter().copied().collect()
    }

    #[test]
    fn bound_decays_with_t() {
        let mut v = ShardVisibility::new(1.0, 2);
        assert!((v.v_t() - 1.0).abs() < 1e-6);
        for c in 0..4 {
            v.on_report(0, c, 0.0);
        }
        assert!((v.v_t() - 0.5).abs() < 1e-6); // 1/sqrt(4)
        assert_eq!(v.t(), 4);
    }

    #[test]
    fn part_retires_when_all_readers_ack() {
        let mut v = ShardVisibility::new(0.1, 3);
        v.on_report(0, 0, 5.0);
        assert!(!v.is_bounded());
        let seq = v.assign_wave((0, 0), set(&[1, 2]));
        v.on_ack(1, seq);
        assert!(!v.is_bounded(), "worker 2 has not seen it");
        v.on_ack(2, seq);
        assert!(v.is_bounded());
        assert_eq!(v.max_in_transit(), 0.0);
    }

    #[test]
    fn empty_awaiting_retires_immediately() {
        let mut v = ShardVisibility::new(0.1, 2);
        v.on_report(0, 0, 9.0);
        let _ = v.assign_wave((0, 0), set(&[]));
        assert!(v.is_bounded(), "no reader to wait for");
    }

    #[test]
    fn aggregates_part_norms_per_worker() {
        let mut v = ShardVisibility::new(10.0, 2);
        v.on_report(0, 0, 4.0);
        v.on_report(0, 1, 4.0);
        assert_eq!(v.max_in_transit(), 8.0);
        // After two reports t=2: v_t = 10/sqrt(2) ~ 7.07 < 8.
        assert!(!v.is_bounded());
    }

    #[test]
    fn detach_resolves_pending_waves() {
        let mut v = ShardVisibility::new(0.1, 3);
        v.on_report(0, 0, 5.0);
        let _seq = v.assign_wave((0, 0), set(&[1, 2]));
        v.detach(1);
        assert!(!v.is_bounded(), "worker 2 still owes an ack");
        v.detach(2);
        assert!(v.is_bounded());
        // Future waves never wait on detached workers.
        v.on_report(0, 1, 5.0);
        let _ = v.assign_wave((0, 1), set(&[1, 2]));
        assert!(v.is_bounded());
        assert!(v.is_detached(1) && v.is_detached(2) && !v.is_detached(0));
    }

    #[test]
    fn detached_workers_own_reports_are_final() {
        let mut v = ShardVisibility::new(0.1, 2);
        v.on_report(0, 0, 5.0);
        v.detach(0);
        assert!(v.is_bounded(), "a detached worker's parts are final");
        // Its later reports still advance t but add no in-transit mass.
        v.on_report(0, 1, 5.0);
        assert!(v.is_bounded());
        assert_eq!(v.t(), 2);
    }

    #[test]
    fn zero_norm_reports_only_advance_t() {
        let mut v = ShardVisibility::new(1.0, 1);
        v.on_report(0, 0, 0.0);
        assert!(v.is_bounded());
        assert_eq!(v.max_in_transit(), 0.0);
        assert_eq!(v.t(), 1);
    }

    #[test]
    fn late_ack_after_retire_is_ignored() {
        let mut v = ShardVisibility::new(0.1, 3);
        v.on_report(0, 0, 2.0);
        let seq = v.assign_wave((0, 0), set(&[1]));
        v.on_ack(1, seq);
        assert!(v.is_bounded());
        // Duplicate / stray acks must not panic or corrupt state.
        v.on_ack(1, seq);
        v.on_ack(2, 999);
        assert!(v.is_bounded());
    }
}
