//! Core identifier types shared across the parameter server, plus the
//! hybrid dense/sparse [`RowDelta`] — the representation-polymorphic unit
//! of every additive update from app INC to shard commit.

/// Table identifier (an application owns one or more tables).
pub type TableId = u32;
/// Row identifier within a table.
pub type RowId = u64;
/// (table, row) — the unit of GET/INC and of server-side storage.
pub type Key = (TableId, RowId);
/// Worker (computation thread) identifier, dense in `0..P`.
pub type WorkerId = usize;
/// Logical clock. Workers start executing clock 0; `committed = -1` means
/// nothing committed yet. Table clock = min over workers' committed clocks.
pub type Clock = i64;

/// Clock value meaning "nothing committed yet".
pub const NEVER: Clock = -1;

/// A sparse [`RowDelta`] densifies once `nnz > len / DENSIFY_DIV`. The
/// wire break-even is `len / 2` (8-byte pairs vs 4-byte dense elements);
/// switching a bit earlier keeps the sorted-pair fold cheap and means a
/// densification can never inflate the encoded size. The threshold also
/// caps the cost of [`RowDelta::add_pair`]'s sorted-`Vec` insertion
/// (O(nnz) memmove per fresh index, so O((len/3)^2) element moves worst
/// case before densifying) — fine in the sparse regime this targets
/// (LDA: nnz ≈ 2 of K ≈ 1e3); a workload filling a very wide row one
/// index at a time should INC dense instead.
pub const DENSIFY_DIV: usize = 3;

/// Largest pair count at which a sparse delta of `len` stays sparse.
#[inline]
pub fn densify_threshold(len: usize) -> usize {
    len / DENSIFY_DIV
}

/// One coalesced additive row delta, in whichever representation is
/// smaller: dense (one f32 per element) or sparse (sorted
/// `(index, value)` pairs against a row of `len` elements).
///
/// The type is load-bearing end-to-end: `UpdateMap` coalesces INCs into
/// it natively, `ToShard::Update` carries it, the wire codec encodes each
/// representation as-is (`transport::wire`), and `ShardCore::apply_rows`
/// folds it into the store without densifying. A sparse LDA-style flush
/// (nnz ≈ 2 of K = 1024) therefore costs O(nnz) bytes and work at every
/// layer instead of O(K).
///
/// Invariants on `Sparse`: indices are strictly ascending, each `< len`,
/// and `pairs.len() <= densify_threshold(len)` for deltas produced by
/// coalescing (the wire decoder enforces the first two and `nnz <= len`).
#[derive(Debug, Clone, PartialEq)]
pub enum RowDelta {
    /// Flat representation: element i of the row changes by `delta[i]`.
    Dense(Vec<f32>),
    /// Pair representation: element `i` changes by `v` for each `(i, v)`;
    /// all other elements of the `len`-wide row are untouched.
    Sparse { len: u32, pairs: Vec<(u32, f32)> },
}

impl RowDelta {
    /// Build a sparse delta, debug-checking the representation invariants.
    pub fn sparse(len: usize, pairs: Vec<(u32, f32)>) -> Self {
        debug_assert!(
            pairs.iter().all(|&(i, _)| (i as usize) < len),
            "sparse index out of range"
        );
        debug_assert!(
            pairs.windows(2).all(|w| w[0].0 < w[1].0),
            "sparse indices not strictly ascending"
        );
        Self::Sparse {
            len: len as u32,
            pairs,
        }
    }

    /// Logical row length (the dense width both representations describe).
    pub fn len(&self) -> usize {
        match self {
            Self::Dense(v) => v.len(),
            Self::Sparse { len, .. } => *len as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of explicitly stored elements (dense: the full width).
    pub fn nnz(&self) -> usize {
        match self {
            Self::Dense(v) => v.len(),
            Self::Sparse { pairs, .. } => pairs.len(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Self::Sparse { .. })
    }

    /// Max |element| — the ∞-norm the value-bounded policies report. A
    /// sparse delta scans only its pairs: implicit zeros cannot raise a
    /// max over absolute values. Routed through `ps::kernels` (unrolled
    /// multi-accumulator fold, bit-identical to the scalar reference).
    pub fn inf_norm(&self) -> f32 {
        match self {
            Self::Dense(v) => super::kernels::inf_norm_dense(v),
            Self::Sparse { pairs, .. } => super::kernels::inf_norm_pairs(pairs),
        }
    }

    /// Fold this delta into a dense buffer: `out[i] += delta[i]`. Sparse
    /// deltas touch only their nnz indices (out-of-range pairs, which the
    /// wire decoder already rejects, are skipped defensively). The dense
    /// arm runs the unrolled `ps::kernels` apply (lane-independent, so
    /// bit-identical to the scalar loop).
    pub fn add_into(&self, out: &mut [f32]) {
        match self {
            Self::Dense(v) => super::kernels::add_dense(out, v),
            Self::Sparse { pairs, .. } => {
                for &(i, v) in pairs {
                    if let Some(a) = out.get_mut(i as usize) {
                        *a += v;
                    }
                }
            }
        }
    }

    /// Materialize as a dense vector. Pair values are *placed* into the
    /// zero-fill, not added to it, so every bit pattern (-0.0, NaN
    /// payloads) survives exactly.
    pub fn to_dense(self) -> Vec<f32> {
        match self {
            Self::Dense(v) => v,
            Self::Sparse { len, pairs } => {
                let mut out = vec![0.0f32; len as usize];
                for (i, v) in pairs {
                    if let Some(a) = out.get_mut(i as usize) {
                        *a = v;
                    }
                }
                out
            }
        }
    }

    /// Switch a sparse delta to the dense representation.
    fn densify(&mut self) {
        if self.is_sparse() {
            let taken = std::mem::replace(self, Self::Dense(Vec::new()));
            *self = Self::Dense(taken.to_dense());
        }
    }

    /// Fold a dense increment in. The accumulator densifies first: a
    /// dense INC names every element, so sparse bookkeeping no longer
    /// pays (and can never become sparse again within this clock).
    pub fn add_dense(&mut self, delta: &[f32]) {
        self.densify();
        if let Self::Dense(v) = self {
            debug_assert_eq!(v.len(), delta.len(), "dense fold length mismatch");
            for (a, d) in v.iter_mut().zip(delta) {
                *a += d;
            }
        }
    }

    /// Fold one `(index, value)` pair in, preserving the representation.
    /// Callers batch the density check via [`Self::maybe_densify`] once
    /// per INC instead of per pair.
    pub fn add_pair(&mut self, i: u32, v: f32) {
        match self {
            Self::Dense(d) => {
                if let Some(a) = d.get_mut(i as usize) {
                    *a += v;
                }
            }
            Self::Sparse { pairs, .. } => {
                match pairs.binary_search_by_key(&i, |p| p.0) {
                    Ok(j) => pairs[j].1 += v,
                    Err(j) => pairs.insert(j, (i, v)),
                }
            }
        }
    }

    /// Densify if the sparse fill passed [`densify_threshold`].
    pub fn maybe_densify(&mut self) {
        if let Self::Sparse { len, pairs } = self {
            if pairs.len() > densify_threshold(*len as usize) {
                self.densify();
            }
        }
    }

    /// Coalesce another delta in (same fold the `UpdateMap` INC path
    /// uses, so accumulation order — and hence float bits — match).
    pub fn add_assign(&mut self, other: &RowDelta) {
        match other {
            Self::Dense(d) => self.add_dense(d),
            Self::Sparse { pairs, .. } => {
                for &(i, v) in pairs {
                    self.add_pair(i, v);
                }
                self.maybe_densify();
            }
        }
    }
}

impl From<Vec<f32>> for RowDelta {
    fn from(v: Vec<f32>) -> Self {
        Self::Dense(v)
    }
}

/// Exact wire footprint of one coalesced update row inside a
/// `ToShard::Update` frame: key (12) + representation tag (1) + body
/// (dense: `len:u32` + 4 bytes/element; sparse: `len:u32 | nnz:u32` + 8
/// bytes/pair). The `transport::wire` codec derives its Update body
/// length from this function — one source of truth — so the client's
/// pending-bytes estimate, the SimNet serialization-time model, and the
/// real TCP framing agree byte-for-byte.
#[inline]
pub fn row_wire_bytes(delta: &RowDelta) -> usize {
    12 + delta_wire_bytes(delta)
}

/// Exact wire footprint of a *keyless* delta payload: representation tag
/// (1) + body. This is the unit the v7 hybrid row encodings (delta push
/// waves, `RowHandoff`) compose — the key travels once per row, not once
/// per delta.
#[inline]
pub fn delta_wire_bytes(delta: &RowDelta) -> usize {
    1 + match delta {
        RowDelta::Dense(v) => 4 + 4 * v.len(),
        RowDelta::Sparse { pairs, .. } => 8 + 8 * pairs.len(),
    }
}

/// Pick the smaller wire representation for a dense row snapshot: the
/// sparse pair encoding (8 bytes/nnz + 8 header) iff it beats the dense
/// one (4 bytes/element + 4 header). Used by the v7 `RowHandoff` hybrid
/// row payload; the encoder and the body-length function both call this
/// so frame sizes stay exact.
#[inline]
pub fn hybrid_snapshot_delta(data: &[f32]) -> RowDelta {
    let nnz = data.iter().filter(|x| x.to_bits() != 0).count();
    if 8 + 8 * nnz < 4 + 4 * data.len() {
        RowDelta::Sparse {
            len: data.len() as u32,
            pairs: data
                .iter()
                .enumerate()
                .filter(|(_, x)| x.to_bits() != 0)
                .map(|(i, x)| (i as u32, *x))
                .collect(),
        }
    } else {
        RowDelta::Dense(data.to_vec())
    }
}

/// Byte size [`hybrid_snapshot_delta`] will encode to, without building it.
#[inline]
pub fn hybrid_snapshot_wire_bytes(data: &[f32]) -> usize {
    let nnz = data.iter().filter(|x| x.to_bits() != 0).count();
    1 + (8 + 8 * nnz).min(4 + 4 * data.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_folds_stay_sparse_below_threshold() {
        let mut d = RowDelta::sparse(1024, vec![]);
        d.add_pair(900, 1.0);
        d.add_pair(3, 2.0);
        d.add_pair(900, 0.5);
        d.maybe_densify();
        assert!(d.is_sparse());
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.len(), 1024);
        // Pairs stay sorted regardless of insertion order.
        match &d {
            RowDelta::Sparse { pairs, .. } => {
                assert_eq!(pairs.as_slice(), &[(3, 2.0), (900, 1.5)]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn densify_crossover_at_threshold() {
        // len 9 => threshold 3: the 4th distinct index flips to dense.
        let mut d = RowDelta::sparse(9, vec![]);
        for i in [0u32, 4, 8] {
            d.add_pair(i, 1.0);
            d.maybe_densify();
            assert!(d.is_sparse(), "{} pairs must stay sparse", d.nnz());
        }
        d.add_pair(2, 5.0);
        d.maybe_densify();
        assert!(!d.is_sparse());
        assert_eq!(
            d.clone().to_dense(),
            vec![1.0, 0.0, 5.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]
        );
    }

    #[test]
    fn dense_inc_densifies_sparse_accumulator() {
        let mut d = RowDelta::sparse(3, vec![(1, 2.0)]);
        d.add_dense(&[1.0, 1.0, 1.0]);
        assert!(!d.is_sparse());
        assert_eq!(d.to_dense(), vec![1.0, 3.0, 1.0]);
    }

    #[test]
    fn add_into_matches_to_dense() {
        let d = RowDelta::sparse(5, vec![(0, -1.5), (3, 2.0)]);
        let mut buf = vec![1.0f32; 5];
        d.add_into(&mut buf);
        assert_eq!(buf, vec![-0.5, 1.0, 1.0, 3.0, 1.0]);
        assert_eq!(d.to_dense(), vec![-1.5, 0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn inf_norm_scans_only_stored_values() {
        assert_eq!(RowDelta::sparse(100, vec![(7, -3.0), (9, 1.0)]).inf_norm(), 3.0);
        assert_eq!(RowDelta::sparse(100, vec![]).inf_norm(), 0.0);
        assert_eq!(RowDelta::Dense(vec![0.5, -2.0]).inf_norm(), 2.0);
    }

    #[test]
    fn wire_bytes_favor_the_smaller_representation() {
        let sparse = RowDelta::sparse(1024, vec![(1, 1.0), (2, 2.0)]);
        let dense = RowDelta::Dense(vec![0.0; 1024]);
        assert_eq!(row_wire_bytes(&sparse), 13 + 8 + 16);
        assert_eq!(row_wire_bytes(&dense), 13 + 4 + 4096);
        // At the densify threshold the sparse encoding is still smaller.
        let at_threshold = RowDelta::sparse(1024, (0..341).map(|i| (i, 1.0)).collect());
        assert!(row_wire_bytes(&at_threshold) < row_wire_bytes(&dense));
    }
}
