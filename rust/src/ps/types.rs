//! Core identifier types shared across the parameter server.

/// Table identifier (an application owns one or more tables).
pub type TableId = u32;
/// Row identifier within a table.
pub type RowId = u64;
/// (table, row) — the unit of GET/INC and of server-side storage.
pub type Key = (TableId, RowId);
/// Worker (computation thread) identifier, dense in `0..P`.
pub type WorkerId = usize;
/// Logical clock. Workers start executing clock 0; `committed = -1` means
/// nothing committed yet. Table clock = min over workers' committed clocks.
pub type Clock = i64;

/// Clock value meaning "nothing committed yet".
pub const NEVER: Clock = -1;

/// Estimated wire size of one pending update row: the `transport::wire`
/// codec's per-row Update framing (key 12 + length prefix 4 + f32
/// payload). Exact message sizes come from the codec itself
/// (`ToShard::wire_bytes`); this is for client-side pending-bytes
/// estimates only.
#[inline]
pub fn row_wire_bytes(len: usize) -> usize {
    len * 4 + 16
}
