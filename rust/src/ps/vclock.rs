//! Min-clock aggregation: tracks each worker's committed clock and derives
//! the table clock (min over workers), which gates SSP reads and drives
//! ESSP pushes.

use super::types::{Clock, WorkerId, NEVER};

/// Tracks committed clocks for `P` workers; the table clock is their min.
#[derive(Debug, Clone)]
pub struct MinClock {
    committed: Vec<Clock>,
}

impl MinClock {
    pub fn new(workers: usize) -> Self {
        Self {
            committed: vec![NEVER; workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.committed.len()
    }

    pub fn committed(&self, w: WorkerId) -> Clock {
        self.committed[w]
    }

    /// Record that worker `w` committed clock `c`. Returns `Some(new_min)`
    /// if the table clock advanced. Panics on clock regression — clocks are
    /// per-worker monotone by construction, so regression is a bug.
    pub fn commit(&mut self, w: WorkerId, c: Clock) -> Option<Clock> {
        assert!(
            c > self.committed[w],
            "worker {w} clock regression: {} -> {c}",
            self.committed[w]
        );
        let old_min = self.min();
        self.committed[w] = c;
        let new_min = self.min();
        (new_min > old_min).then_some(new_min)
    }

    /// The table clock: every update with clock <= min is fully applied.
    pub fn min(&self) -> Clock {
        self.committed.iter().copied().min().unwrap_or(NEVER)
    }

    pub fn max(&self) -> Clock {
        self.committed.iter().copied().max().unwrap_or(NEVER)
    }

    /// Clock spread (max - min): bounded by s+1 under SSP if the clients
    /// enforce the read condition (property-tested).
    pub fn spread(&self) -> Clock {
        self.max() - self.min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_never() {
        let m = MinClock::new(3);
        assert_eq!(m.min(), NEVER);
        assert_eq!(m.max(), NEVER);
    }

    #[test]
    fn min_advances_only_when_slowest_commits() {
        let mut m = MinClock::new(3);
        assert_eq!(m.commit(0, 0), None);
        assert_eq!(m.commit(1, 0), None);
        assert_eq!(m.commit(2, 0), Some(0)); // slowest committed -> advance
        assert_eq!(m.commit(0, 1), None);
        assert_eq!(m.min(), 0);
        assert_eq!(m.spread(), 1);
    }

    #[test]
    fn skipping_clocks_is_allowed() {
        // A worker may commit several clocks in one message burst.
        let mut m = MinClock::new(2);
        m.commit(0, 3);
        assert_eq!(m.commit(1, 5), Some(3));
    }

    #[test]
    #[should_panic(expected = "regression")]
    fn regression_panics() {
        let mut m = MinClock::new(2);
        m.commit(0, 2);
        m.commit(0, 1);
    }
}
