//! Clock-window policies: BSP, SSP, ESSP and Async.
//!
//! All four share one client shape — a staleness window over the SSP read
//! condition — and differ only in the window width and refresh strategy:
//!
//!   * BSP  = `WindowClient { s: 0, eager: false }` (barrier every clock),
//!   * SSP  = `WindowClient { s, eager: false }` (lazy pulls),
//!   * ESSP = `WindowClient { s, eager: true }` + [`PushServer`] (the
//!     same bound, refreshed by clock-gated server waves),
//!   * Async = [`AsyncClient`] (no bound at all; opportunistic re-pulls).
//!
//! Server-side, the pull-only models need no policy at all
//! ([`PullServer`] is empty); ESSP's entire server behavior is "mark
//! applied rows dirty, flush them as one wave per registered reader at
//! each table-clock advance" — which the core provides as
//! [`ShardCore::push_wave`], so the policy is a two-line adapter. That
//! economy is the point: ESSP really is SSP plus an eager communication
//! strategy.

use super::{ClientPolicy, ServerPolicy};
use crate::ps::shard::ShardCore;
use crate::ps::types::{Clock, WorkerId};

/// Client policy for the clock-bounded family (BSP / SSP / ESSP).
#[derive(Debug, Clone)]
pub struct WindowClient {
    /// Staleness bound `s` of the SSP read condition.
    pub s: Clock,
    /// Register for eager pushes (ESSP) instead of lazy pulls (BSP/SSP).
    pub eager: bool,
}

impl WindowClient {
    pub fn lazy(s: Clock) -> Self {
        Self { s, eager: false }
    }

    pub fn eager(s: Clock) -> Self {
        Self { s, eager: true }
    }
}

impl ClientPolicy for WindowClient {
    fn min_row_vclock(&self, clock: Clock) -> Option<Clock> {
        // All updates with clock <= c - s - 1 must be visible.
        Some(clock - self.s - 1)
    }

    fn eager_register(&self) -> bool {
        self.eager
    }

    fn replica_reads(&self) -> bool {
        // Lazy pulls carry the whole admission in `min_row_vclock`, which
        // a replica enforces identically; ESSP's eager family reads off
        // primary waves instead.
        !self.eager
    }
}

/// Client policy for Async (Hogwild-flavored baseline): reads never block
/// after the first fetch; cached rows are re-pulled opportunistically
/// every `refresh_every` clocks.
#[derive(Debug, Clone)]
pub struct AsyncClient {
    pub refresh_every: Clock,
}

impl ClientPolicy for AsyncClient {
    fn min_row_vclock(&self, _clock: Clock) -> Option<Clock> {
        None
    }

    fn refresh_every(&self) -> Option<Clock> {
        Some(self.refresh_every)
    }

    fn replica_reads(&self) -> bool {
        // Unbounded reads admit any copy; a replica's is as good as the
        // primary's.
        true
    }
}

/// Server policy for the pull-only models (BSP / SSP / Async): the core's
/// hold-the-GET behavior is the whole protocol; nothing to add.
#[derive(Debug, Clone)]
pub struct PullServer;

impl ServerPolicy for PullServer {}

/// Server policy for ESSP: clock-gated delta push waves.
#[derive(Debug, Clone)]
pub struct PushServer;

impl ServerPolicy for PushServer {
    fn pushes_on_commit(&self) -> bool {
        true
    }

    fn on_commit(&mut self, core: &mut ShardCore, table_clock: Clock) {
        core.push_wave(table_clock);
    }

    fn on_push_ack(&mut self, _core: &mut ShardCore, _worker: WorkerId, _vclock: Clock) {
        // Ack traffic is modeled for byte accounting; nothing to track.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_is_ssp0() {
        let bsp = WindowClient::lazy(0);
        assert_eq!(bsp.min_row_vclock(5), Some(4));
        assert!(!bsp.eager_register());
        assert!(!bsp.read_blocked());
    }

    #[test]
    fn ssp_window() {
        let ssp = WindowClient::lazy(3);
        // Read at clock 10 must see all updates <= 6.
        assert_eq!(ssp.min_row_vclock(10), Some(6));
        let essp = WindowClient::eager(3);
        assert_eq!(essp.min_row_vclock(10), Some(6));
        assert!(essp.eager_register());
        assert!(PushServer.pushes_on_commit());
        assert!(!PullServer.pushes_on_commit());
        // Replica fan-out: lazy pulls may hit replicas, eager reads not.
        assert!(ssp.replica_reads());
        assert!(!essp.replica_reads());
    }

    #[test]
    fn async_is_unbounded_with_refresh() {
        let a = AsyncClient { refresh_every: 5 };
        assert_eq!(a.min_row_vclock(1_000_000), None);
        assert_eq!(a.refresh_every(), Some(5));
        assert!(!a.eager_register());
        assert!(!a.reports_norms());
        assert!(a.replica_reads());
    }
}
