//! The consistency-policy engine: pluggable enforcement of the paper's
//! consistency models over one PS mechanism.
//!
//! The paper's central observation is that BSP, SSP, ESSP, Async and VAP
//! are *policies* layered over the same GET/INC/CLOCK machinery — ESSP is
//! "SSP plus an eager communication strategy", VAP swaps the clock bound
//! for a value bound. Before this layer existed, that observation was
//! smeared across ad-hoc branches in `client.rs` / `shard.rs`; now it is
//! a pair of traits:
//!
//!   * [`ClientPolicy`] — the client-side contract: read admission (the
//!     clock window), refresh strategy (eager registration / opportunistic
//!     re-pulls), flush-time obligations (∞-norm reports), the read gate
//!     (bound grants/revokes), and end-of-run teardown.
//!   * [`ServerPolicy`] — the shard-side contract: push decisions (clock-
//!     gated waves vs per-update waves), commit hooks, and ack/report/
//!     detach handling.
//!
//! [`super::consistency::Consistency`] is pure configuration that selects
//! a policy pair; the client and shard cores are policy-agnostic. Adding
//! a model means adding a policy pair here — e.g. [`value`] implements
//! both VAP (value bound, clock-unbounded) and AVAP (value bound + SSP
//! clock window, the paper's §Theory suggestion) with zero edits to the
//! cores.
//!
//! Policies are driven entirely by messages ([`crate::ps::msg`]), so
//! every model — including VAP, which previously needed a process-global
//! tracker — runs unchanged over the simulated network, loopback TCP,
//! and multi-process clusters.

pub mod value;
pub mod window;

use super::shard::ShardCore;
use super::types::{Clock, Key, WorkerId};

/// Client-side consistency contract. One instance per PS client; the
/// client core consults it on every read and flush and forwards
/// policy-addressed control messages to it.
pub trait ClientPolicy: Send {
    /// Clock-window read condition: the minimum guaranteed row vclock for
    /// a read at worker clock `clock` (the SSP condition `>= c - s - 1`).
    /// `None` = clock-unbounded: any cached copy is admissible once
    /// present, and pulls are served at whatever clock the shard holds.
    fn min_row_vclock(&self, clock: Clock) -> Option<Clock>;

    /// Register for eager server pushes on first access of a key
    /// (ESSP-style refresh, also the addressing basis of VAP waves).
    fn eager_register(&self) -> bool {
        false
    }

    /// Opportunistic refresh period: re-pull a cached row if it was last
    /// refreshed more than this many clocks ago (Async family).
    fn refresh_every(&self) -> Option<Clock> {
        None
    }

    /// Must every CLOCK flush be preceded by per-shard ∞-norm reports
    /// (`ToShard::NormReport`, value-bounded family)?
    fn reports_norms(&self) -> bool {
        false
    }

    /// Inbound bound grant/revoke from `shard` (`ToWorker::Bound`).
    fn on_bound(&mut self, _shard: usize, _granted: bool) {}

    /// Must reads currently hold? True while any shard has revoked its
    /// bound grant; the client spins (draining the inbox, so acks keep
    /// flowing) until this clears.
    fn read_blocked(&self) -> bool {
        false
    }

    /// Does the policy keep per-worker server-side state that must be
    /// torn down with `ToShard::Detach` when the worker finishes?
    fn detach_on_finish(&self) -> bool {
        false
    }

    /// May pulls be fanned out to replicas of the owning shard? True only
    /// for policies whose entire read admission is the clock window the
    /// replica itself enforces on the Get (the lazy window family and
    /// Async): a replica receives the same per-worker FIFO update/clock
    /// stream as its primary and holds the reply until its own table
    /// clock satisfies `min_vclock`, so a replica-served read carries
    /// exactly the model's staleness guarantee. Eager and value-bounded
    /// families read primary-only — their waves, visibility ledgers and
    /// bound grants live on the primary.
    fn replica_reads(&self) -> bool {
        false
    }
}

/// Shard-side consistency contract. One instance per [`ShardCore`]; the
/// shard core owns rows/clocks/registrations and calls into the policy at
/// the protocol's decision points.
pub trait ServerPolicy: Send {
    /// Should the core track dirty rows and expect a batched push wave at
    /// each table-clock advance (ESSP family)? Queried once at shard
    /// construction.
    fn pushes_on_commit(&self) -> bool {
        false
    }

    /// Does the policy fire a wave per inbound Update batch (VAP family)?
    /// Queried once at shard construction: together with
    /// `pushes_on_commit` it decides whether `apply_rows` keeps per-key
    /// `WaveLog`s so waves can ship wire-v7 delta chains instead of
    /// snapshots. (In deterministic mode per-update waves preview staged
    /// state instead of applied state, so the logs would go unconsumed —
    /// the core gates on that itself.)
    fn waves_per_update(&self) -> bool {
        false
    }

    /// `worker` registered for eager pushes of a key (the core has
    /// already recorded it in the inverted index). The first policy-
    /// visible proof that a route to `worker` exists — value-bounded
    /// policies bring the newcomer up to date on the bound state here.
    fn on_register(&mut self, _core: &mut ShardCore, _worker: WorkerId) {}

    /// One inbound Update batch was processed: applied (eager path) or
    /// staged for deterministic replay. `touched` lists its keys. Fire
    /// per-update waves here (VAP family).
    fn on_update(
        &mut self,
        _core: &mut ShardCore,
        _source: WorkerId,
        _clock: Clock,
        _touched: &[Key],
    ) {
    }

    /// The table clock advanced to `table_clock` (staged updates already
    /// replayed, pending GETs already served). Fire clock-gated waves
    /// here (ESSP family).
    fn on_commit(&mut self, _core: &mut ShardCore, _table_clock: Clock) {}

    /// A client acked a clock-gated push wave (`ToShard::PushAck`).
    fn on_push_ack(&mut self, _core: &mut ShardCore, _worker: WorkerId, _vclock: Clock) {}

    /// A client acked a per-update wave (`ToShard::VapAck`).
    fn on_wave_ack(&mut self, _core: &mut ShardCore, _worker: WorkerId, _seq: u64) {}

    /// A client reported the ∞-norm of a flushed batch part
    /// (`ToShard::NormReport`; zero-norm reports still advance the decay
    /// clock t).
    fn on_norm_report(
        &mut self,
        _core: &mut ShardCore,
        _worker: WorkerId,
        _clock: Clock,
        _inf_norm: f32,
    ) {
    }

    /// A worker finished its run (`ToShard::Detach`).
    fn on_detach(&mut self, _core: &mut ShardCore, _worker: WorkerId) {}
}
