//! Value-bounded policies: VAP and AVAP, distributable across processes.
//!
//! VAP gates *reads* on a bound over in-transit update magnitudes instead
//! of (VAP) or in addition to (AVAP) the SSP clock window. Enforcement is
//! the wire protocol described in [`crate::ps::vap`]:
//!
//!   * the client prefixes every CLOCK flush with one per-shard
//!     `ToShard::NormReport` (the ∞-norm of the batch part routed to that
//!     shard; zero-norm parts included so every shard's decay clock t
//!     advances identically);
//!   * the shard applies the part, eagerly pushes the touched rows to
//!     every *other* registered reader (`ToWorker::VapPush`, ack-tracked
//!     per wave), and retires the part once every addressed reader acked
//!     (`ToShard::VapAck`);
//!   * whenever the shard-local inequality Σ part norms <= v_t flips, the
//!     shard broadcasts `ToWorker::Bound { granted }` to every worker it
//!     has heard from; the client blocks reads while any shard's grant is
//!     revoked, spinning on its inbox so acks keep flowing.
//!
//! Because grants travel as messages, enforcement is eventually
//! consistent within one network latency — a read racing an in-flight
//! revoke may still be admitted. That is the honest distributed analogue
//! of the paper's process-global tracker (which got atomicity for free
//! from shared memory); the cost the paper cares about — a per-update
//! round trip to every reader, surfacing as read stalls — is unchanged
//! and now measurable over real sockets too.
//!
//! AVAP (`avap:V0:S`, the paper's §Theory suggestion) composes the value
//! bound with SSP's clock window: [`ValueClient`] with a finite
//! `staleness` — same [`ValueServer`], zero edits to the client/shard
//! cores. Clock-window refreshes use SSP-style lazy pulls; the eager
//! VapPush waves keep value visibility (and its ack accounting) flowing.

use std::collections::HashSet;
use std::sync::Arc;

use super::{ClientPolicy, ServerPolicy};
use crate::ps::msg::{PushRow, ToWorker};
use crate::ps::shard::ShardCore;
use crate::ps::types::{Clock, Key, RowDelta, WorkerId, NEVER};
use crate::ps::vap::ShardVisibility;

/// Client policy for the value-bounded family.
#[derive(Debug, Clone)]
pub struct ValueClient {
    /// SSP staleness bound composed with the value bound (AVAP), or
    /// `None` for pure VAP (clock-unbounded — honestly, not via a huge
    /// sentinel window).
    pub staleness: Option<Clock>,
    /// Per-shard bound grants; a read may proceed only while all are
    /// granted. Starts all-granted (nothing is in transit at t=0).
    granted: Vec<bool>,
}

impl ValueClient {
    pub fn new(staleness: Option<Clock>, n_shards: usize) -> Self {
        Self {
            staleness,
            granted: vec![true; n_shards],
        }
    }
}

impl ClientPolicy for ValueClient {
    fn min_row_vclock(&self, clock: Clock) -> Option<Clock> {
        self.staleness.map(|s| clock - s - 1)
    }

    fn eager_register(&self) -> bool {
        // Registration addresses the per-update VapPush waves.
        true
    }

    fn reports_norms(&self) -> bool {
        true
    }

    fn on_bound(&mut self, shard: usize, granted: bool) {
        if let Some(g) = self.granted.get_mut(shard) {
            *g = granted;
        }
    }

    fn read_blocked(&self) -> bool {
        self.granted.iter().any(|&g| !g)
    }

    fn detach_on_finish(&self) -> bool {
        true
    }
}

/// Server policy for the value-bounded family: shard-local visibility
/// ledger + per-update eager waves + bound grant/revoke broadcasts.
#[derive(Debug)]
pub struct ValueServer {
    vis: ShardVisibility,
    /// Workers this shard has heard from (a Register or NormReport) —
    /// a route to them provably exists, so bound broadcasts are never
    /// sent into the void before a peer has connected. Every VAP reader
    /// registers on its very first GET and reports on its very first
    /// flush, so this fills within one clock.
    known: Vec<bool>,
    /// The last bound state broadcast (grants are edge-triggered).
    granted: bool,
}

impl ValueServer {
    pub fn new(v0: f32, workers: usize) -> Self {
        Self {
            vis: ShardVisibility::new(v0, workers),
            known: vec![false; workers],
            granted: true,
        }
    }

    /// Test/metrics access to the ledger.
    pub fn visibility(&self) -> &ShardVisibility {
        &self.vis
    }

    /// First contact from `worker`: mark it reachable, and if the bound
    /// is currently revoked, bring it up to date immediately — it missed
    /// the edge-triggered broadcast.
    fn mark_known(&mut self, core: &mut ShardCore, worker: WorkerId) {
        if worker >= self.known.len() || self.known[worker] {
            return;
        }
        self.known[worker] = true;
        if !self.granted {
            core.send_to_worker(
                worker,
                ToWorker::Bound {
                    shard: core.logical,
                    granted: false,
                },
            );
        }
    }

    /// Broadcast the bound state to every known, still-attached worker if
    /// it flipped since the last broadcast.
    fn sync_bound(&mut self, core: &mut ShardCore) {
        let ok = self.vis.is_bounded();
        if ok == self.granted {
            return;
        }
        self.granted = ok;
        for w in 0..core.workers {
            if self.known[w] && !self.vis.is_detached(w) {
                core.send_to_worker(
                    w,
                    ToWorker::Bound {
                        shard: core.logical,
                        granted: ok,
                    },
                );
            }
        }
    }

    /// Eager value propagation: push the rows this part touched to every
    /// *other* registered reader, ack-tracked per wave. This per-update
    /// round trip is the synchronization cost the paper argues makes
    /// value bounds impractical; it is reproduced faithfully so the cost
    /// can be measured (the VAPSIM experiment), in-process or over TCP.
    ///
    /// In deterministic mode the update batches are staged, not applied,
    /// so the wave composes *preview* contents — the committed row plus
    /// the sum of ALL staged deltas for that key (not just this part's:
    /// a reader's cache is overwritten wholesale by each wave, so a
    /// preview missing a concurrent worker's staged part would erase it
    /// from reader caches until commit). Readers thus genuinely see the
    /// update whose norm is in transit, while the store itself stays
    /// untouched until the sorted commit replay — final parameters
    /// remain bit-deterministic.
    /// Payload selection per (key, reader) mirrors the ESSP clock wave:
    /// in eager mode, a reader whose chain token (`core.shipped`, holding
    /// wave seqs here) is live gets the triggering update's ordered delta
    /// log (wire v7) on a `base` of the last wave it received; everyone
    /// else gets the full preview snapshot. Readers a wave *skips* (the
    /// writer itself, detached workers) have their token broken — their
    /// cached copy missed this wave's content, so the next wave they do
    /// receive must re-seed with a snapshot. Deterministic mode keeps no
    /// wave logs (previews are staged compositions, not applied state)
    /// and always snapshots.
    fn wave(&mut self, core: &mut ShardCore, source: WorkerId, clock: Clock, touched: &[Key]) {
        let mut per_worker: Vec<Vec<PushRow>> = Vec::new();
        per_worker.resize_with(core.workers, Vec::new);
        // Chain tokens of rows shipped this wave are set to the wave's
        // seq — which is only assigned once the receiver set is known, so
        // collect the (key, reader) pairs and stamp them after.
        let mut stamp: Vec<(Key, WorkerId)> = Vec::new();
        let staged = core.staged_sums(touched);
        let mut delta_rows: u64 = 0;
        for key in touched {
            // Consume the delta log up front (even on the skip paths
            // below) so it never outlives the wave it describes.
            let log = core.wave_log.remove(key);
            let Some(readers) = core.readers.get(key) else {
                continue;
            };
            let (data, fresh): (Arc<[f32]>, Clock) = match (core.rows.get(key), staged.get(key)) {
                // Eager path: the update is already applied to the store.
                (Some(row), None) => (Arc::clone(&row.data), row.fresh),
                // Deterministic path: overlay the staged sums (preview).
                // A sparse sum folds only its nnz indices into the copy.
                (Some(row), Some(d)) => {
                    let mut v = row.data.to_vec();
                    d.add_into(&mut v);
                    (v.into(), row.fresh.max(clock))
                }
                // Row not yet materialized: the staged sum from zeros is
                // the preview (exactly how the commit will create it).
                (None, Some(d)) => (d.clone().to_dense().into(), clock),
                (None, None) => continue,
            };
            let deltas: Option<(Arc<[RowDelta]>, Vec<WorkerId>)> =
                log.map(|l| (l.deltas.into(), l.writers));
            let workers = core.workers;
            let tokens = core
                .shipped
                .entry(*key)
                .or_insert_with(|| vec![NEVER; workers]);
            for w in readers.iter() {
                if w == source || self.vis.is_detached(w) {
                    // The writer reads-its-own-writes locally; either way
                    // a skipped reader's copy misses this wave, so any
                    // chain it held is dead.
                    tokens[w] = NEVER;
                    continue;
                }
                let push = match &deltas {
                    Some((d, writers)) if tokens[w] != NEVER && !writers.contains(&w) => {
                        delta_rows += 1;
                        PushRow::deltas(*key, tokens[w], Arc::clone(d), fresh)
                    }
                    _ => PushRow::snapshot(*key, Arc::clone(&data), fresh),
                };
                per_worker[w].push(push);
                stamp.push((*key, w));
            }
        }
        let awaiting: HashSet<WorkerId> = (0..core.workers)
            .filter(|&w| !per_worker[w].is_empty())
            .collect();
        let seq = self.vis.assign_wave((source, clock), awaiting.clone());
        for (key, w) in stamp {
            core.shipped.get_mut(&key).expect("stamped above")[w] = seq as Clock;
        }
        core.stats.rows_pushed_delta += delta_rows;
        core.metrics.rows_pushed_delta.add(delta_rows);
        for w in awaiting {
            let rows = std::mem::take(&mut per_worker[w]);
            core.stats.rows_pushed += rows.len() as u64;
            core.send_to_worker(
                w,
                ToWorker::VapPush {
                    shard: core.logical,
                    seq,
                    rows,
                },
            );
        }
    }
}

impl ServerPolicy for ValueServer {
    fn waves_per_update(&self) -> bool {
        true
    }

    fn on_update(
        &mut self,
        core: &mut ShardCore,
        source: WorkerId,
        clock: Clock,
        touched: &[Key],
    ) {
        // Deterministic mode stages the application until the table-clock
        // commit, but the wave must fire *now*: gating value visibility on
        // clock advances would deadlock (a bound-blocked reader cannot
        // tick the very clock whose commit would retire the batch it is
        // waiting on). `wave` composes preview contents in that case, so
        // readers still receive the update whose norm is in transit.
        self.wave(core, source, clock, touched);
        self.sync_bound(core);
    }

    fn on_wave_ack(&mut self, core: &mut ShardCore, worker: WorkerId, seq: u64) {
        self.vis.on_ack(worker, seq);
        self.sync_bound(core);
    }

    fn on_register(&mut self, core: &mut ShardCore, worker: WorkerId) {
        // A reader registers before its first read: making it reachable
        // here (not only at its first flush) means a revoke raised while
        // it is still computing its first clock reaches it too.
        self.mark_known(core, worker);
    }

    fn on_norm_report(
        &mut self,
        core: &mut ShardCore,
        worker: WorkerId,
        clock: Clock,
        inf_norm: f32,
    ) {
        self.vis.on_report(worker, clock, inf_norm);
        self.mark_known(core, worker);
        self.sync_bound(core);
    }

    fn on_detach(&mut self, core: &mut ShardCore, worker: WorkerId) {
        self.vis.detach(worker);
        self.sync_bound(core);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::consistency::Consistency;
    use crate::ps::msg::ToShard;
    use crate::ps::shard::Shard;
    use crate::sim::net::{NetConfig, SimNet};
    use crate::transport::TransportHandle;
    use std::collections::HashMap;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Duration;

    /// A VAP shard with `workers` instant-net worker inboxes.
    fn vap_fixture_det(
        workers: usize,
        v0: f32,
        deterministic: bool,
    ) -> (Shard, Vec<Receiver<ToWorker>>, SimNet) {
        let mut wtxs = Vec::new();
        let mut wrxs = Vec::new();
        for _ in 0..workers {
            let (wtx, wrx) = channel();
            wtxs.push(wtx);
            wrxs.push(wrx);
        }
        let (stx, _srx) = channel();
        let net = SimNet::new(NetConfig::instant(), wtxs, vec![stx]);
        let shard = Shard::new(
            0,
            workers,
            Consistency::Vap { v0 },
            TransportHandle::new(net.handle()),
            HashMap::new(),
            deterministic,
        );
        (shard, wrxs, net)
    }

    fn vap_fixture(workers: usize, v0: f32) -> (Shard, Vec<Receiver<ToWorker>>, SimNet) {
        vap_fixture_det(workers, v0, false)
    }

    fn recv(rx: &Receiver<ToWorker>) -> ToWorker {
        rx.recv_timeout(Duration::from_secs(1)).expect("message")
    }

    #[test]
    fn update_fires_ack_tracked_wave_to_other_readers() {
        let (mut shard, wrxs, net) = vap_fixture(3, 100.0);
        shard.init_row((0, 1), vec![0.0]);
        for w in 0..3 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 1.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0].into())],
            span: None,
        });
        // The wave reaches readers 1 and 2 but never the writer.
        for w in [1usize, 2] {
            match recv(&wrxs[w]) {
                ToWorker::VapPush { shard: s, rows, .. } => {
                    assert_eq!(s, 0);
                    assert_eq!(rows.len(), 1);
                    assert_eq!(&rows[0].snapshot_data()[..], &[1.0]);
                }
                other => panic!("worker {w}: unexpected {other:?}"),
            }
        }
        net.flush();
        assert!(wrxs[0].try_recv().is_err(), "writer must not receive its own wave");
    }

    #[test]
    fn bound_revoked_then_regranted_on_acks() {
        let (mut shard, wrxs, _net) = vap_fixture(2, 0.5);
        shard.init_row((0, 1), vec![0.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        // Make both workers known so bound broadcasts reach them.
        shard.handle(ToShard::NormReport {
            worker: 1,
            clock: 0,
            inf_norm: 0.0,
        });
        // Worker 0 flushes a part whose norm blows the bound.
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 5.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![5.0].into())],
            span: None,
        });
        // Worker 1 sees: revoke, then the wave.
        match recv(&wrxs[1]) {
            ToWorker::Bound { granted, .. } => assert!(!granted, "expected a revoke"),
            other => panic!("unexpected {other:?}"),
        }
        let seq = match recv(&wrxs[1]) {
            ToWorker::VapPush { seq, .. } => seq,
            other => panic!("unexpected {other:?}"),
        };
        // The writer got the revoke too.
        match recv(&wrxs[0]) {
            ToWorker::Bound { granted, .. } => assert!(!granted),
            other => panic!("unexpected {other:?}"),
        }
        // The ack retires the part: both workers get the grant back.
        shard.handle(ToShard::VapAck { worker: 1, seq });
        for wrx in &wrxs {
            match recv(wrx) {
                ToWorker::Bound { granted, .. } => assert!(granted, "expected a grant"),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn detach_regrants_and_stops_waves_to_finished_workers() {
        let (mut shard, wrxs, net) = vap_fixture(2, 0.5);
        shard.init_row((0, 1), vec![0.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 5.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![5.0].into())],
            span: None,
        });
        // Worker 1 never acks — it finishes instead. The part must retire
        // and the grant return to worker 0 (the only attached worker).
        shard.handle(ToShard::Detach { worker: 1 });
        match recv(&wrxs[0]) {
            ToWorker::Bound { granted, .. } => assert!(!granted),
            other => panic!("unexpected {other:?}"),
        }
        match recv(&wrxs[0]) {
            ToWorker::Bound { granted, .. } => assert!(granted),
            other => panic!("unexpected {other:?}"),
        }
        // Further updates produce no wave traffic to the detached worker.
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 1,
            inf_norm: 0.1,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), vec![0.1].into())],
            span: None,
        });
        // Drain anything addressed to worker 1 before the update above:
        // only the pre-detach revoke/wave pair may be present.
        net.flush();
        let mut later_wave = false;
        while let Ok(msg) = wrxs[1].try_recv() {
            if let ToWorker::VapPush { rows, .. } = &msg {
                if rows[0].snapshot_data()[0] > 5.0 {
                    later_wave = true;
                }
            }
        }
        assert!(!later_wave, "detached worker received a post-detach wave");
    }

    #[test]
    fn deterministic_wave_carries_preview_contents() {
        // Deterministic mode stages the update (store untouched until the
        // commit), yet the eager wave must carry the update's values —
        // committed contents plus the staged delta — so the in-transit
        // norm being tracked corresponds to data readers actually see.
        let (mut shard, wrxs, _net) = vap_fixture_det(2, 100.0, true);
        shard.init_row((0, 1), vec![10.0, 20.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 2.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0, 2.0].into())],
            span: None,
        });
        // The store is unchanged (staged until commit) ...
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[10.0, 20.0]);
        // ... but the wave previews the post-update values.
        match recv(&wrxs[1]) {
            ToWorker::VapPush { rows, .. } => {
                assert_eq!(rows.len(), 1);
                assert_eq!(&rows[0].snapshot_data()[..], &[11.0, 22.0]);
                assert_eq!(rows[0].fresh, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        // A concurrent writer's staged part accumulates into later
        // previews: worker 1's wave must carry BOTH staged deltas, or a
        // reader cache overwritten by it would lose worker 0's update.
        shard.handle(ToShard::NormReport {
            worker: 1,
            clock: 0,
            inf_norm: 1.0,
        });
        shard.handle(ToShard::Update {
            worker: 1,
            clock: 0,
            rows: vec![((0, 1), vec![100.0, 0.0].into())],
            span: None,
        });
        match recv(&wrxs[0]) {
            ToWorker::VapPush { rows, .. } => {
                assert_eq!(&rows[0].snapshot_data()[..], &[111.0, 22.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The commit applies the same deltas to the store.
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[111.0, 22.0]);
    }

    #[test]
    fn deterministic_wave_previews_sparse_staged_deltas() {
        // A sparse update staged for deterministic replay must still
        // preview correctly in the eager wave: the pairs overlay the
        // committed row copy, untouched indices keep their values, and
        // the commit later applies the identical delta to the store.
        use crate::ps::types::RowDelta;
        let (mut shard, wrxs, _net) = vap_fixture_det(2, 100.0, true);
        shard.init_row((0, 1), vec![10.0, 20.0, 30.0]);
        for w in 0..2 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 2.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), RowDelta::sparse(3, vec![(2, 2.0)]))],
            span: None,
        });
        // Store untouched; wave previews the sparse overlay.
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[10.0, 20.0, 30.0]);
        match recv(&wrxs[1]) {
            ToWorker::VapPush { rows, .. } => {
                assert_eq!(&rows[0].snapshot_data()[..], &[10.0, 20.0, 32.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        shard.handle(ToShard::ClockTick { worker: 0, clock: 0 });
        shard.handle(ToShard::ClockTick { worker: 1, clock: 0 });
        assert_eq!(&shard.row(&(0, 1)).unwrap().data[..], &[10.0, 20.0, 32.0]);
    }

    #[test]
    fn eager_waves_ship_delta_chains_after_the_seeding_snapshot() {
        use crate::ps::msg::PushPayload;
        let (mut shard, wrxs, _net) = vap_fixture(3, 100.0);
        shard.init_row((0, 1), vec![0.0, 0.0]);
        for w in 0..3 {
            shard.handle(ToShard::Register { key: (0, 1), worker: w });
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 1.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 1), vec![1.0, 2.0].into())],
            span: None,
        });
        // First contact: readers 1 and 2 are seeded with snapshots.
        let mut seed_seq = 0;
        for w in [1usize, 2] {
            match recv(&wrxs[w]) {
                ToWorker::VapPush { seq, rows, .. } => {
                    assert_eq!(&rows[0].snapshot_data()[..], &[1.0, 2.0]);
                    seed_seq = seq;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Second update: the chain is live, so the wave carries only the
        // triggering delta on top of the seeded base.
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 1,
            inf_norm: 3.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 1,
            rows: vec![((0, 1), RowDelta::sparse(2, vec![(1, 3.0)]))],
            span: None,
        });
        for w in [1usize, 2] {
            match recv(&wrxs[w]) {
                ToWorker::VapPush { rows, .. } => match &rows[0].payload {
                    PushPayload::Deltas { base, deltas } => {
                        assert_eq!(*base, seed_seq as Clock, "base names the seeding wave");
                        assert_eq!(deltas.len(), 1);
                        let mut v = [1.0f32, 2.0];
                        deltas[0].add_into(&mut v);
                        assert_eq!(v, [1.0, 5.0]);
                    }
                    other => panic!("expected a delta chain, got {other:?}"),
                },
                other => panic!("unexpected {other:?}"),
            }
        }
        // A pull reply replaces worker 1's copy outside the chain: its
        // next wave re-seeds with a snapshot while worker 2 stays on the
        // delta chain.
        shard.handle(ToShard::Get {
            key: (0, 1),
            worker: 1,
            min_vclock: crate::ps::types::NEVER,
            span: None,
        });
        match recv(&wrxs[1]) {
            ToWorker::Row { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 2,
            inf_norm: 1.0,
        });
        shard.handle(ToShard::Update {
            worker: 0,
            clock: 2,
            rows: vec![((0, 1), vec![0.5, 0.0].into())],
            span: None,
        });
        match recv(&wrxs[1]) {
            ToWorker::VapPush { rows, .. } => {
                assert_eq!(&rows[0].snapshot_data()[..], &[1.5, 5.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        match recv(&wrxs[2]) {
            ToWorker::VapPush { rows, .. } => {
                assert!(rows[0].payload.is_deltas(), "unbroken chain keeps shipping deltas");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn revoke_reaches_registered_workers_before_their_first_flush() {
        // A reader that has registered but not yet flushed (no NormReport)
        // must still receive a revoke raised by another worker's batch —
        // registration already proves the route.
        let (mut shard, wrxs, _net) = vap_fixture(2, 0.5);
        shard.init_row((0, 1), vec![0.0]);
        shard.handle(ToShard::Register { key: (0, 1), worker: 1 });
        shard.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 5.0,
        });
        match recv(&wrxs[1]) {
            ToWorker::Bound { granted, .. } => assert!(!granted),
            other => panic!("unexpected {other:?}"),
        }
        // And a worker first heard from while revoked is caught up.
        let (mut shard2, wrxs2, _net2) = vap_fixture(2, 0.5);
        shard2.init_row((0, 1), vec![0.0]);
        shard2.handle(ToShard::NormReport {
            worker: 0,
            clock: 0,
            inf_norm: 5.0,
        });
        shard2.handle(ToShard::Register { key: (0, 1), worker: 1 });
        match recv(&wrxs2[1]) {
            ToWorker::Bound { granted, .. } => assert!(!granted, "late registrant not caught up"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn avap_composes_clock_window_with_value_bound() {
        let avap = ValueClient::new(Some(3), 2);
        assert_eq!(avap.min_row_vclock(10), Some(6), "SSP window enforced");
        assert!(avap.reports_norms() && avap.eager_register());
        let vap = ValueClient::new(None, 2);
        assert_eq!(vap.min_row_vclock(10), None, "VAP is clock-unbounded");
        let mut c = ValueClient::new(None, 2);
        assert!(!c.read_blocked());
        c.on_bound(1, false);
        assert!(c.read_blocked());
        c.on_bound(1, true);
        assert!(!c.read_blocked());
    }
}
