//! Consistency models (DESIGN.md §7) — the paper's central object of
//! study, reduced to *pure configuration*.
//!
//! `Consistency` carries each model's parameters and knows how to parse /
//! label them; all enforcement lives in [`crate::ps::policy`], selected by
//! [`Consistency::client_policy`] / [`Consistency::server_policy`]. The
//! client and shard cores are policy-agnostic: every model shares one
//! code path and differs only in the policy pair it plugs in — mirroring
//! how ESSP is "SSP plus an eager communication strategy" in the paper,
//! and how AVAP is SSP's clock window composed with VAP's value bound.
//!
//! Model strings (CLI `--consistency`):
//!
//! | string      | model                                              |
//! |-------------|----------------------------------------------------|
//! | `bsp`       | Bulk Synchronous Parallel (== `ssp:0`)             |
//! | `ssp:S`     | Stale Synchronous Parallel, staleness `S`          |
//! | `essp:S`    | Eager SSP: same bound, server-push refresh         |
//! | `async[:R]` | unbounded; opportunistic re-pull every `R` clocks  |
//! | `vap:V0`    | value-bounded (v_t = V0/sqrt(t)), clock-unbounded  |
//! | `avap:V0:S` | value bound *and* SSP clock window (§Theory)       |

use super::policy::value::{ValueClient, ValueServer};
use super::policy::window::{AsyncClient, PullServer, PushServer, WindowClient};
use super::policy::{ClientPolicy, ServerPolicy};
use super::types::Clock;

/// Which consistency model a run uses. Pure data: the enforcement is the
/// policy pair this selects (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Consistency {
    /// Bulk Synchronous Parallel: barrier every clock (== `Ssp { s: 0 }`,
    /// kept distinct for reporting).
    Bsp,
    /// Stale Synchronous Parallel with staleness bound `s`; lazy pulls
    /// ("waits until the last minute" — paper Fig. 1 discussion).
    Ssp { s: Clock },
    /// Eager SSP: same bound `s`, but the server pushes refreshed rows to
    /// registered clients on every table-clock advance.
    Essp { s: Clock },
    /// No bound at all (Hogwild-flavored baseline). Reads never block;
    /// rows refresh opportunistically every `refresh_every` clocks.
    Async { refresh_every: Clock },
    /// Value-bounded Asynchronous Parallel: reads additionally wait until
    /// every worker's aggregated in-transit update magnitude is below
    /// `v0 / sqrt(t)`. Clock-wise genuinely unbounded. Enforced by
    /// shard-local visibility ledgers plus bound grant/revoke messages,
    /// so it runs over any transport — at the per-update-round-trip cost
    /// the paper predicts.
    Vap { v0: f32 },
    /// AVAP (the paper's §Theory suggestion): VAP's value bound composed
    /// with SSP's clock window `s`. Implemented purely as a policy pair —
    /// no client/shard core involvement.
    Avap { v0: f32, s: Clock },
}

impl Consistency {
    /// The client-side enforcement for this model.
    pub fn client_policy(&self, n_shards: usize) -> Box<dyn ClientPolicy> {
        match *self {
            Consistency::Bsp => Box::new(WindowClient::lazy(0)),
            Consistency::Ssp { s } => Box::new(WindowClient::lazy(s)),
            Consistency::Essp { s } => Box::new(WindowClient::eager(s)),
            Consistency::Async { refresh_every } => Box::new(AsyncClient { refresh_every }),
            Consistency::Vap { .. } => Box::new(ValueClient::new(None, n_shards)),
            Consistency::Avap { s, .. } => Box::new(ValueClient::new(Some(s), n_shards)),
        }
    }

    /// The shard-side enforcement for this model.
    pub fn server_policy(&self, workers: usize) -> Box<dyn ServerPolicy> {
        match *self {
            Consistency::Bsp | Consistency::Ssp { .. } | Consistency::Async { .. } => {
                Box::new(PullServer)
            }
            Consistency::Essp { .. } => Box::new(PushServer),
            Consistency::Vap { v0 } | Consistency::Avap { v0, .. } => {
                Box::new(ValueServer::new(v0, workers))
            }
        }
    }

    /// The value bound v0, for models that have one (reporting only —
    /// enforcement lives in the policies).
    pub fn value_bound(&self) -> Option<f32> {
        match self {
            Consistency::Vap { v0 } | Consistency::Avap { v0, .. } => Some(*v0),
            _ => None,
        }
    }

    /// Parse a model string (see module docs for the grammar).
    pub fn parse(s: &str) -> Result<Self, String> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        fn staleness(a: &str) -> Result<Clock, String> {
            let s: Clock = a.parse().map_err(|e| format!("bad staleness: {e}"))?;
            if s < 0 {
                return Err(format!("bad staleness: {s} is negative"));
            }
            Ok(s)
        }
        fn bound(a: &str) -> Result<f32, String> {
            let v0: f32 = a.parse().map_err(|e| format!("bad v0: {e}"))?;
            if !(v0.is_finite() && v0 > 0.0) {
                return Err(format!("bad v0: {v0} must be finite and > 0"));
            }
            Ok(v0)
        }
        match head {
            "bsp" => match arg {
                None => Ok(Consistency::Bsp),
                Some(a) => Err(format!("bsp takes no argument (got {a:?})")),
            },
            "ssp" => Ok(Consistency::Ssp {
                s: staleness(arg.ok_or("ssp needs a staleness, e.g. ssp:3")?)?,
            }),
            "essp" => Ok(Consistency::Essp {
                s: staleness(arg.ok_or("essp needs a staleness, e.g. essp:3")?)?,
            }),
            "async" => {
                let r: Clock = match arg {
                    Some(a) => a.parse().map_err(|e| format!("bad refresh: {e}"))?,
                    None => 1,
                };
                if r < 1 {
                    return Err(format!("bad refresh: {r} must be >= 1"));
                }
                Ok(Consistency::Async { refresh_every: r })
            }
            "vap" => Ok(Consistency::Vap {
                v0: bound(arg.ok_or("vap needs a value bound, e.g. vap:0.1")?)?,
            }),
            "avap" => {
                let a = arg.ok_or("avap needs a bound and staleness, e.g. avap:0.1:3")?;
                let (v, s) = a
                    .split_once(':')
                    .ok_or("avap needs both parts, e.g. avap:0.1:3")?;
                Ok(Consistency::Avap {
                    v0: bound(v)?,
                    s: staleness(s)?,
                })
            }
            _ => Err(format!("unknown consistency model {s:?}")),
        }
    }

    /// Short human/CSV label, e.g. "essp:3"; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            Consistency::Bsp => "bsp".into(),
            Consistency::Ssp { s } => format!("ssp:{s}"),
            Consistency::Essp { s } => format!("essp:{s}"),
            Consistency::Async { refresh_every } => format!("async:{refresh_every}"),
            Consistency::Vap { v0 } => format!("vap:{v0}"),
            Consistency::Avap { v0, s } => format!("avap:{v0}:{s}"),
        }
    }
}

impl std::fmt::Display for Consistency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["bsp", "ssp:3", "essp:7", "async:2", "vap:0.25", "avap:0.5:4"] {
            let m = Consistency::parse(s).unwrap();
            assert_eq!(m.label(), s);
        }
        assert_eq!(
            Consistency::parse("async").unwrap(),
            Consistency::Async { refresh_every: 1 }
        );
        for bad in [
            "", "ssp", "essp", "vap", "avap", "avap:0.5", "bsp:1", "ssp:-2", "vap:0",
            "vap:-1", "vap:inf", "async:0", "avap:1:-3", "wild:1",
        ] {
            assert!(Consistency::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn policies_enforce_the_models_bounds() {
        // BSP/SSP: the clock window; ESSP: window + eager registration.
        assert_eq!(Consistency::Bsp.client_policy(2).min_row_vclock(5), Some(4));
        let ssp = Consistency::Ssp { s: 3 }.client_policy(2);
        assert_eq!(ssp.min_row_vclock(10), Some(6));
        assert!(!ssp.eager_register());
        let essp = Consistency::Essp { s: 3 }.client_policy(2);
        assert_eq!(essp.min_row_vclock(10), Some(6));
        assert!(essp.eager_register());
        assert!(Consistency::Essp { s: 3 }.server_policy(2).pushes_on_commit());
        assert!(!Consistency::Ssp { s: 3 }.server_policy(2).pushes_on_commit());
        // Async and VAP are honestly clock-unbounded — no sentinel window.
        let vap = Consistency::Vap { v0: 0.5 }.client_policy(2);
        assert_eq!(vap.min_row_vclock(2_000_000), None);
        assert!(vap.reports_norms() && vap.eager_register() && vap.detach_on_finish());
        let asy = Consistency::Async { refresh_every: 2 }.client_policy(2);
        assert_eq!(asy.min_row_vclock(2_000_000), None);
        assert!(!asy.reports_norms());
        // AVAP composes both bounds.
        let avap = Consistency::Avap { v0: 0.5, s: 3 }.client_policy(2);
        assert_eq!(avap.min_row_vclock(10), Some(6));
        assert!(avap.reports_norms());
    }

    #[test]
    fn value_bound_is_config_introspection() {
        assert_eq!(Consistency::Vap { v0: 0.5 }.value_bound(), Some(0.5));
        assert_eq!(Consistency::Avap { v0: 0.25, s: 1 }.value_bound(), Some(0.25));
        assert_eq!(Consistency::Bsp.value_bound(), None);
    }
}
