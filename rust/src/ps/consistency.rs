//! Consistency models (DESIGN.md §7) — the paper's central object of study.
//!
//! A consistency model decides (a) when a cached row may be read, (b) how
//! rows are refreshed (lazy pull vs eager push), and (c) any additional
//! global condition (VAP's value bound). `Consistency` is pure data; the
//! enforcement lives in `client.rs` / `shard.rs` / `vap.rs`, keyed off the
//! accessors here, so every model shares one code path and differs only in
//! policy — mirroring how ESSP is "SSP plus an eager communication
//! strategy" in the paper.

use super::types::Clock;

/// Which consistency model a run uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Consistency {
    /// Bulk Synchronous Parallel: barrier every clock (== `Ssp { s: 0 }`,
    /// kept distinct for reporting).
    Bsp,
    /// Stale Synchronous Parallel with staleness bound `s`; lazy pulls
    /// ("waits until the last minute" — paper Fig. 1 discussion).
    Ssp { s: Clock },
    /// Eager SSP: same bound `s`, but the server pushes refreshed rows to
    /// registered clients on every table-clock advance.
    Essp { s: Clock },
    /// No bound at all (Hogwild-flavored baseline). Reads never block;
    /// rows refresh opportunistically every `refresh_every` clocks.
    Async { refresh_every: Clock },
    /// Value-bounded Asynchronous Parallel: reads additionally wait until
    /// every worker's aggregated in-transit update magnitude is below
    /// `v0 / sqrt(t)`. Enforced by a global tracker that is only
    /// realizable because the cluster is simulated (the paper's point).
    /// Transport is eager (ESSP-style) so visibility can be tracked.
    Vap { v0: f32 },
}

impl Consistency {
    /// Staleness bound used in the SSP read condition; `None` = unbounded.
    pub fn staleness(&self) -> Option<Clock> {
        match self {
            Consistency::Bsp => Some(0),
            Consistency::Ssp { s } | Consistency::Essp { s } => Some(*s),
            Consistency::Async { .. } => None,
            // VAP bounds *values*, not clocks; clock-wise it is unbounded
            // (we still cap at a large window to avoid pathological runs,
            // matching the paper's "updates finitely apart" assumption).
            Consistency::Vap { .. } => Some(1_000_000),
        }
    }

    /// Minimum row vclock needed for a read at worker clock `c`:
    /// all updates with clock <= c - s - 1 must be visible.
    pub fn min_row_vclock(&self, c: Clock) -> Clock {
        match self.staleness() {
            Some(s) => c - s - 1,
            None => Clock::MIN / 2,
        }
    }

    /// Does the server eagerly push refreshed rows to registered clients?
    pub fn server_push(&self) -> bool {
        matches!(self, Consistency::Essp { .. } | Consistency::Vap { .. })
    }

    /// Does the client need the global VAP value-bound check before reads?
    pub fn value_bound(&self) -> Option<f32> {
        match self {
            Consistency::Vap { v0 } => Some(*v0),
            _ => None,
        }
    }

    /// Async refresh period (None for bounded models).
    pub fn async_refresh(&self) -> Option<Clock> {
        match self {
            Consistency::Async { refresh_every } => Some(*refresh_every),
            _ => None,
        }
    }

    /// Parse "bsp" | "ssp:3" | "essp:3" | "async" | "async:5" | "vap:0.1".
    pub fn parse(s: &str) -> Result<Self, String> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "bsp" => Ok(Consistency::Bsp),
            "ssp" => {
                let s: Clock = arg
                    .ok_or("ssp needs a staleness, e.g. ssp:3")?
                    .parse()
                    .map_err(|e| format!("bad staleness: {e}"))?;
                Ok(Consistency::Ssp { s })
            }
            "essp" => {
                let s: Clock = arg
                    .ok_or("essp needs a staleness, e.g. essp:3")?
                    .parse()
                    .map_err(|e| format!("bad staleness: {e}"))?;
                Ok(Consistency::Essp { s })
            }
            "async" => {
                let r: Clock = match arg {
                    Some(a) => a.parse().map_err(|e| format!("bad refresh: {e}"))?,
                    None => 1,
                };
                Ok(Consistency::Async { refresh_every: r })
            }
            "vap" => {
                let v0: f32 = arg
                    .ok_or("vap needs a value bound, e.g. vap:0.1")?
                    .parse()
                    .map_err(|e| format!("bad v0: {e}"))?;
                Ok(Consistency::Vap { v0 })
            }
            _ => Err(format!("unknown consistency model {s:?}")),
        }
    }

    /// Short human/CSV label, e.g. "essp:3".
    pub fn label(&self) -> String {
        match self {
            Consistency::Bsp => "bsp".into(),
            Consistency::Ssp { s } => format!("ssp:{s}"),
            Consistency::Essp { s } => format!("essp:{s}"),
            Consistency::Async { refresh_every } => format!("async:{refresh_every}"),
            Consistency::Vap { v0 } => format!("vap:{v0}"),
        }
    }
}

impl std::fmt::Display for Consistency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_is_ssp0() {
        assert_eq!(Consistency::Bsp.staleness(), Some(0));
        assert_eq!(Consistency::Bsp.min_row_vclock(5), 4);
        assert_eq!(Consistency::Ssp { s: 0 }.min_row_vclock(5), 4);
    }

    #[test]
    fn ssp_window() {
        let m = Consistency::Ssp { s: 3 };
        // Read at clock 10 must see all updates <= 6.
        assert_eq!(m.min_row_vclock(10), 6);
        assert!(!m.server_push());
        assert_eq!(Consistency::Essp { s: 3 }.min_row_vclock(10), 6);
        assert!(Consistency::Essp { s: 3 }.server_push());
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["bsp", "ssp:3", "essp:7", "async:2", "vap:0.25"] {
            let m = Consistency::parse(s).unwrap();
            assert_eq!(m.label(), s);
        }
        assert_eq!(
            Consistency::parse("async").unwrap(),
            Consistency::Async { refresh_every: 1 }
        );
        assert!(Consistency::parse("ssp").is_err());
        assert!(Consistency::parse("wild:1").is_err());
    }

    #[test]
    fn vap_exposes_bound() {
        assert_eq!(Consistency::Vap { v0: 0.5 }.value_bound(), Some(0.5));
        assert_eq!(Consistency::Bsp.value_bound(), None);
        assert!(Consistency::Vap { v0: 0.5 }.server_push());
    }
}
