//! ESSPTable client library: the GET / INC / CLOCK interface workers
//! program against (paper, "PS Interface").
//!
//! The client core is consistency-agnostic: every model-specific decision
//! is delegated to the [`ClientPolicy`] its [`Consistency`] config
//! selects (see `ps::policy`):
//!   * read admission — the policy's clock window gates cached copies; a
//!     miss pulls and blocks (`ToShard::Get` with `min_vclock`, which the
//!     shard holds until the table clock is high enough);
//!   * refresh — eager registration (ESSP/VAP families) or opportunistic
//!     re-pulls (Async family);
//!   * the value gate — reads spin (draining the inbox, so acks keep
//!     flowing) while any shard's bound grant is revoked
//!     (`ToWorker::Bound`, value-bounded family);
//!   * flush obligations — per-shard ∞-norm reports ahead of the Update
//!     batches, and end-of-run `Detach` teardown.
//!
//! Read paths, fastest first:
//!   * [`PsClient::with_row`] — borrow the cached snapshot in place;
//!     allocation-free on the hot path (a reusable scratch buffer is used
//!     only when pending local writes must be overlaid).
//!   * [`PsClient::get_into`] — copy into a caller-owned reusable buffer.
//!   * [`PsClient::get`] — compat wrapper returning a fresh `Vec<f32>`.
//!
//! All blocked time is attributed to the communication side of the
//! Fig. 1 (right) breakdown via `metrics::timeline`.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::cache::RowCache;
use super::consistency::Consistency;
use super::msg::{PushPayload, ToShard, ToWorker};
use super::placement::{PlacementDelta, PlacementMap};
use super::policy::ClientPolicy;
use super::types::{Clock, Key, RowDelta, TableId, WorkerId};
use super::update::UpdateMap;
use crate::metrics::staleness::StalenessHist;
use crate::metrics::timeline::Timeline;
use crate::telemetry::registry::{Counter, LogHist, MetricsSource, Snapshot};
use crate::telemetry::spans::{Mark, SpanCtx, SpanRing, SpanSampler};
use crate::telemetry::trace::TraceRing;
use crate::transport::{NodeId, Packet, TransportHandle};
use crate::util::hash::{FxHashMap, FxHashSet};

/// Client-side configuration.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub consistency: Consistency,
    /// Row-cache capacity (0 = unbounded).
    pub cache_capacity: usize,
    /// Overlay the worker's own pending + flushed updates on reads.
    pub read_my_writes: bool,
    /// Virtual per-clock compute duration for `pace()` (see
    /// ClusterConfig::virtual_clock).
    pub virtual_clock: Option<std::time::Duration>,
    /// Telemetry: every `n` CLOCKs this worker sends a `StatsPull` to
    /// every live shard node and stashes the replies in its shard-report
    /// mirror (0 = never; out-of-band, see `ps::server` § Observability).
    pub stats_pull_every: Clock,
    /// Failover replay buffer: keep the last `n` flushed clocks' update
    /// batches so that, when the coordinator promotes a *fresh spare*
    /// (WAL-fallback, no live replica survived), this worker can re-send
    /// its recent tail and close the dead primary's un-fsynced gap. The
    /// spare's one-shot replay floors drop whatever its disk rebuild
    /// already contains. 0 disables (no per-flush clone cost); replicated
    /// or durable clusters should set it to at least the model's
    /// staleness bound + 1.
    pub resend_window: Clock,
    /// Causal request tracing: sample one of every `n` client-issued
    /// frames (Get pulls and primary Update batches) with a wire-v9 span
    /// context, so each hop can append a timed segment (`--span-sample`;
    /// 0 disables — sampled-out frames carry zero extra wire bytes).
    /// Strictly out-of-band: never consulted by any protocol decision.
    pub span_sample: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            consistency: Consistency::Essp { s: 1 },
            cache_capacity: 0,
            read_my_writes: true,
            virtual_clock: None,
            stats_pull_every: 0,
            resend_window: 0,
            span_sample: 0,
        }
    }
}

/// How long one blocking read may go *without any inbound message*
/// before the client fails fast (`ESSPTABLE_READ_TIMEOUT_S`; 0 disables,
/// default 600s). The timer restarts whenever anything arrives, so slow
/// but healthy clusters (extreme stragglers/virtual clocks) only trip it
/// if they exceed ten silent minutes — while a dead shard, which can
/// never reply, turns a forever-hang into a diagnosable failure.
fn read_stall_limit() -> Duration {
    static LIMIT: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *LIMIT.get_or_init(|| {
        match std::env::var("ESSPTABLE_READ_TIMEOUT_S")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            Some(0) => Duration::MAX,
            Some(secs) => Duration::from_secs(secs),
            None => Duration::from_secs(600),
        }
    })
}

/// Per-client counters.
#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub gets: u64,
    pub cache_hits: u64,
    pub pulls: u64,
    pub pushes_received: u64,
    pub rows_pushed_in: u64,
    pub raw_incs: u64,
    pub update_batches: u64,
    /// Pulls fanned out to a replica shard instead of the primary
    /// (policies with `replica_reads`, replicated clusters only).
    pub replica_pulls: u64,
    /// Wire-v7 delta push waves: rows whose delta chain folded onto the
    /// cached copy, and rows whose chain did not continue (copy dropped
    /// and re-pulled from the primary).
    pub rows_delta_folded: u64,
    pub rows_delta_discarded: u64,
    /// Value-bounded models: total time reads spent blocked on revoked
    /// bound grants, and the number of reads that blocked at least once.
    pub vap_stall_ns: u64,
    pub vap_stalled_reads: u64,
    /// Reads caught mid-flight by a failover: their in-flight pull
    /// targeted the node a promotion just declared dead, so the blocked
    /// read had to re-fire against the promoted owner.
    pub failover_stalls: u64,
    /// Tripwire (see `ps::server` § Observability): reads *admitted* with
    /// a guaranteed clock below the model's bound. Provably zero for the
    /// clock-bounded models — the admission loop enforces exactly that
    /// bound — so any nonzero value is a consistency bug, not load.
    pub staleness_violations: u64,
}

/// Live telemetry registry of one worker node (`Arc`-shared with the
/// admin scrape thread; see `ps::server` § Observability). Mirrors the
/// plain [`ClientStats`] counters that matter live and adds the read
/// latency histogram and stall-time counters only the live plane needs.
#[derive(Debug)]
pub struct ClientMetrics {
    /// Node label for snapshots, e.g. `"worker0"`.
    pub node: String,
    pub gets: Counter,
    pub cache_hits: Counter,
    /// Reads that missed (or were stale beyond the bound) and blocked on
    /// at least one pull round-trip.
    pub cache_misses: Counter,
    pub pulls: Counter,
    pub replica_pulls: Counter,
    pub pushes_received: Counter,
    pub rows_pushed_in: Counter,
    /// See [`ClientStats::staleness_violations`].
    pub staleness_violations: Counter,
    /// `StatsReport` snapshots received into the shard-report mirror.
    pub stats_reports: Counter,
    /// Wall time of every admitted read, miss round-trips included.
    pub read_latency_ns: LogHist,
    /// Per-read staleness lag: this worker's clock minus the served
    /// copy's guaranteed vclock, clamped at zero (log2 buckets). The
    /// non-negative mirror of the paper's clock differential — BSP pins
    /// it at 1, SSP spreads it over the window, ESSP's eager waves
    /// concentrate it near 1. Surfaced per consistency model in
    /// `RunReport` and Prometheus (see `ps::server` § Observability).
    pub staleness_lag: LogHist,
    /// Total wall time blocked in the SSP/miss pull loop.
    pub read_stall_ns: Counter,
    /// Total wall time blocked on revoked value-bound grants (VAP).
    pub vap_stall_ns: Counter,
    /// See [`ClientStats::failover_stalls`].
    pub failover_stall: Counter,
}

impl ClientMetrics {
    pub fn new(worker: WorkerId) -> Self {
        Self {
            node: format!("worker{worker}"),
            gets: Counter::new(),
            cache_hits: Counter::new(),
            cache_misses: Counter::new(),
            pulls: Counter::new(),
            replica_pulls: Counter::new(),
            pushes_received: Counter::new(),
            rows_pushed_in: Counter::new(),
            staleness_violations: Counter::new(),
            stats_reports: Counter::new(),
            read_latency_ns: LogHist::new(),
            staleness_lag: LogHist::new(),
            read_stall_ns: Counter::new(),
            vap_stall_ns: Counter::new(),
            failover_stall: Counter::new(),
        }
    }

    /// Flatten to snapshot entries (`telemetry::registry` convention).
    pub fn entries(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = vec![
            ("gets".into(), self.gets.get()),
            ("cache_hits".into(), self.cache_hits.get()),
            ("cache_misses".into(), self.cache_misses.get()),
            ("pulls".into(), self.pulls.get()),
            ("replica_pulls".into(), self.replica_pulls.get()),
            ("pushes_received".into(), self.pushes_received.get()),
            ("rows_pushed_in".into(), self.rows_pushed_in.get()),
            ("staleness_violations".into(), self.staleness_violations.get()),
            ("stats_reports".into(), self.stats_reports.get()),
            ("read_stall_ns".into(), self.read_stall_ns.get()),
            ("vap_stall_ns".into(), self.vap_stall_ns.get()),
            ("failover_stall".into(), self.failover_stall.get()),
        ];
        self.read_latency_ns.snapshot().entries("read_latency_ns", &mut out);
        self.staleness_lag.snapshot().entries("staleness_lag", &mut out);
        out
    }
}

impl MetricsSource for ClientMetrics {
    fn snapshots(&self) -> Vec<Snapshot> {
        vec![Snapshot {
            node: self.node.clone(),
            entries: self.entries(),
        }]
    }
}

/// The latest `StatsReport` snapshot per shard node, as received by one
/// worker's `StatsPull` polling. `Arc`-shared with the admin scrape
/// thread, so a worker process's `--metrics-addr` endpoint exposes the
/// shards it observes alongside its own counters — which is how
/// `run-cluster` (and `ps-top`) see live *cluster-wide* state without
/// any side channel beyond the data plane itself.
#[derive(Debug, Default)]
pub struct ShardReportMirror {
    inner: Mutex<HashMap<usize, Vec<(String, u64)>>>,
}

impl ShardReportMirror {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn store(&self, shard: usize, entries: Vec<(String, u64)>) {
        self.inner.lock().unwrap().insert(shard, entries);
    }

    /// Latest snapshot entries for `shard`, if any report arrived yet.
    pub fn get(&self, shard: usize) -> Option<Vec<(String, u64)>> {
        self.inner.lock().unwrap().get(&shard).cloned()
    }

    /// Shard ids with at least one report, ascending.
    pub fn shards(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.inner.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }
}

impl MetricsSource for ShardReportMirror {
    fn snapshots(&self) -> Vec<Snapshot> {
        let g = self.inner.lock().unwrap();
        let mut ids: Vec<usize> = g.keys().copied().collect();
        ids.sort_unstable();
        ids.into_iter()
            .map(|id| Snapshot {
                node: format!("shard{id}"),
                entries: g[&id].clone(),
            })
            .collect()
    }
}

/// The per-worker PS client.
pub struct PsClient {
    worker: WorkerId,
    clock: Clock,
    cfg: ClientConfig,
    policy: Box<dyn ClientPolicy>,
    /// Epoch-versioned key -> shard placement (`ps::placement`).
    placement: PlacementMap,
    /// A placement epoch announced by the coordinator, held until this
    /// worker's clock reaches its activation boundary.
    pending_placement: Option<PlacementDelta>,
    /// Round-robin counter for replica read fan-out.
    replica_rr: u64,
    net: TransportHandle,
    inbox: Receiver<ToWorker>,
    cache: RowCache,
    pending: UpdateMap,
    /// Row lengths per table (for sparse INC fill-in).
    row_len: FxHashMap<TableId, usize>,
    registered: FxHashSet<Key>,
    /// In-flight pulls and the shard each was sent to: the reply's cached
    /// copy is tagged with that source, so per-shard wave announcements
    /// certify only copies the announcing shard actually served.
    pulls_in_flight: FxHashMap<Key, usize>,
    /// Async mode: last clock at which a refresh pull was fired per key.
    last_refresh: FxHashMap<Key, Clock>,
    /// Keys whose last delta wave did not continue the cached chain: the
    /// next pull for such a key must hit the *primary* (whose reply
    /// clears its seeded bit, forcing the next wave back to a snapshot)
    /// rather than round-robin to a replica — a replica-served pull
    /// leaves the primary believing the chain is intact, which would
    /// re-break on every subsequent wave.
    force_primary: FxHashSet<Key>,
    /// Per shard: the latest wave vclock announced (ESSP). A cached row
    /// from shard s is guaranteed through max(row.vclock, announced[s]):
    /// delta waves carry every row dirtied since the previous wave, so a
    /// row absent from all waves up to T is certified unchanged through T.
    /// This makes wave processing O(rows in wave) instead of O(cache).
    shard_announced: Vec<Clock>,
    /// Failover replay buffer (`ClientConfig::resend_window`): the last
    /// n flushed clocks' per-primary update batches, oldest first, kept
    /// so a WAL-fallback promotion can be re-fed this worker's recent
    /// tail (the dead primary's un-fsynced gap).
    replay: std::collections::VecDeque<(Clock, Vec<Vec<(Key, RowDelta)>>)>,
    /// Reusable overlay buffer for `with_row` (read-my-writes composition
    /// without per-read allocation).
    scratch: Vec<f32>,
    /// End-of-run teardown already sent.
    finished: bool,
    started: Instant,
    pub staleness: StalenessHist,
    pub timeline: Timeline,
    pub stats: ClientStats,
    clock_started: Instant,
    /// Live telemetry registry (`Arc`-shared with the scrape thread).
    metrics: Arc<ClientMetrics>,
    /// Latest wire-shipped shard snapshots (`StatsPull` polling).
    shard_reports: Arc<ShardReportMirror>,
    /// Event-trace flight recorder, when enabled (`--trace-out`).
    trace: Option<Arc<TraceRing>>,
    /// Request-span recorder (`--trace-spans`), when attached. Strictly
    /// out-of-band: sampling only decides whether a frame carries the
    /// 12-byte span tail, never how it is routed or admitted.
    spans: Option<Arc<SpanRing>>,
    /// Deterministic per-client sampling counter (`ClientConfig::
    /// span_sample`): frame k of every `n` gets trace id
    /// `(worker << 40) | seq` — unique across workers with no
    /// coordination, and identical run-to-run.
    span_sampler: SpanSampler,
}

impl PsClient {
    pub fn new(
        worker: WorkerId,
        cfg: ClientConfig,
        placement: PlacementMap,
        net: TransportHandle,
        inbox: Receiver<ToWorker>,
        row_len: HashMap<TableId, usize>,
        started: Instant,
    ) -> Self {
        let cache_capacity = cfg.cache_capacity;
        let span_sample = cfg.span_sample;
        // Policy state that is per-shard (bound grants) covers the
        // primaries: replicas never push, report or grant.
        let policy = cfg.consistency.client_policy(placement.primaries());
        let total = placement.total_shards();
        Self {
            worker,
            clock: 0,
            cfg,
            policy,
            placement,
            pending_placement: None,
            replica_rr: 0,
            net,
            inbox,
            cache: RowCache::new(cache_capacity),
            pending: UpdateMap::new(),
            row_len: row_len.into_iter().collect(),
            registered: FxHashSet::default(),
            pulls_in_flight: FxHashMap::default(),
            last_refresh: FxHashMap::default(),
            force_primary: FxHashSet::default(),
            shard_announced: vec![super::types::NEVER; total],
            replay: std::collections::VecDeque::new(),
            scratch: Vec::new(),
            finished: false,
            started,
            staleness: StalenessHist::new(),
            timeline: Timeline::new(),
            stats: ClientStats::default(),
            clock_started: Instant::now(),
            metrics: Arc::new(ClientMetrics::new(worker)),
            shard_reports: Arc::new(ShardReportMirror::new()),
            trace: None,
            spans: None,
            span_sampler: SpanSampler::new(span_sample),
        }
    }

    /// The live telemetry registry (share with an admin scrape socket).
    pub fn metrics(&self) -> Arc<ClientMetrics> {
        Arc::clone(&self.metrics)
    }

    /// The shard-report mirror this worker's `StatsPull` polling fills
    /// (share with an admin scrape socket).
    pub fn shard_reports(&self) -> Arc<ShardReportMirror> {
        Arc::clone(&self.shard_reports)
    }

    /// Attach the event-trace flight recorder.
    pub fn set_trace(&mut self, ring: Arc<TraceRing>) {
        self.trace = Some(ring);
    }

    /// Attach the request-span recorder (sampling rate comes from
    /// [`ClientConfig::span_sample`]; with no ring attached the sampler
    /// is never consulted and every frame ships span-free).
    pub fn set_spans(&mut self, ring: Arc<SpanRing>) {
        self.spans = Some(ring);
    }

    /// Draw the next sampling decision: `Some(ctx)` for one of every
    /// `span_sample` issued frames when a recorder is attached.
    fn span_sample(&mut self) -> Option<SpanCtx> {
        if self.spans.is_none() {
            return None;
        }
        self.span_sampler
            .tick()
            .map(|seq| SpanCtx::for_worker(self.worker as u32, seq))
    }

    /// Timestamp (µs) iff `span` is sampled and a recorder is attached —
    /// zero otherwise, so unsampled paths never touch the clock.
    fn span_ts(&self, span: Option<SpanCtx>) -> u64 {
        if self.spans.is_some() && span.is_some() {
            SpanRing::now_us()
        } else {
            0
        }
    }

    /// Close a segment opened at `start_us` (no-op when unsampled).
    fn span_record(&self, span: Option<SpanCtx>, seg: &'static str, start_us: u64) {
        if let (Some(ring), Some(span)) = (&self.spans, span) {
            let now = SpanRing::now_us();
            ring.record(
                span,
                &self.metrics.node,
                seg,
                start_us,
                now.saturating_sub(start_us),
            );
        }
    }

    /// Inbound frame carrying a span: close the inbox-wait segment the
    /// transport's arrival mark opened (`reply_decode` for pull replies,
    /// same name for push waves — both measure arrival-to-pickup).
    fn span_arrive(&self, span: Option<SpanCtx>) {
        let (Some(ring), Some(span)) = (&self.spans, span) else {
            return;
        };
        let now = SpanRing::now_us();
        let start = ring
            .take_mark(span.trace_id, Mark::ArriveWorker)
            .unwrap_or(now);
        ring.record(
            span,
            &self.metrics.node,
            "reply_decode",
            start,
            now.saturating_sub(start),
        );
    }

    /// Record one lifecycle event on the attached trace ring (no-op when
    /// tracing is off), stamped with this worker's clock.
    fn trace_event(&self, kind: &str, detail: String) {
        if let Some(t) = &self.trace {
            t.record(&self.metrics.node, self.clock, kind, detail);
        }
    }

    pub fn worker_id(&self) -> WorkerId {
        self.worker
    }

    pub fn clock(&self) -> Clock {
        self.clock
    }

    pub fn consistency(&self) -> Consistency {
        self.cfg.consistency
    }

    /// Seconds since the cluster run started (for convergence curves).
    pub fn elapsed_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Send to a *logical* shard: the one chokepoint where logical ids
    /// become physical addresses, so a failed-over primary transparently
    /// re-routes to its promoted replica everywhere.
    fn send(&self, shard: usize, msg: ToShard) {
        self.net.send(
            NodeId::Worker(self.worker),
            NodeId::Shard(self.placement.node_of(shard)),
            Packet::ToShard(msg),
        );
    }

    /// Send to a *physical* node directly. Attached spares live outside
    /// the logical shard id space, so `send`'s logical routing cannot
    /// address them.
    fn send_node(&self, node: usize, msg: ToShard) {
        self.net.send(
            NodeId::Worker(self.worker),
            NodeId::Shard(node),
            Packet::ToShard(msg),
        );
    }

    /// Apply one inbound message to the cache (or route it to the
    /// policy). Pushed/pulled payloads are stored as-is (`Arc` clone) —
    /// the fan-out path never deep-copies.
    fn apply(&mut self, msg: ToWorker) {
        match msg {
            ToWorker::Row {
                key,
                data,
                vclock,
                fresh,
                span,
            } => {
                self.span_arrive(span);
                let t0 = self.span_ts(span);
                let source = self
                    .pulls_in_flight
                    .remove(&key)
                    .unwrap_or(super::cache::NO_SOURCE);
                self.cache.insert(key, data, vclock, fresh, source);
                self.span_record(span, "cache_install", t0);
            }
            ToWorker::Push {
                shard,
                vclock,
                rows,
                span,
            } => {
                self.span_arrive(span);
                let span_t0 = self.span_ts(span);
                self.stats.pushes_received += 1;
                self.stats.rows_pushed_in += rows.len() as u64;
                self.metrics.pushes_received.inc();
                self.metrics.rows_pushed_in.add(rows.len() as u64);
                for row in rows {
                    match row.payload {
                        // Snapshot: install and arm the delta chain at
                        // this wave's vclock (the shard's `last_wave`
                        // records the same token).
                        PushPayload::Snapshot(data) => {
                            self.cache
                                .insert_pushed(row.key, data, vclock, row.fresh, shard, vclock);
                        }
                        // Delta chain: fold the ordered deltas onto the
                        // cached copy iff it certifiably continues the
                        // chain (same source, token == base). On any
                        // mismatch — evicted copy, missed wave, pull or
                        // local write in between — drop the copy and
                        // route the re-pull to the primary, whose reply
                        // clears its seeded bit (next wave: snapshot).
                        PushPayload::Deltas { base, deltas } => {
                            if self.cache.fold_wave(
                                &row.key,
                                shard,
                                base,
                                &deltas,
                                vclock,
                                Some(vclock),
                                row.fresh,
                            ) {
                                self.stats.rows_delta_folded += 1;
                            } else {
                                self.stats.rows_delta_discarded += 1;
                                self.cache.remove(&row.key);
                                self.force_primary.insert(row.key);
                            }
                        }
                    }
                }
                // Rows absent from the wave are certified unchanged by the
                // shard through `vclock` (delta waves carry every dirtied
                // row): record one announcement instead of touching every
                // cached row (§Perf iteration 3).
                if vclock > self.shard_announced[shard] {
                    self.shard_announced[shard] = vclock;
                }
                self.span_record(span, "cache_install", span_t0);
                self.send(
                    shard,
                    ToShard::PushAck {
                        worker: self.worker,
                        vclock,
                    },
                );
            }
            ToWorker::VapPush { shard, seq, rows } => {
                self.stats.pushes_received += 1;
                self.stats.rows_pushed_in += rows.len() as u64;
                self.metrics.pushes_received.inc();
                self.metrics.rows_pushed_in.add(rows.len() as u64);
                // VAP eager previews: the chain token is the wave
                // sequence number, and folds carry no clock guarantee
                // (`vclock: None` — exactly force_data's contract).
                let wave = seq as Clock;
                for row in rows {
                    match row.payload {
                        PushPayload::Snapshot(data) => {
                            self.cache.force_data(row.key, data, row.fresh, shard, wave);
                        }
                        PushPayload::Deltas { base, deltas } => {
                            if self.cache.fold_wave(
                                &row.key,
                                shard,
                                base,
                                &deltas,
                                wave,
                                None,
                                row.fresh,
                            ) {
                                self.stats.rows_delta_folded += 1;
                            } else {
                                self.stats.rows_delta_discarded += 1;
                                self.cache.remove(&row.key);
                                self.force_primary.insert(row.key);
                            }
                        }
                    }
                }
                self.send(
                    shard,
                    ToShard::VapAck {
                        worker: self.worker,
                        seq,
                    },
                );
            }
            ToWorker::Bound { shard, granted } => {
                self.policy.on_bound(shard, granted);
            }
            ToWorker::Placement { delta } => {
                // Accept exactly the next epoch (duplicates idempotent,
                // gaps impossible with one coordinator).
                if delta.epoch == self.placement.epoch() + 1 {
                    self.trace_event(
                        "placement_announced",
                        format!("epoch {} (activates at clock {})", delta.epoch, delta.at_clock),
                    );
                    self.pending_placement = Some(delta);
                    self.maybe_activate_placement();
                }
            }
            ToWorker::StatsReport { shard, entries } => {
                self.metrics.stats_reports.inc();
                self.shard_reports.store(shard, entries);
            }
        }
    }

    /// Apply a pending placement epoch once this worker's clock has
    /// reached its activation boundary: flushes and reads of clocks
    /// >= `at_clock` route via the new map, and registered keys whose
    /// owner changed re-register with the new owner (so eager waves
    /// resume from there). Runs after `tick` advances the clock, and on
    /// arrival (a late learner activates immediately; its earlier
    /// flushes are conserved via the old owner's forward table).
    fn maybe_activate_placement(&mut self) {
        // A fence-free delta (pure promotion) activates on arrival: it
        // moves no keys, and waiting for a clock boundary could deadlock
        // a worker blocked reading from the dead node.
        let activate = self
            .pending_placement
            .as_ref()
            .is_some_and(|d| d.fence_free() || self.clock >= d.at_clock);
        if !activate {
            return;
        }
        let delta = self.pending_placement.take().unwrap();
        self.trace_event(
            "placement_activate",
            format!(
                "epoch {} live{}",
                delta.epoch,
                match delta.promote {
                    Some((p, n)) => format!(" (promotion: partition {p} -> node {n})"),
                    None => String::new(),
                }
            ),
        );
        let old_owners: Vec<(Key, usize)> = self
            .registered
            .iter()
            .map(|k| (*k, self.placement.shard_of(k)))
            .collect();
        // A promotion onto a node outside the logical shard id space is a
        // WAL-fallback spare (double failure: no live replica survived).
        // Its disk rebuild may miss the dead primary's un-fsynced tail;
        // decide — before the map mutates — whether this worker must
        // re-feed its replay buffer. A spare that was *attached* already
        // receives the live duplicated stream and must not get it twice.
        let wal_fallback = delta.promote.is_some_and(|(p, n)| {
            (n as usize) >= self.placement.total_shards()
                && !self
                    .placement
                    .attached_of(p as usize)
                    .contains(&(n as usize))
        });
        self.placement.apply(&delta);
        for (key, old) in old_owners {
            let now = self.placement.shard_of(&key);
            if now != old {
                self.send(
                    now,
                    ToShard::Register {
                        key,
                        worker: self.worker,
                    },
                );
            }
        }
        if let Some((primary, _)) = delta.promote {
            let primary = primary as usize;
            // The dead primary can never reply: un-track pulls sent to it
            // so blocked reads re-fire (through the send boundary they now
            // reach the promoted node). Each cleared pull is a read the
            // failover caught mid-flight — the `failover_stall` metric.
            let before = self.pulls_in_flight.len();
            self.pulls_in_flight.retain(|_, target| *target != primary);
            let stalled = (before - self.pulls_in_flight.len()) as u64;
            if stalled > 0 {
                self.stats.failover_stalls += stalled;
                self.metrics.failover_stall.add(stalled);
                self.trace_event(
                    "failover_stall",
                    format!("{stalled} in-flight pulls re-aimed at promoted partition {primary}"),
                );
            }
            // ...clear any revoked value-bound grant the dead node left
            // behind (the promoted node's fresh ledger re-revokes if it
            // must)...
            self.policy.on_bound(primary, true);
            // ...and re-register this worker's keys with the promoted
            // node, which never saw the registrations the primary held.
            let keys: Vec<Key> = self
                .registered
                .iter()
                .filter(|k| self.placement.shard_of(k) == primary)
                .copied()
                .collect();
            for key in keys {
                self.send(
                    primary,
                    ToShard::Register {
                        key,
                        worker: self.worker,
                    },
                );
            }
            // WAL-fallback: re-feed the replay tail (updates, then ticks,
            // FIFO-ordered per clock) so the spare closes the un-fsynced
            // gap; its one-shot replay floors drop what its disk rebuild
            // already holds.
            if wal_fallback {
                let mut resent = 0u64;
                for (c, batches) in self.replay.iter() {
                    let rows = &batches[primary];
                    if !rows.is_empty() {
                        resent += 1;
                        self.send(
                            primary,
                            ToShard::Update {
                                worker: self.worker,
                                clock: *c,
                                rows: rows.clone(),
                                span: None,
                            },
                        );
                    }
                    self.send(
                        primary,
                        ToShard::ClockTick {
                            worker: self.worker,
                            clock: *c,
                        },
                    );
                }
                self.trace_event(
                    "failover_resend",
                    format!(
                        "partition {primary}: replayed {} buffered clocks ({resent} update batches)",
                        self.replay.len()
                    ),
                );
            }
        }
        if let Some((primary, node)) = delta.attach {
            let primary = primary as usize;
            let node = node as usize;
            // A fresh replica joined this partition: register this
            // worker's keys with it (it has no reader state), so its
            // pull-serving — and any later promotion's first wave — sees
            // the same readership as the primary. Updates and ticks are
            // duplicated to it from this flush on (the attach fence
            // `at_clock` has passed; see `tick`).
            self.trace_event(
                "replica_attach",
                format!("node {node} joins partition {primary}'s read fan-out"),
            );
            let keys: Vec<Key> = self
                .registered
                .iter()
                .filter(|k| self.placement.shard_of(k) == primary)
                .copied()
                .collect();
            for key in keys {
                self.send_node(
                    node,
                    ToShard::Register {
                        key,
                        worker: self.worker,
                    },
                );
            }
        }
    }

    fn drain_inbox(&mut self) {
        while let Ok(msg) = self.inbox.try_recv() {
            self.apply(msg);
        }
    }

    /// Block on the inbox until at least one message is applied, charging
    /// the wait to comm time. Returns whether anything arrived (the
    /// liveness signal for the read-stall watchdog).
    fn wait_inbox(&mut self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => {
                self.timeline.add_comm(t0.elapsed());
                self.apply(msg);
                self.drain_inbox();
                true
            }
            Err(RecvTimeoutError::Timeout) => {
                self.timeline.add_comm(t0.elapsed());
                false
            }
            Err(RecvTimeoutError::Disconnected) => {
                panic!("worker {} inbox disconnected mid-run", self.worker)
            }
        }
    }

    /// Value-bound read gate: spin (draining acks, so shards can retire
    /// in-transit batches and re-grant) while any shard's bound grant is
    /// revoked. No-op for policies without a value bound.
    fn value_gate(&mut self) {
        if !self.policy.read_blocked() {
            return;
        }
        let t0 = Instant::now();
        self.stats.vap_stalled_reads += 1;
        let mut last_msg = Instant::now();
        while self.policy.read_blocked() {
            if self.wait_inbox(Duration::from_micros(200)) {
                last_msg = Instant::now();
            }
            if last_msg.elapsed() > read_stall_limit() {
                panic!(
                    "worker {} value-gated read got no messages for {:?} \
                     waiting for bound grants: shard unreachable or cluster \
                     wedged (raise/disable via ESSPTABLE_READ_TIMEOUT_S)",
                    self.worker,
                    last_msg.elapsed()
                );
            }
        }
        let stalled = t0.elapsed().as_nanos() as u64;
        self.stats.vap_stall_ns += stalled;
        self.metrics.vap_stall_ns.add(stalled);
    }

    /// Core of every read: enforce the policy's read conditions, then
    /// return the cached snapshot (an `Arc` clone — no payload copy). The
    /// overlay of this worker's pending writes is left to the public
    /// wrappers.
    fn get_snapshot(&mut self, key: Key) -> Arc<[f32]> {
        self.stats.gets += 1;
        self.metrics.gets.inc();
        let read_started = Instant::now();
        self.drain_inbox();
        self.value_gate();

        // ESSP/VAP families: register for eager pushes on first access.
        if self.policy.eager_register() && self.registered.insert(key) {
            self.send(
                self.placement.shard_of(&key),
                ToShard::Register {
                    key,
                    worker: self.worker,
                },
            );
        }

        // The clock window (None = clock-unbounded: any cached copy is
        // admissible, and pulls are served at whatever clock the shard
        // holds).
        let min_vclock = self.policy.min_row_vclock(self.clock);
        let pull_floor = min_vclock.unwrap_or(Clock::MIN / 2);
        let key_shard = self.placement.shard_of(&key);
        let mut pulled = false;
        let mut stalled_since: Option<Instant> = None;
        loop {
            // Re-read each pass: waves applied in wait_inbox move it.
            let announced = self.shard_announced[key_shard];
            if let Some(row) = self.cache.get(&key) {
                // Effective guarantee: the copy's own vclock, or the
                // owner's latest wave announcement if newer (the row was
                // in no wave since, hence unchanged) — applicable only
                // when the copy actually came FROM the owner: a shard's
                // announcements certify its own serving history, never a
                // copy from a key's previous owner (live migration) or
                // from a replica.
                let vclock = if row.source == key_shard {
                    row.vclock.max(announced)
                } else {
                    row.vclock
                };
                let ok = match min_vclock {
                    Some(mv) => vclock >= mv,
                    None => true,
                };
                if ok {
                    // The paper's clock differential: c_param - c_worker,
                    // where c_param is the row copy's *guaranteed* clock
                    // ("all updates from all workers generated before
                    // clock x have been applied" — exactly our vclock).
                    // BSP pins this at -1; SSP spreads it over the window;
                    // ESSP's eager waves concentrate it near -1.
                    let differential = vclock - self.clock;
                    let data = Arc::clone(&row.data);
                    self.staleness.record(differential);
                    // Staleness-lag observability: the same differential,
                    // negated and clamped — how many clocks *behind* this
                    // worker the served copy was guaranteed at, in log2
                    // buckets for the live plane.
                    self.metrics
                        .staleness_lag
                        .record((self.clock - vclock).max(0) as u64);
                    // Tripwire, not flow control: the admission above just
                    // enforced the bound, so this counter is provably zero
                    // unless a wave/announcement/migration path certifies a
                    // copy it shouldn't — which is exactly what we want a
                    // first-class, asserted-on counter for.
                    if min_vclock.is_some_and(|mv| vclock < mv) {
                        self.stats.staleness_violations += 1;
                        self.metrics.staleness_violations.inc();
                    }
                    let elapsed = read_started.elapsed().as_nanos() as u64;
                    self.metrics.read_latency_ns.record(elapsed);
                    if !pulled {
                        self.stats.cache_hits += 1;
                        self.metrics.cache_hits.inc();
                    } else {
                        self.metrics.cache_misses.inc();
                        self.metrics.read_stall_ns.add(elapsed);
                    }
                    // Opportunistic refresh (Async family).
                    if let Some(every) = self.policy.refresh_every() {
                        let last = *self.last_refresh.get(&key).unwrap_or(&(Clock::MIN / 2));
                        if self.clock - last >= every && !self.pulls_in_flight.contains_key(&key)
                        {
                            self.fire_pull(key, Clock::MIN / 2);
                            self.last_refresh.insert(key, self.clock);
                        }
                    }
                    return data;
                }
            }
            // Cache miss or stale beyond the bound: pull and block.
            if !self.pulls_in_flight.contains_key(&key) {
                self.fire_pull(key, pull_floor);
            }
            if !pulled {
                stalled_since = Some(Instant::now());
            }
            pulled = true;
            if self.wait_inbox(Duration::from_millis(100)) {
                // Something arrived: the cluster is alive, restart the
                // silence timer.
                stalled_since = Some(Instant::now());
            }
            // Liveness watchdog: total *silence* for this long means the
            // shard is unreachable (e.g. its process died — over TCP the
            // reply can then never arrive) or the cluster is wedged.
            // Fail fast with context instead of spinning forever.
            if let Some(t0) = stalled_since {
                if t0.elapsed() > read_stall_limit() {
                    panic!(
                        "worker {} read of {key:?} got no messages for {:?} \
                         waiting for vclock >= {pull_floor}: shard unreachable \
                         or cluster wedged (raise/disable via \
                         ESSPTABLE_READ_TIMEOUT_S)",
                        self.worker,
                        t0.elapsed()
                    );
                }
            }
        }
    }

    /// Fold this worker's pending (not yet flushed) deltas into `buf`
    /// (read-my-writes), if enabled. A sparse pending delta touches only
    /// its nnz indices.
    fn overlay_pending(&self, key: &Key, buf: &mut [f32]) {
        if self.cfg.read_my_writes {
            if let Some(delta) = self.pending.pending(key) {
                delta.add_into(buf);
            }
        }
    }

    /// GET: returns a copy of the row, enforcing the read condition of the
    /// configured consistency model. Compat wrapper over [`Self::get_into`]
    /// — inner loops should prefer `get_into` / [`Self::with_row`], which
    /// do not allocate per read.
    pub fn get(&mut self, key: Key) -> Vec<f32> {
        let data = self.get_snapshot(key);
        let mut out = data.to_vec();
        self.overlay_pending(&key, &mut out);
        out
    }

    /// GET into a caller-owned buffer (cleared and refilled). The buffer's
    /// allocation is reused across reads, so steady-state GETs perform no
    /// heap allocation.
    pub fn get_into(&mut self, key: Key, buf: &mut Vec<f32>) {
        let data = self.get_snapshot(key);
        buf.clear();
        buf.extend_from_slice(&data);
        self.overlay_pending(&key, buf);
    }

    /// GET without copying: runs `f` on the row snapshot in place. When
    /// read-my-writes has pending local deltas for `key`, the overlay is
    /// composed in a client-owned reusable scratch buffer; otherwise `f`
    /// borrows the cached `Arc` payload directly (zero copies, zero
    /// allocations).
    pub fn with_row<R>(&mut self, key: Key, f: impl FnOnce(&[f32]) -> R) -> R {
        let data = self.get_snapshot(key);
        if self.cfg.read_my_writes && self.pending.pending(&key).is_some() {
            let mut scratch = std::mem::take(&mut self.scratch);
            scratch.clear();
            scratch.extend_from_slice(&data);
            self.overlay_pending(&key, &mut scratch);
            let out = f(&scratch);
            self.scratch = scratch;
            out
        } else {
            f(&data)
        }
    }

    fn fire_pull(&mut self, key: Key, min_vclock: Clock) {
        self.stats.pulls += 1;
        self.metrics.pulls.inc();
        // A key flagged by a failed delta fold must pull from the
        // primary: only the primary's reply clears its seeded bit, so a
        // replica-served pull would leave it shipping doomed deltas on
        // every wave.
        let force_primary = self.force_primary.remove(&key);
        // Replica read fan-out: policies whose whole admission is the
        // clock window may round-robin pulls over the owner and its
        // replicas — the replica enforces the same `min_vclock` wait on
        // its own (identically fed) table clock.
        let target = if !force_primary
            && self.placement.replicas_per() > 0
            && self.policy.replica_reads()
        {
            let pick = self.replica_rr;
            self.replica_rr = self.replica_rr.wrapping_add(1);
            let target = self.placement.read_target(&key, pick);
            if self.placement.is_replica(target) {
                self.stats.replica_pulls += 1;
                self.metrics.replica_pulls.inc();
            }
            target
        } else {
            self.placement.shard_of(&key)
        };
        self.pulls_in_flight.insert(key, target);
        let span = self.span_sample();
        let t0 = self.span_ts(span);
        self.send(
            target,
            ToShard::Get {
                key,
                worker: self.worker,
                min_vclock,
                span,
            },
        );
        self.span_record(span, "client_issue", t0);
    }

    /// INC: additive update, coalesced client-side until CLOCK.
    pub fn inc(&mut self, key: Key, delta: &[f32]) {
        self.stats.raw_incs += 1;
        self.pending.inc(key, delta);
    }

    /// Sparse INC: (index, value) pairs against a row of the table's
    /// width. The pairs coalesce — and ship — sparse (O(nnz) wire bytes,
    /// not O(row len)) unless the pending row's fill crosses the density
    /// threshold or a dense INC touches it (see `ps::update`).
    pub fn inc_sparse(&mut self, key: Key, pairs: &[(usize, f32)]) {
        self.stats.raw_incs += 1;
        let len = *self
            .row_len
            .get(&key.0)
            .unwrap_or_else(|| panic!("unknown table {} in inc_sparse", key.0));
        self.pending.inc_sparse(key, len, pairs);
    }

    /// CLOCK: flush coalesced updates, commit the tick, advance the clock.
    pub fn tick(&mut self) {
        // Inbound traffic — placement announcements in particular — must
        // be seen even by workers that never read between flushes.
        self.drain_inbox();
        // Read-my-writes across the flush: fold the deltas into our cached
        // copies in place — borrowed from the coalescing map, no per-row
        // clone; `drain_routed` then *moves* the same deltas into the
        // outgoing Update batches. (The server copy will include them once
        // applied; replacing pushes/pulls overwrite, so nothing
        // double-counts.)
        if self.cfg.read_my_writes {
            let clock = self.clock;
            for (key, delta) in self.pending.iter() {
                self.cache.apply_delta(key, delta);
                // The copy now reflects this worker's clock-`c` updates.
                self.cache.bump_fresh(key, clock);
            }
        }
        let primaries = self.placement.primaries();
        let replicas = self.placement.replicas_per();
        let total = self.placement.total_shards();
        let placement = &self.placement;
        let batches = self.pending.drain_routed(primaries, |k| placement.shard_of(k));
        // Value-bounded models: report each part's ∞-norm to its shard
        // ahead of the Update on the same FIFO link, so the shard
        // registers the in-transit mass before it can apply the part.
        // Zero-norm (incl. empty) parts are reported too — every shard's
        // decay clock t must count every flush of every worker. The norm
        // scan costs O(batch) and runs only under these policies; a
        // sparse part is scanned directly off its stored pairs (implicit
        // zeros cannot raise a max of absolute values). Reports cover the
        // primaries only: replicas never grant or revoke.
        let report_norms = self.policy.reports_norms();
        // Failover replay buffer: keep this flush's per-primary batches
        // for `resend_window` clocks (see `maybe_activate_placement`'s
        // WAL-fallback path). Cloned before the sends consume them.
        if self.cfg.resend_window > 0 {
            self.replay.push_back((self.clock, batches.clone()));
            while self.replay.len() as Clock > self.cfg.resend_window {
                self.replay.pop_front();
            }
        }
        for (shard, rows) in batches.into_iter().enumerate() {
            if report_norms {
                let inf_norm = rows
                    .iter()
                    .map(|(_, d)| d.inf_norm())
                    .fold(0.0f32, |m, x| m.max(x));
                self.send(
                    shard,
                    ToShard::NormReport {
                        worker: self.worker,
                        clock: self.clock,
                        inf_norm,
                    },
                );
            }
            if !rows.is_empty() {
                // Replicas receive the same per-worker FIFO update
                // stream, duplicated client-side — the honest cost of
                // replication without server-side relays; replica reads
                // then need no extra machinery to stay within the
                // model's staleness bound.
                for r in 0..replicas {
                    let rep = primaries + shard * replicas + r;
                    // A promoted replica already receives the primary-
                    // addressed copy (the send boundary re-routes it): a
                    // duplicate here would double-apply every delta. A
                    // dead replica can never receive one.
                    if rep == self.placement.node_of(shard) || self.placement.is_dead(rep) {
                        continue;
                    }
                    // Duplicated copies (replicas, spares) ship span-free:
                    // one trace id must not ride several concurrent
                    // frames, or their arrival marks would collide.
                    self.send(
                        rep,
                        ToShard::Update {
                            worker: self.worker,
                            clock: self.clock,
                            rows: rows.clone(),
                            span: None,
                        },
                    );
                }
                // Attached spares (re-replication) get the same
                // duplicated per-worker FIFO stream as configured
                // replicas, from the attach fence on.
                for &a in self.placement.attached_of(shard) {
                    self.send_node(
                        a,
                        ToShard::Update {
                            worker: self.worker,
                            clock: self.clock,
                            rows: rows.clone(),
                            span: None,
                        },
                    );
                }
                self.stats.update_batches += 1;
                // Only the primary-bound copy is span-eligible: it is the
                // frame whose apply the model's guarantees hang off.
                let span = self.span_sample();
                let t0 = self.span_ts(span);
                self.send(
                    shard,
                    ToShard::Update {
                        worker: self.worker,
                        clock: self.clock,
                        rows,
                        span,
                    },
                );
                self.span_record(span, "client_issue", t0);
            }
        }
        // Commit tick to every shard node (FIFO after the updates) —
        // active primaries, idle provisioned primaries and replicas
        // alike: their table clocks advance in lockstep, which is what
        // bounds replica read lag and lets an idle shard accept migrated
        // keys mid-run with a live clock.
        for shard in 0..total {
            // A failed-over primary's node is dead, and its promoted
            // replica commits its OWN tick below — a re-routed second
            // copy would double-commit the clock there. A dead replica
            // (detected, not promoted from) can never receive one.
            if self.placement.node_of(shard) != shard || self.placement.is_dead(shard) {
                continue;
            }
            self.send(
                shard,
                ToShard::ClockTick {
                    worker: self.worker,
                    clock: self.clock,
                },
            );
        }
        // Attached spares commit the same per-worker tick stream (FIFO
        // after their duplicated updates above), keeping their table
        // clocks in lockstep for pull admission and later promotion.
        for shard in 0..primaries {
            for &a in self.placement.attached_of(shard) {
                self.send_node(
                    a,
                    ToShard::ClockTick {
                        worker: self.worker,
                        clock: self.clock,
                    },
                );
            }
        }
        self.clock += 1;
        // Telemetry polling (out-of-band): ask every live shard node for
        // its metrics snapshot. Same dead-node skip as the tick loop —
        // a failed-over primary's node can never reply.
        if self.cfg.stats_pull_every > 0 && self.clock % self.cfg.stats_pull_every == 0 {
            for shard in 0..total {
                if self.placement.node_of(shard) != shard || self.placement.is_dead(shard) {
                    continue;
                }
                self.send(
                    shard,
                    ToShard::StatsPull {
                        worker: self.worker,
                    },
                );
            }
        }
        // A pending placement whose boundary this tick crossed becomes
        // live before the next clock's reads and flushes.
        self.maybe_activate_placement();
        self.timeline.finish_clock(self.clock_started.elapsed());
        self.clock_started = Instant::now();
    }

    /// End-of-run teardown: policies with per-worker server-side state
    /// (value-bounded family) notify every shard that this worker will
    /// never read or ack again — otherwise the remaining workers would
    /// stall forever waiting on its acks. Idempotent; a no-op for other
    /// policies.
    pub fn finish(&mut self) {
        if self.finished || !self.policy.detach_on_finish() {
            return;
        }
        self.finished = true;
        for shard in 0..self.placement.primaries() {
            self.send(
                shard,
                ToShard::Detach {
                    worker: self.worker,
                },
            );
        }
    }

    /// Pace the virtual clock: after finishing `done` of `total` work
    /// units, sleep until `done/total` of the virtual clock duration has
    /// elapsed. Under a virtual clock, real compute is fast, so without
    /// pacing every GET would cluster at the start of the clock — unlike
    /// the modeled system, where reads interleave with seconds of compute.
    /// No-op when no virtual clock is configured.
    pub fn pace(&mut self, done: usize, total: usize) {
        let Some(v) = self.cfg.virtual_clock else { return };
        if total == 0 {
            return;
        }
        let target = v.mul_f64(done as f64 / total as f64);
        let elapsed = self.clock_started.elapsed();
        // Only sleep ahead-of-schedule *compute* — waiting time counts.
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
    }

    /// Number of pending (coalesced) rows not yet flushed.
    pub fn pending_rows(&self) -> usize {
        self.pending.len()
    }

    /// Cache size (rows).
    pub fn cached_rows(&self) -> usize {
        self.cache.len()
    }

    /// Configure the cache capacity (rows; 0 = unbounded). Exposed for the
    /// LRU-eviction experiments.
    pub fn set_cache_capacity(&mut self, capacity: usize) {
        self.cache = RowCache::new(capacity);
    }
}
