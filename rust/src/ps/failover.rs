//! Detection-driven failover: the coordinator's failure detector and
//! self-healing control loop.
//!
//! Earlier revisions of the failover plane pre-armed each doomed primary
//! with a "dying act" — the kill fault itself emitted the promotion
//! delta, which only works when the failure schedule is known up front.
//! This module replaces that with an *observing* coordinator: a
//! [`Detector`] thread that learns about shard death the way a real
//! cluster does, and then drives the same recovery machinery the armed
//! path used.
//!
//! # Evidence
//!
//! Two independent signals feed the detector:
//!
//! * **Heartbeats.** Every `heartbeat_every` the detector polls each
//!   live shard node with `ToShard::StatsPull { worker:
//!   COORD_STATS_WORKER }`; the shard replies with a `StatsReport`
//!   addressed to [`NodeId::Coordinator`]. The reply's arrival is the
//!   liveness proof; its payload doubles as a telemetry snapshot (the
//!   detector reads the synthetic `table_clock` entry to plan
//!   re-replication fences). A node that misses `missed_k` consecutive
//!   polls *and* has been silent for `suspect_after` becomes
//!   **suspected**.
//! * **Peer events.** Both transports surface a dead inbox as
//!   [`PeerEvent::Disconnected`]`{ clean: false }` — the TCP reader sees
//!   the socket drop, the SimNet router sees the mpsc receiver hung up.
//!   An unclean disconnect **confirms** death immediately; a suspected
//!   node with no event is confirmed once its silence reaches
//!   `2 * suspect_after` (so a heartbeat-only plane still heals).
//!
//! # Recovery (per confirmed death)
//!
//! ```text
//! healthy --> suspected --> dead
//!                            |-- node served a partition?
//!                            |     no:  fence-free `dead` delta (clients
//!                            |          drop it from the read fan-out)
//!                            |     yes: promote, in preference order:
//!                            |       1. a live configured replica  -> Promote
//!                            |       2. a spare + durable WAL      -> ReplicaCatchUp
//!                            |          (from_disk) then Promote; clients
//!                            |          re-send their in-window tail
//!                            |       3. nothing                    -> loud
//!                            |          `failover_unreplicated` verdict
//!                            `-- re_replicate && a spare is free?
//!                                  gate spare (ReplicaCatchUp), announce the
//!                                  fenced attach delta, arm the serving
//!                                  node's cut (ReplicaSync)
//! ```
//!
//! Promotion deltas are fence-free (`at_clock: 0`): the replica has been
//! fed the complete per-worker FIFO stream all along, so the switch is
//! pure re-addressing. Attach deltas are fenced at `observed table clock
//! + attach_slack`, aligning the client-side stream duplication with the
//! serving node's `ReplicaSync` row cut.
//!
//! The detector has no direct channel to the workers in a multi-process
//! cluster, so promotion-less deltas (attach / dead-only) are relayed
//! through a live serving shard (`ToShard::Promote` with `promote:
//! None`); promotion deltas reach the workers via the promoted node's
//! own relay.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::msg::{ToShard, ToWorker, COORD_STATS_WORKER};
use super::placement::{PlacementDelta, PlacementMap};
use super::types::Clock;
use crate::telemetry::trace::TraceRing;
use crate::transport::{NodeId, Packet, PeerEvent, TransportHandle};

/// Failure-detector tuning. The defaults favor fast in-process tests;
/// `run-cluster` maps `--heartbeat-every` / `--suspect-after` /
/// `--re-replicate` / `--failover-deadline` onto these.
#[derive(Debug, Clone)]
pub struct FailoverConfig {
    /// Heartbeat poll period.
    pub heartbeat_every: Duration,
    /// Minimum silence before a node missing `missed_k` polls is
    /// suspected; twice this confirms death without a peer event.
    pub suspect_after: Duration,
    /// Consecutive missed heartbeats required for suspicion.
    pub missed_k: u32,
    /// After promoting, catch a fresh spare up from the serving node and
    /// attach it as a replacement replica.
    pub re_replicate: bool,
    /// Clocks of headroom between the highest observed table clock and a
    /// re-replication attach fence. Must exceed the staleness bound plus
    /// the announce latency (in clocks) or the cut misses flushes.
    pub attach_slack: Clock,
    /// Abort budget for the `run-cluster` driver: a confirmed death with
    /// no recovery path (or a recovery that never completes) past this
    /// deadline fails the run with a named error. The detector itself
    /// only records the verdict; enforcement is the driver's.
    pub deadline: Option<Duration>,
}

impl Default for FailoverConfig {
    fn default() -> Self {
        Self {
            heartbeat_every: Duration::from_millis(25),
            suspect_after: Duration::from_millis(150),
            missed_k: 3,
            re_replicate: false,
            attach_slack: 8,
            deadline: None,
        }
    }
}

/// Liveness state of one shard node, as the detector believes it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Suspected,
    Dead,
}

struct NodeState {
    health: Health,
    /// Last proof of life (heartbeat reply; detector start initially).
    last_seen: Instant,
    /// Consecutive heartbeat polls without a reply.
    missed: u32,
}

/// What the detector did, harvested after its thread joins.
#[derive(Debug, Clone, Default)]
pub struct FailoverReport {
    /// Every promotion emitted: (logical primary, new serving node).
    pub promotions: Vec<(usize, usize)>,
    /// Every re-replication attach emitted: (logical primary, spare).
    pub attached: Vec<(usize, usize)>,
    /// Nodes confirmed dead, in detection order.
    pub dead: Vec<usize>,
    /// Primaries that died with no live replica, no usable spare, and no
    /// durable WAL — the unreplicated-promotion window. A nonzero list
    /// is a failed run.
    pub unreplicated: Vec<usize>,
    /// First failover's window: ms from the victim's last proof of life
    /// to the promotion being emitted.
    pub failover_ms: Option<u64>,
    /// Heartbeat polls sent.
    pub heartbeats: u64,
    /// Placement epoch after all emitted deltas.
    pub final_epoch: u64,
}

/// The coordinator's failure-detecting control loop. Owns its copy of
/// the placement map and advances it with every delta it emits; sends
/// through the transport as [`NodeId::Coordinator`].
pub struct Detector {
    cfg: FailoverConfig,
    placement: PlacementMap,
    net: TransportHandle,
    events: Receiver<PeerEvent>,
    inbox: Receiver<ToWorker>,
    nodes: Vec<NodeState>,
    /// Free spare node ids (>= the provisioned total), LIFO.
    spares: Vec<usize>,
    /// Whether shard nodes run the durability plane (enables the
    /// from-disk double-failure fallback).
    durable: bool,
    trace: Option<Arc<TraceRing>>,
    stop: Arc<AtomicBool>,
    /// Deaths fully *resolved* (promotion emitted, verdict recorded, or
    /// dead-only delta relayed) — the launcher polls this after the
    /// workers finish to wait out any in-flight recovery before
    /// harvesting.
    resolved: Arc<AtomicUsize>,
    /// Highest table clock observed in any heartbeat reply.
    max_clock: Clock,
    report: FailoverReport,
}

impl Detector {
    pub fn new(
        cfg: FailoverConfig,
        placement: PlacementMap,
        spares: Vec<usize>,
        durable: bool,
        net: TransportHandle,
        events: Receiver<PeerEvent>,
        inbox: Receiver<ToWorker>,
        trace: Option<Arc<TraceRing>>,
        stop: Arc<AtomicBool>,
    ) -> Self {
        let now = Instant::now();
        let tracked = placement.total_shards() + spares.len();
        Self {
            cfg,
            placement,
            net,
            events,
            inbox,
            nodes: (0..tracked)
                .map(|_| NodeState {
                    health: Health::Healthy,
                    last_seen: now,
                    missed: 0,
                })
                .collect(),
            spares,
            durable,
            trace,
            stop,
            resolved: Arc::new(AtomicUsize::new(0)),
            max_clock: 0,
            report: FailoverReport::default(),
        }
    }

    /// Handle the launcher polls to wait for in-flight recoveries: the
    /// count of confirmed deaths whose recovery action has been emitted.
    pub fn resolved_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.resolved)
    }

    fn trace_event(&self, kind: &str, detail: String) {
        if let Some(t) = &self.trace {
            t.record("coordinator", self.max_clock, kind, detail);
        }
    }

    /// Run until the stop flag is raised; returns what happened.
    pub fn run(mut self) -> FailoverReport {
        // First poll fires immediately so short tests get a baseline.
        let mut last_poll = Instant::now() - self.cfg.heartbeat_every;
        while !self.stop.load(Ordering::Acquire) {
            self.drain_events();
            self.drain_inbox();
            if last_poll.elapsed() >= self.cfg.heartbeat_every {
                self.poll();
                last_poll = Instant::now();
            }
            self.check_silence();
            std::thread::sleep(Duration::from_millis(1));
        }
        self.report.final_epoch = self.placement.epoch();
        self.report
    }

    fn drain_events(&mut self) {
        while let Ok(ev) = self.events.try_recv() {
            match ev {
                PeerEvent::Disconnected {
                    node: NodeId::Shard(n),
                    clean: false,
                } => self.confirm_dead(n, "peer_down"),
                // Worker completion and clean teardown are not failures.
                PeerEvent::Disconnected { .. } | PeerEvent::Connected(_) => {}
            }
        }
    }

    fn drain_inbox(&mut self) {
        while let Ok(msg) = self.inbox.try_recv() {
            if let ToWorker::StatsReport { shard, entries } = msg {
                if let Some(s) = self.nodes.get_mut(shard) {
                    if s.health != Health::Dead {
                        s.health = Health::Healthy;
                        s.last_seen = Instant::now();
                        s.missed = 0;
                    }
                }
                if let Some(&(_, clk)) =
                    entries.iter().find(|(name, _)| name == "table_clock")
                {
                    self.max_clock = self.max_clock.max(clk as Clock);
                }
            }
        }
    }

    /// One heartbeat round: charge a miss to every live node, then poll
    /// it. The reply (drained next iterations) zeroes the counter.
    fn poll(&mut self) {
        for n in 0..self.nodes.len() {
            if self.nodes[n].health == Health::Dead {
                continue;
            }
            self.nodes[n].missed = self.nodes[n].missed.saturating_add(1);
            self.report.heartbeats += 1;
            self.net.send(
                NodeId::Coordinator,
                NodeId::Shard(n),
                Packet::ToShard(ToShard::StatsPull {
                    worker: COORD_STATS_WORKER,
                }),
            );
        }
    }

    /// Escalate silent nodes: suspect at (`missed_k` misses AND
    /// `suspect_after` silence); confirm at twice the silence bound if no
    /// peer event arrived first.
    fn check_silence(&mut self) {
        for n in 0..self.nodes.len() {
            let silent = self.nodes[n].last_seen.elapsed();
            match self.nodes[n].health {
                Health::Healthy
                    if self.nodes[n].missed >= self.cfg.missed_k
                        && silent >= self.cfg.suspect_after =>
                {
                    self.nodes[n].health = Health::Suspected;
                    self.trace_event(
                        "failover_suspect",
                        format!(
                            "node {n}: {} missed polls, silent {silent:?}",
                            self.nodes[n].missed
                        ),
                    );
                }
                Health::Suspected if silent >= 2 * self.cfg.suspect_after => {
                    self.confirm_dead(n, "heartbeat_timeout");
                }
                _ => {}
            }
        }
    }

    /// A node is confirmed dead: record it, then fail its partition over
    /// (if it was serving one) or just drop it from the fan-out.
    fn confirm_dead(&mut self, node: usize, why: &str) {
        match self.nodes.get(node) {
            Some(s) if s.health != Health::Dead => {}
            _ => return,
        }
        let window = self.nodes[node].last_seen.elapsed();
        self.nodes[node].health = Health::Dead;
        self.spares.retain(|&s| s != node);
        self.report.dead.push(node);
        self.trace_event(
            "failover_dead",
            format!("node {node} confirmed dead via {why} after {window:?}"),
        );
        // Which logical partition (if any) was this node serving?
        let served = (0..self.placement.primaries())
            .find(|&p| self.placement.node_of(p) == node);
        match served {
            Some(p) => self.fail_over(p, node, window),
            None => self.emit_dead_only(node),
        }
        self.resolved.fetch_add(1, Ordering::AcqRel);
    }

    /// Promote a replacement for logical primary `p`, whose serving node
    /// `dead_node` just died.
    fn fail_over(&mut self, p: usize, dead_node: usize, window: Duration) {
        // Preference 1: a configured replica of p that is still alive.
        let live_replica = (0..self.placement.replicas_per())
            .map(|r| self.placement.replica_of(p, r))
            .find(|&rep| {
                rep != dead_node
                    && self
                        .nodes
                        .get(rep)
                        .is_some_and(|s| s.health != Health::Dead)
            });
        let target = match live_replica {
            Some(rep) => rep,
            None => {
                // Preference 2: a spare rebuilt from the dead node's WAL.
                match (self.durable, self.spares.pop()) {
                    (true, Some(spare)) => {
                        // Gate + graft before the Promote arrives (FIFO on
                        // the coordinator->spare link): the spare rebuilds
                        // the dead node's durable generation, then the
                        // Promote installs the real policy over live rows.
                        self.net.send(
                            NodeId::Coordinator,
                            NodeId::Shard(spare),
                            Packet::ToShard(ToShard::ReplicaCatchUp {
                                epoch: self.placement.epoch() + 1,
                                at_clock: 0,
                                source: dead_node as u32,
                                from_disk: true,
                            }),
                        );
                        spare
                    }
                    _ => {
                        // The unreplicated-promotion window: nothing can
                        // serve this partition. Record the loud verdict;
                        // the driver turns it into a nonzero exit.
                        self.report.unreplicated.push(p);
                        self.trace_event(
                            "failover_unreplicated",
                            format!(
                                "partition {p}: node {dead_node} died with no live \
                                 replica and no usable spare (durable={})",
                                self.durable
                            ),
                        );
                        eprintln!(
                            "coordinator: partition {p} is DOWN — node {dead_node} \
                             died unreplicated (no replica, no spare/WAL)"
                        );
                        self.emit_dead_only(dead_node);
                        return;
                    }
                }
            }
        };
        let delta = PlacementDelta {
            epoch: self.placement.epoch() + 1,
            at_clock: 0,
            grow_active: None,
            promote: Some((p as u32, target as u32)),
            attach: None,
            dead: vec![dead_node as u32],
            moves: vec![],
        };
        self.placement.apply(&delta);
        self.trace_event(
            "failover_promote",
            format!("partition {p}: node {dead_node} -> node {target} ({window:?} window)"),
        );
        self.net.send(
            NodeId::Coordinator,
            NodeId::Shard(target),
            Packet::ToShard(ToShard::Promote { delta }),
        );
        self.report
            .failover_ms
            .get_or_insert(window.as_millis() as u64);
        self.report.promotions.push((p, target));
        if self.cfg.re_replicate {
            self.re_replicate(p);
        }
    }

    /// Record a death that moved no partition (a replica or idle spare):
    /// a fence-free dead-only delta so clients drop the node from the
    /// read fan-out and stop duplicating updates to it.
    fn emit_dead_only(&mut self, node: usize) {
        let delta = PlacementDelta {
            epoch: self.placement.epoch() + 1,
            at_clock: 0,
            grow_active: None,
            promote: None,
            attach: None,
            dead: vec![node as u32],
            moves: vec![],
        };
        self.placement.apply(&delta);
        self.relay_to_workers(delta);
    }

    /// Catch a fresh spare up from partition `p`'s serving node and
    /// attach it as a replacement replica.
    fn re_replicate(&mut self, p: usize) {
        let Some(spare) = self.spares.pop() else {
            self.trace_event(
                "failover_no_spare",
                format!("partition {p} stays under-replicated: spare pool empty"),
            );
            return;
        };
        let serving = self.placement.node_of(p);
        // The fence must land ahead of every client's next flush: observed
        // table clock + slack. Clients activate the attach at that flush
        // boundary, exactly where the serving node cuts its row copy.
        let at_clock = (self.max_clock + self.cfg.attach_slack).max(1);
        let delta = PlacementDelta {
            epoch: self.placement.epoch() + 1,
            at_clock,
            grow_active: None,
            promote: None,
            attach: Some((p as u32, spare as u32)),
            dead: vec![],
            moves: vec![],
        };
        self.placement.apply(&delta);
        self.trace_event(
            "failover_rereplicate",
            format!("partition {p}: spare {spare} catching up from node {serving} at clock {at_clock}"),
        );
        // Order matters, all on FIFO control links: gate the spare first,
        // then announce the fenced delta (via the serving relay), then arm
        // the source cut. The spare's gate must exist before any handoff
        // or duplicated update can reach it.
        self.net.send(
            NodeId::Coordinator,
            NodeId::Shard(spare),
            Packet::ToShard(ToShard::ReplicaCatchUp {
                epoch: delta.epoch,
                at_clock,
                source: serving as u32,
                from_disk: false,
            }),
        );
        self.relay_to_workers(delta.clone());
        self.net.send(
            NodeId::Coordinator,
            NodeId::Shard(serving),
            Packet::ToShard(ToShard::ReplicaSync {
                epoch: delta.epoch,
                at_clock,
                target: spare as u32,
            }),
        );
        self.report.attached.push((p, spare));
    }

    /// Ship a promotion-less delta to the workers through a live serving
    /// shard (`Promote { promote: None }` is a pure relay there).
    fn relay_to_workers(&mut self, delta: PlacementDelta) {
        let relay = (0..self.placement.primaries())
            .map(|p| self.placement.node_of(p))
            .find(|&n| {
                self.nodes
                    .get(n)
                    .is_some_and(|s| s.health != Health::Dead)
            });
        match relay {
            Some(node) => self.net.send(
                NodeId::Coordinator,
                NodeId::Shard(node),
                Packet::ToShard(ToShard::Promote { delta }),
            ),
            None => eprintln!(
                "coordinator: no live shard left to relay placement epoch {}",
                delta.epoch
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Mutex;
    use crate::transport::Transport;

    /// Transport stub capturing every send.
    struct CaptureNet(Mutex<std::sync::mpsc::Sender<(NodeId, Packet)>>);
    impl Transport for CaptureNet {
        fn send(&self, _src: NodeId, dst: NodeId, packet: Packet) {
            let _ = self.0.lock().unwrap().send((dst, packet));
        }
    }

    fn harness(
        placement: PlacementMap,
        spares: Vec<usize>,
        durable: bool,
        cfg: FailoverConfig,
    ) -> (
        Detector,
        std::sync::mpsc::Sender<PeerEvent>,
        std::sync::mpsc::Sender<ToWorker>,
        Receiver<(NodeId, Packet)>,
        Arc<AtomicBool>,
    ) {
        let (ev_tx, ev_rx) = channel();
        let (in_tx, in_rx) = channel();
        let (cap_tx, cap_rx) = channel();
        let stop = Arc::new(AtomicBool::new(false));
        let det = Detector::new(
            cfg,
            placement,
            spares,
            durable,
            TransportHandle::new(CaptureNet(Mutex::new(cap_tx))),
            ev_rx,
            in_rx,
            None,
            Arc::clone(&stop),
        );
        (det, ev_tx, in_tx, cap_rx, stop)
    }

    fn drain(rx: &Receiver<(NodeId, Packet)>) -> Vec<(NodeId, Packet)> {
        let mut out = Vec::new();
        while let Ok(x) = rx.try_recv() {
            out.push(x);
        }
        out
    }

    #[test]
    fn peer_down_promotes_live_replica() {
        let placement = PlacementMap::new(2, 2, 1); // nodes 0,1 primaries; 2,3 replicas
        let (mut det, ev_tx, _in_tx, cap_rx, _stop) =
            harness(placement, vec![], false, FailoverConfig::default());
        ev_tx
            .send(PeerEvent::Disconnected {
                node: NodeId::Shard(0),
                clean: false,
            })
            .unwrap();
        det.drain_events();
        let sent = drain(&cap_rx);
        let promote = sent
            .iter()
            .find_map(|(dst, p)| match p {
                Packet::ToShard(ToShard::Promote { delta }) => Some((*dst, delta.clone())),
                _ => None,
            })
            .expect("no Promote emitted");
        assert_eq!(promote.0, NodeId::Shard(2), "must target shard 0's replica");
        assert_eq!(promote.1.promote, Some((0, 2)));
        assert_eq!(promote.1.dead, vec![0]);
        assert!(promote.1.fence_free());
        assert_eq!(det.report.promotions, vec![(0, 2)]);
        assert!(det.report.failover_ms.is_some());
        assert!(det.report.unreplicated.is_empty());
    }

    #[test]
    fn double_failure_skips_dead_replica_and_falls_back_to_wal() {
        // The replica (node 2) dies first, then the primary (node 0):
        // promotion must NOT target the dead replica; with a durable
        // spare the coordinator orders a from-disk rebuild instead.
        let placement = PlacementMap::new(2, 2, 1);
        let spare = placement.total_shards(); // 4
        let (mut det, ev_tx, _in_tx, cap_rx, _stop) =
            harness(placement, vec![spare], true, FailoverConfig::default());
        for node in [2usize, 0] {
            ev_tx
                .send(PeerEvent::Disconnected {
                    node: NodeId::Shard(node),
                    clean: false,
                })
                .unwrap();
        }
        det.drain_events();
        let sent = drain(&cap_rx);
        // The spare is gated with a from-disk catch-up BEFORE its Promote.
        let spare_msgs: Vec<&Packet> = sent
            .iter()
            .filter(|(dst, _)| *dst == NodeId::Shard(spare))
            .map(|(_, p)| p)
            .collect();
        assert!(
            matches!(
                spare_msgs[0],
                Packet::ToShard(ToShard::ReplicaCatchUp {
                    from_disk: true,
                    source: 0,
                    ..
                })
            ),
            "first spare message must be the from-disk catch-up, got {spare_msgs:?}"
        );
        assert!(matches!(
            spare_msgs[1],
            Packet::ToShard(ToShard::Promote { delta })
                if delta.promote == Some((0, spare as u32))
        ));
        // Nothing was ever addressed to the dead replica after its death.
        assert_eq!(det.report.promotions, vec![(0, spare)]);
        assert!(det.report.unreplicated.is_empty());
    }

    #[test]
    fn unreplicated_death_is_a_loud_verdict() {
        let placement = PlacementMap::new(2, 2, 0); // no replicas
        let (mut det, ev_tx, _in_tx, cap_rx, _stop) =
            harness(placement, vec![], false, FailoverConfig::default());
        ev_tx
            .send(PeerEvent::Disconnected {
                node: NodeId::Shard(1),
                clean: false,
            })
            .unwrap();
        det.drain_events();
        assert_eq!(det.report.unreplicated, vec![1]);
        assert!(det.report.promotions.is_empty());
        // The death is still recorded for the clients (relayed dead-only
        // delta through the surviving shard 0).
        let sent = drain(&cap_rx);
        assert!(sent.iter().any(|(dst, p)| *dst == NodeId::Shard(0)
            && matches!(p, Packet::ToShard(ToShard::Promote { delta })
                if delta.promote.is_none() && delta.dead == vec![1])));
    }

    #[test]
    fn heartbeat_silence_escalates_to_promotion() {
        let placement = PlacementMap::new(1, 1, 1);
        let cfg = FailoverConfig {
            heartbeat_every: Duration::from_millis(1),
            suspect_after: Duration::from_millis(5),
            missed_k: 2,
            ..Default::default()
        };
        let (mut det, _ev_tx, in_tx, cap_rx, _stop) = harness(placement, vec![], false, cfg);
        // The replica keeps replying; the primary never does.
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut last_poll = Instant::now() - Duration::from_millis(1);
        while det.report.promotions.is_empty() && Instant::now() < deadline {
            in_tx
                .send(ToWorker::StatsReport {
                    shard: 1,
                    entries: vec![("table_clock".into(), 3)],
                })
                .unwrap();
            det.drain_inbox();
            if last_poll.elapsed() >= det.cfg.heartbeat_every {
                det.poll();
                last_poll = Instant::now();
            }
            det.check_silence();
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(det.report.promotions, vec![(0, 1)]);
        assert_eq!(det.max_clock, 3, "table_clock entry must be harvested");
        assert!(det.report.heartbeats > 0);
        let sent = drain(&cap_rx);
        assert!(sent
            .iter()
            .any(|(_, p)| matches!(p, Packet::ToShard(ToShard::StatsPull { worker })
                if *worker == COORD_STATS_WORKER)));
    }

    #[test]
    fn re_replication_orders_gate_announce_cut() {
        let placement = PlacementMap::new(2, 2, 1);
        let spare = placement.total_shards();
        let cfg = FailoverConfig {
            re_replicate: true,
            attach_slack: 4,
            ..Default::default()
        };
        let (mut det, ev_tx, in_tx, cap_rx, _stop) =
            harness(placement, vec![spare], false, cfg);
        in_tx
            .send(ToWorker::StatsReport {
                shard: 1,
                entries: vec![("table_clock".into(), 10)],
            })
            .unwrap();
        det.drain_inbox();
        ev_tx
            .send(PeerEvent::Disconnected {
                node: NodeId::Shard(0),
                clean: false,
            })
            .unwrap();
        det.drain_events();
        let sent = drain(&cap_rx);
        // Expected order after the Promote: gate the spare, relay the
        // fenced attach delta, arm the serving node's cut.
        let idx = |pred: &dyn Fn(&Packet) -> bool| {
            sent.iter().position(|(_, p)| pred(p)).expect("message missing")
        };
        let gate = idx(&|p| {
            matches!(p, Packet::ToShard(ToShard::ReplicaCatchUp { from_disk: false, .. }))
        });
        let announce = idx(&|p| {
            matches!(p, Packet::ToShard(ToShard::Promote { delta })
                if delta.attach == Some((0, spare as u32)))
        });
        let cut = idx(&|p| {
            matches!(p, Packet::ToShard(ToShard::ReplicaSync { target, .. })
                if *target == spare as u32)
        });
        assert!(gate < announce && announce < cut, "gate={gate} announce={announce} cut={cut}");
        // The fence clears the observed clock by the configured slack.
        let Some((_, Packet::ToShard(ToShard::ReplicaSync { at_clock, .. }))) =
            sent.iter().find(|(_, p)| matches!(p, Packet::ToShard(ToShard::ReplicaSync { .. })))
        else {
            unreachable!()
        };
        assert_eq!(*at_clock, 14);
        assert_eq!(det.report.attached, vec![(0, spare)]);
        // The spare left the pool.
        assert!(det.spares.is_empty());
    }
}
