//! Artifact discovery: `artifacts/meta.json` + `artifacts/*.hlo.txt`.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which embed the L1
//! Pallas kernels) to HLO *text* and records, per artifact, the positional
//! input/output tensor specs plus — for LM artifacts — the parameter-row
//! layout contract (`params`: ordered name/shape list). This module reads
//! that metadata back so the rust side can drive the executables blind.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One positional input/output of an artifact.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_, _>>()?,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

/// LM parameter layout entry (PS row contract).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// LM geometry recorded at lowering time.
#[derive(Debug, Clone)]
pub struct LmConfigMeta {
    pub preset: String,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub batch: usize,
    pub param_count: usize,
}

/// Metadata for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub params: Option<Vec<ParamSpec>>,
    pub lm_config: Option<LmConfigMeta>,
    /// MF block geometry (bm, bn, k) if this is an MF artifact.
    pub mf_block: Option<(usize, usize, usize)>,
}

/// A directory of AOT artifacts.
#[derive(Debug)]
pub struct ArtifactDir {
    dir: PathBuf,
    metas: Vec<ArtifactMeta>,
}

impl ArtifactDir {
    /// Default location: `$ESSPTABLE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ESSPTABLE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {} (run `make artifacts` first)", meta_path.display()))?;
        let root = Json::parse(&text).context("parse meta.json")?;
        let mut metas = Vec::new();
        for (name, j) in root.as_obj()? {
            let inputs = j
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = j
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let params = match j.opt("params")? {
                Some(p) => Some(
                    p.as_arr()?
                        .iter()
                        .map(|e| {
                            Ok(ParamSpec {
                                name: e.get("name")?.as_str()?.to_string(),
                                shape: e
                                    .get("shape")?
                                    .as_arr()?
                                    .iter()
                                    .map(|v| v.as_usize())
                                    .collect::<Result<_, _>>()?,
                            })
                        })
                        .collect::<Result<Vec<_>>>()?,
                ),
                None => None,
            };
            let lm_config = match j.opt("lm_config")? {
                Some(c) => Some(LmConfigMeta {
                    preset: c.get("preset")?.as_str()?.to_string(),
                    vocab: c.get("vocab")?.as_usize()?,
                    seq: c.get("seq")?.as_usize()?,
                    d_model: c.get("d_model")?.as_usize()?,
                    n_layer: c.get("n_layer")?.as_usize()?,
                    n_head: c.get("n_head")?.as_usize()?,
                    batch: c.get("batch")?.as_usize()?,
                    param_count: c.get("param_count")?.as_usize()?,
                }),
                None => None,
            };
            let mf_block = match j.opt("block")? {
                Some(b) => Some((
                    b.get("bm")?.as_usize()?,
                    b.get("bn")?.as_usize()?,
                    b.get("k")?.as_usize()?,
                )),
                None => None,
            };
            metas.push(ArtifactMeta {
                name: name.clone(),
                inputs,
                outputs,
                params,
                lm_config,
                mf_block,
            });
        }
        Ok(Self { dir, metas })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        self.metas.iter().map(|m| m.name.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("artifact {name} not in meta.json (have: {:?})", self.names()))
    }

    /// Path of the HLO text module for an artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_meta(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("meta.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_mf_meta() {
        let dir = std::env::temp_dir().join(format!("esspt-art-{}", std::process::id()));
        write_meta(
            &dir,
            r#"{"mf_block_64x64x32": {
                "inputs": [{"name":"L","shape":[64,32],"dtype":"float32"}],
                "outputs": [{"name":"dL","shape":[64,32],"dtype":"float32"}],
                "block": {"bm":64,"bn":64,"k":32}
            }}"#,
        );
        let art = ArtifactDir::open(&dir).unwrap();
        let m = art.meta("mf_block_64x64x32").unwrap();
        assert_eq!(m.inputs[0].shape, vec![64, 32]);
        assert_eq!(m.inputs[0].dtype, DType::F32);
        assert_eq!(m.mf_block, Some((64, 64, 32)));
        assert!(m.params.is_none());
        assert!(art.meta("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn parses_lm_meta_params() {
        let dir = std::env::temp_dir().join(format!("esspt-art2-{}", std::process::id()));
        write_meta(
            &dir,
            r#"{"lm_step_x": {
                "inputs": [{"name":"tokens","shape":[2,8],"dtype":"int32"}],
                "outputs": [{"name":"loss","shape":[],"dtype":"float32"}],
                "params": [{"name":"tok_emb","shape":[64,16]}],
                "lm_config": {"preset":"x","vocab":64,"seq":8,"d_model":16,
                              "n_layer":1,"n_head":2,"batch":2,"param_count":1024}
            }}"#,
        );
        let art = ArtifactDir::open(&dir).unwrap();
        let m = art.meta("lm_step_x").unwrap();
        assert_eq!(m.inputs[0].dtype, DType::I32);
        let params = m.params.as_ref().unwrap();
        assert_eq!(params[0].elements(), 1024);
        assert_eq!(m.lm_config.as_ref().unwrap().vocab, 64);
        assert!(art.hlo_path("lm_step_x").ends_with("lm_step_x.hlo.txt"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactDir::open("/nonexistent-essptable").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
