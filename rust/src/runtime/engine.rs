//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and serves execute requests from worker threads.
//!
//! Architecture notes (see /opt/xla-example and DESIGN.md):
//!  * Interchange is HLO *text* — `HloModuleProto::from_text_file`
//!    reassigns instruction ids, avoiding the 64-bit-id proto rejection.
//!  * The modules were lowered with `return_tuple=True`, so the execution
//!    result is always a tuple literal; we untuple into per-output vectors.
//!  * One `RuntimeService` thread owns the PJRT client and all compiled
//!    executables; workers talk to it through a channel (`RuntimeHandle`,
//!    cloneable). On the 1-core testbed serialized execution costs
//!    nothing, and it sidesteps `!Send` FFI handles. Python is never
//!    involved at run time.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{ArtifactDir, DType};

/// A tensor crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    /// Unwrap f32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    /// Consume into f32 payload.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims)
            .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e}"))?;
        let (dims, ty) = match shape {
            xla::Shape::Array(a) => (
                a.dims().iter().map(|&d| d as usize).collect::<Vec<_>>(),
                a.primitive_type(),
            ),
            other => bail!("non-array output shape {other:?}"),
        };
        match ty {
            xla::PrimitiveType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
            }),
            xla::PrimitiveType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
            }),
            other => bail!("unsupported output primitive type {other:?}"),
        }
    }
}

/// The engine proper: PJRT client + compiled executables. Not `Send`; owned
/// by the service thread (or used single-threaded in tests/benches).
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: ArtifactDir,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new(artifacts: ArtifactDir) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            artifacts,
            executables: HashMap::new(),
        })
    }

    pub fn artifacts(&self) -> &ArtifactDir {
        &self.artifacts
    }

    /// Compile (and cache) an artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parse HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. Inputs are validated against meta.json.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        let meta = self.artifacts.meta(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype {
                bail!(
                    "{name}: input {} expects {:?}{:?}, got {:?}{:?}",
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executables.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e}"))?;
        // Modules are lowered with return_tuple=True.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of {name}: {e}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Preload {
        name: String,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
}

impl RuntimeHandle {
    /// Execute an artifact, blocking until the result is ready.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Compile ahead of the run (so compile time is not charged to clock 0).
    pub fn preload(&self, name: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Preload {
                name: name.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

/// The runtime service: spawns the engine-owning thread.
pub struct RuntimeService {
    tx: Sender<Request>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl RuntimeService {
    pub fn start(artifacts: ArtifactDir) -> Result<Self> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || service_loop(artifacts, rx, ready_tx))
            .context("spawn runtime thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during init"))??;
        Ok(Self {
            tx,
            join: Mutex::new(Some(join)),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle {
            tx: self.tx.clone(),
        }
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn service_loop(artifacts: ArtifactDir, rx: Receiver<Request>, ready: Sender<Result<()>>) {
    let mut engine = match Engine::new(artifacts) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute {
                name,
                inputs,
                reply,
            } => {
                let _ = reply.send(engine.execute(&name, &inputs));
            }
            Request::Preload { name, reply } => {
                let _ = reply.send(engine.load(&name));
            }
            Request::Shutdown => break,
        }
    }
}
