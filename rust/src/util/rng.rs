//! Deterministic PRNG (no `rand` crate in the offline vendor set).
//!
//! PCG64-DXSM-flavored generator: fast, statistically solid for simulation
//! use, and — critically for the experiments — fully reproducible from a
//! `u64` seed. `Rng::fork(tag)` derives independent streams per worker /
//! per subsystem so thread scheduling never perturbs the sampled workload.

/// SplitMix64, used for seeding and as a cheap one-shot mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic, forkable PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

impl Rng {
    /// Build from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Build from a seed and a stream id; distinct streams are independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut s = seed;
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        let mut t = stream ^ 0xDA3E_39CB_94B9_5BDB;
        let c = splitmix64(&mut t);
        let mut rng = Self {
            state: ((a as u128) << 64) | b as u128,
            inc: (((c as u128) << 64) | (stream as u128)) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream, e.g. one per worker.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::with_stream(seed, tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // PCG-DXSM on 128-bit state.
        const MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xDA94_2042_E4DD_58B5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (stateless variant: discards pair).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Draw from a symmetric Dirichlet(alpha) of dimension `k`.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s > 0.0 {
            for x in &mut v {
                *x /= s;
            }
        } else {
            v.fill(1.0 / k as f64);
        }
        v
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Returns `weights.len() - 1` if the total mass underflows.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.usize_below(weights.len().max(1));
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(42, 0);
        let mut b = Rng::with_stream(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn forks_are_independent_of_parent_continuation() {
        let mut parent = Rng::new(7);
        let mut child = parent.fork(3);
        let c1: Vec<u64> = (0..4).map(|_| child.next_u64()).collect();
        // Re-derive: same fork tag from same parent state gives same child.
        let mut parent2 = Rng::new(7);
        let mut child2 = parent2.fork(3);
        let c2: Vec<u64> = (0..4).map(|_| child2.next_u64()).collect();
        assert_eq!(c1, c2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        let v = r.dirichlet(0.1, 50);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
        // Rough proportion check.
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..10_000 {
            if r.categorical(&w) == 1 {
                c1 += 1;
            }
        }
        assert!((c1 as f64 / 10_000.0 - 0.75).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
