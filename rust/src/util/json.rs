//! Minimal JSON parser + writer (no serde in the offline vendor set).
//!
//! The parser covers the full JSON grammar needed by `artifacts/meta.json`
//! and experiment configs; the writer is used by `metrics::export`. Both are
//! intentionally small — this repo's hot path never touches JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so output and
/// tests are deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse / access errors. Display and `std::error::Error` are implemented
/// by hand (no `thiserror` in the offline build).
#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    Type(&'static str, &'static str),
    MissingKey(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => {
                write!(f, "unexpected character {c:?} at byte {i}")
            }
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type(want, got) => write!(f, "expected {want} but found {got}"),
            JsonError::MissingKey(k) => write!(f, "missing key {k:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = parse_value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(JsonError::Trailing(i));
        }
        Ok(v)
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(JsonError::Type("object", other.type_name())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(JsonError::Type("array", other.type_name())),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type("string", other.type_name())),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type("number", other.type_name())),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type("bool", other.type_name())),
        }
    }

    /// Object field access with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional field: Ok(None) when absent or null.
    pub fn opt(&self, key: &str) -> Result<Option<&Json>, JsonError> {
        Ok(match self.as_obj()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        })
    }

    /// Serialize; `indent` of 0 means compact.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        write_value(self, indent, 0, &mut out);
        out
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_string_pretty(0))
    }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, i);
    let c = *b.get(*i).ok_or(JsonError::Eof(*i))?;
    match c {
        b'{' => parse_obj(b, i),
        b'[' => parse_arr(b, i),
        b'"' => Ok(Json::Str(parse_string(b, i)?)),
        b't' => parse_lit(b, i, "true", Json::Bool(true)),
        b'f' => parse_lit(b, i, "false", Json::Bool(false)),
        b'n' => parse_lit(b, i, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, i),
        _ => Err(JsonError::Unexpected(c as char, *i)),
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &str, v: Json) -> Result<Json, JsonError> {
    if b[*i..].starts_with(lit.as_bytes()) {
        *i += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(b[*i] as char, *i))
    }
}

fn parse_num(b: &[u8], i: &mut usize) -> Result<Json, JsonError> {
    let start = *i;
    if b[*i] == b'-' {
        *i += 1;
    }
    while *i < b.len() && matches!(b[*i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *i += 1;
    }
    std::str::from_utf8(&b[start..*i])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    let mut s = String::new();
    loop {
        let c = *b.get(*i).ok_or(JsonError::Eof(*i))?;
        match c {
            b'"' => {
                *i += 1;
                return Ok(s);
            }
            b'\\' => {
                *i += 1;
                let e = *b.get(*i).ok_or(JsonError::Eof(*i))?;
                match e {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        if *i + 4 >= b.len() {
                            return Err(JsonError::Eof(*i));
                        }
                        let hex = std::str::from_utf8(&b[*i + 1..*i + 5])
                            .map_err(|_| JsonError::BadEscape(*i))?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|_| JsonError::BadEscape(*i))?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *i += 4;
                    }
                    _ => return Err(JsonError::BadEscape(*i)),
                }
                *i += 1;
            }
            _ => {
                // Copy a run of plain bytes (valid UTF-8 by construction).
                let start = *i;
                while *i < b.len() && b[*i] != b'"' && b[*i] != b'\\' {
                    *i += 1;
                }
                s.push_str(std::str::from_utf8(&b[start..*i]).map_err(|_| JsonError::Eof(start))?);
            }
        }
    }
}

fn parse_arr(b: &[u8], i: &mut usize) -> Result<Json, JsonError> {
    *i += 1; // consume '['
    let mut v = Vec::new();
    skip_ws(b, i);
    if *b.get(*i).ok_or(JsonError::Eof(*i))? == b']' {
        *i += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, i)?);
        skip_ws(b, i);
        match *b.get(*i).ok_or(JsonError::Eof(*i))? {
            b',' => *i += 1,
            b']' => {
                *i += 1;
                return Ok(Json::Arr(v));
            }
            c => return Err(JsonError::Unexpected(c as char, *i)),
        }
    }
}

fn parse_obj(b: &[u8], i: &mut usize) -> Result<Json, JsonError> {
    *i += 1; // consume '{'
    let mut m = BTreeMap::new();
    skip_ws(b, i);
    if *b.get(*i).ok_or(JsonError::Eof(*i))? == b'}' {
        *i += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, i);
        if *b.get(*i).ok_or(JsonError::Eof(*i))? != b'"' {
            return Err(JsonError::Unexpected(b[*i] as char, *i));
        }
        let key = parse_string(b, i)?;
        skip_ws(b, i);
        if *b.get(*i).ok_or(JsonError::Eof(*i))? != b':' {
            return Err(JsonError::Unexpected(b[*i] as char, *i));
        }
        *i += 1;
        m.insert(key, parse_value(b, i)?);
        skip_ws(b, i);
        match *b.get(*i).ok_or(JsonError::Eof(*i))? {
            b',' => *i += 1,
            b'}' => {
                *i += 1;
                return Ok(Json::Obj(m));
            }
            c => return Err(JsonError::Unexpected(c as char, *i)),
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, indent: usize, depth: usize, out: &mut String) {
    let nl = |out: &mut String, d: usize| {
        if indent > 0 {
            out.push('\n');
            out.push_str(&" ".repeat(indent * d));
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (k, item) in a.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                nl(out, depth + 1);
                write_value(item, indent, depth + 1, out);
            }
            if !a.is_empty() {
                nl(out, depth);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (k, (key, item)) in m.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                nl(out, depth + 1);
                write_escaped(key, out);
                out.push(':');
                if indent > 0 {
                    out.push(' ');
                }
                write_value(item, indent, depth + 1, out);
            }
            if !m.is_empty() {
                nl(out, depth);
            }
            out.push('}');
        }
    }
}

/// Convenience builders for export code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn str(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty(2)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_meta_like_structure() {
        let src = r#"{"mf": {"inputs": [{"name":"L","shape":[64,32],"dtype":"float32"}]}}"#;
        let v = Json::parse(src).unwrap();
        let inputs = v.get("mf").unwrap().get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str().unwrap(), "L");
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(), 32);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string_pretty(0), "3");
        assert_eq!(Json::Num(3.5).to_string_pretty(0), "3.5");
    }
}
