//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! Measures wall time over warmup + timed iterations and reports
//! mean / p50 / p95 / throughput. Used by `rust/benches/*` (harness=false
//! targets), which print the rows the paper's tables correspond to.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement.
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    /// Items-per-second given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.iters
        );
    }

    pub fn print_throughput(&self, items: f64, unit: &str) {
        println!(
            "{:<44} {:>10.3?} mean  {:>12.0} {unit}/s  ({} iters)",
            self.name,
            self.mean,
            self.throughput(items),
            self.iters
        );
    }
}

/// Run `f` for `warmup` + `iters` iterations, timing the latter.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats::mean(&times)),
        p50: Duration::from_secs_f64(stats::percentile(&times, 50.0)),
        p95: Duration::from_secs_f64(stats::percentile(&times, 95.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.p95 >= r.p50);
    }
}
