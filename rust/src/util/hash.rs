//! Deterministic FxHash-style hasher for the PS hot path.
//!
//! Every `(TableId, RowId)`-keyed map in the data plane (shard row store,
//! client row cache, update coalescing, sim-net link tables) hashes small
//! fixed-width integer keys millions of times per run. `std`'s default
//! SipHash is DoS-resistant but ~5-10x slower on such keys, and its
//! per-process random seed makes iteration order (and thus microbench
//! variance) nondeterministic. This is the rustc-style multiply-rotate
//! Fx scheme: no dependencies, deterministic across processes, a handful
//! of cycles per key. Not DoS-resistant — fine for a system whose keys
//! are dense internal ids, never attacker-controlled strings.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed with the deterministic Fx hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
/// `HashSet` keyed with the deterministic Fx hasher.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;
/// Zero-sized deterministic `BuildHasher`.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Golden-ratio-derived odd multiplier (same constant as rustc's FxHash).
const SEED: u64 = 0x517c_c1b7_2722_0a95;

/// The hasher state: one 64-bit word, folded with rotate-xor-multiply.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Fold 8 bytes at a time; the ragged tail is zero-padded. Length
        // is not mixed in separately: keys here are fixed-width integers,
        // so no two distinct keys produce the same byte stream.
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add(i as u64);
        self.add((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }

    #[inline]
    fn write_i8(&mut self, i: i8) {
        self.add(i as u8 as u64);
    }

    #[inline]
    fn write_i16(&mut self, i: i16) {
        self.add(i as u16 as u64);
    }

    #[inline]
    fn write_i32(&mut self, i: i32) {
        self.add(i as u32 as u64);
    }

    #[inline]
    fn write_i64(&mut self, i: i64) {
        self.add(i as u64);
    }

    #[inline]
    fn write_isize(&mut self, i: isize) {
        self.add(i as usize as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_across_instances() {
        let key: crate::ps::types::Key = (3, 12345);
        // Two independent hasher instances agree (no per-process seed).
        assert_eq!(hash_of(&key), hash_of(&key));
    }

    #[test]
    fn distinct_keys_rarely_collide() {
        // Dense sequential row ids (the common PS key pattern) must spread.
        let mut seen = std::collections::HashSet::new();
        for t in 0..4u32 {
            for r in 0..10_000u64 {
                seen.insert(hash_of(&(t, r)));
            }
        }
        assert_eq!(seen.len(), 40_000, "collisions on sequential keys");
    }

    #[test]
    fn map_and_set_roundtrip() {
        let mut m: FxHashMap<(u32, u64), f32> = FxHashMap::default();
        for r in 0..1000u64 {
            m.insert((0, r), r as f32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&(0, 512)], 512.0);
        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(7);
        assert!(s.contains(&7) && !s.contains(&8));
    }

    #[test]
    fn byte_stream_fallback_matches_padding_rules() {
        // write() must consume ragged tails without panicking and differ
        // from the empty hash.
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3]);
        assert_ne!(h.finish(), FxHasher::default().finish());
    }
}
