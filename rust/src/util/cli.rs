//! Tiny CLI argument helper (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value` and `--key=value`; typed accessors with
//! defaults; collects positional arguments. Unknown-flag detection is the
//! caller's job via `unused()`.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<std::collections::HashSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(rest.to_string(), v);
                } else {
                    flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self {
            flags,
            positional,
            consumed: Default::default(),
        }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.raw(key)
            .map(|v| v.parse().unwrap_or_else(|e| panic!("--{key}: {e}")))
            .unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.u64(key, default as u64) as usize
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.raw(key)
            .map(|v| v.parse().unwrap_or_else(|e| panic!("--{key}: {e}")))
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.f64(key, default as f64) as f32
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.raw(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma-separated list value (e.g. `--cluster host:1,host:2`);
    /// empty/absent -> empty vec.
    pub fn strs(&self, key: &str) -> Vec<String> {
        self.raw(key)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim())
                    .filter(|s| !s.is_empty())
                    .map(|s| s.to_string())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Flags present on the command line but never read by the program —
    /// almost always a typo; callers surface these as errors.
    pub fn unused(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_styles() {
        let a = args("run --workers 8 --staleness=3 --verbose");
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.usize("workers", 1), 8);
        assert_eq!(a.u64("staleness", 0), 3);
        assert!(a.bool("verbose", false));
        assert_eq!(a.usize("shards", 2), 2);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("--offset -3");
        assert_eq!(a.f64("offset", 0.0), -3.0);
    }

    #[test]
    fn unused_detection() {
        let a = args("--used 1 --typo 2");
        let _ = a.u64("used", 0);
        assert_eq!(a.unused(), vec!["typo".to_string()]);
    }

    #[test]
    fn comma_lists() {
        let a = args("--cluster host:1,host:2,host:3 --empty=");
        assert_eq!(a.strs("cluster"), vec!["host:1", "host:2", "host:3"]);
        assert!(a.strs("empty").is_empty());
        assert!(a.strs("missing").is_empty());
    }

    #[test]
    fn positional_collection() {
        let a = args("fig2-mf out.csv --seed 1");
        assert_eq!(a.positional(), &["fig2-mf", "out.csv"]);
    }
}
