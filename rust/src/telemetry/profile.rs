//! Sampled hot-key profiler: a space-saving top-K heavy-hitters sketch
//! per shard, counting per-key GET and update traffic in fixed memory.
//!
//! The sketch is the classic *space-saving* algorithm (Metwally et al.):
//! at most `k` tracked entries; a hit increments its entry, a miss on a
//! full sketch evicts the minimum-count entry and inherits its count as
//! the new entry's error bound. Guarantees: every key with true
//! frequency > N/k is present, estimates never undercount
//! (`count - err <= true <= count`), and memory is O(k) regardless of
//! the key universe — exactly the shape a placement controller needs to
//! find hot keys without a per-key map (ROADMAP item 1's sensor half).
//!
//! Concurrency follows the registry spirit — scrape-safe sharing with
//! hot-path cost bounded and allocation-free: the sketch lives behind a
//! mutex that only the owning shard thread and the (rare) scrape path
//! take, `observe` is O(1) on a hit and O(k) on a miss, and `k` is small
//! (default 32). Entries flatten into the standard snapshot convention
//! (`hot.g.<table>:<row>` / `hot.u.<table>:<row>`), so the counts travel
//! the existing `StatsReport` wire path, surface on both admin endpoints
//! and feed the `ps-top` hot-key panel with no new plumbing.
//!
//! Strictly out-of-band: observations never feed back into protocol
//! decisions, and runs are bit-identical with profiling on or off
//! (`tests/integration_spans.rs`).

use std::sync::Mutex;

use crate::ps::types::Key;
use crate::util::hash::FxHashMap;

/// One tracked heavy hitter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HotKey {
    pub key: Key,
    /// Estimated count (never an undercount of the true frequency).
    pub count: u64,
    /// Overestimation bound: `count - err <= true frequency <= count`.
    pub err: u64,
}

#[derive(Default)]
struct Inner {
    entries: Vec<HotKey>,
    /// Key -> index into `entries` (kept in sync on eviction).
    index: FxHashMap<Key, usize>,
}

/// Space-saving top-K sketch. `k == 0` disables (observe is a no-op).
pub struct HotKeySketch {
    k: usize,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for HotKeySketch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        write!(f, "HotKeySketch(k={}, tracked={})", self.k, g.entries.len())
    }
}

impl HotKeySketch {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Whether the sketch tracks anything at all.
    pub fn enabled(&self) -> bool {
        self.k > 0
    }

    /// Count one observation of `key`.
    pub fn observe(&self, key: Key) {
        self.observe_n(key, 1);
    }

    /// Count `n` observations of `key` at once (batch updates).
    pub fn observe_n(&self, key: Key, n: u64) {
        if self.k == 0 || n == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if let Some(&i) = g.index.get(&key) {
            g.entries[i].count += n;
            return;
        }
        if g.entries.len() < self.k {
            let i = g.entries.len();
            g.entries.push(HotKey { key, count: n, err: 0 });
            g.index.insert(key, i);
            return;
        }
        // Full: replace the minimum-count entry, inheriting its count as
        // the newcomer's error bound (the space-saving step).
        let (i, min) = g
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.count)
            .map(|(i, e)| (i, e.count))
            .expect("k > 0");
        let old = g.entries[i].key;
        g.index.remove(&old);
        g.entries[i] = HotKey {
            key,
            count: min + n,
            err: min,
        };
        g.index.insert(key, i);
    }

    /// Tracked heavy hitters, estimated count descending (key-ordered
    /// tiebreak, so output is deterministic).
    pub fn top(&self) -> Vec<HotKey> {
        let mut out = self.inner.lock().unwrap().entries.clone();
        out.sort_by(|a, b| b.count.cmp(&a.count).then(a.key.cmp(&b.key)));
        out
    }

    /// Flatten into snapshot entries as `<prefix><table>:<row>` counts
    /// (e.g. `hot.g.0:17`), estimated count descending.
    pub fn entries(&self, prefix: &str, out: &mut Vec<(String, u64)>) {
        for h in self.top() {
            out.push((format!("{prefix}{}:{}", h.key.0, h.key.1), h.count));
        }
    }
}

/// Parse a flattened sketch entry name back into its key: the inverse of
/// [`HotKeySketch::entries`], used by the `ps-top` hot-key panel.
pub fn parse_hot_entry(name: &str, prefix: &str) -> Option<Key> {
    let rest = name.strip_prefix(prefix)?;
    let (t, r) = rest.split_once(':')?;
    Some((t.parse().ok()?, r.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_below_capacity() {
        let s = HotKeySketch::new(8);
        for _ in 0..5 {
            s.observe((0, 1));
        }
        s.observe_n((0, 2), 3);
        let top = s.top();
        assert_eq!(top.len(), 2);
        assert_eq!(top[0], HotKey { key: (0, 1), count: 5, err: 0 });
        assert_eq!(top[1], HotKey { key: (0, 2), count: 3, err: 0 });
    }

    #[test]
    fn disabled_sketch_is_a_noop() {
        let s = HotKeySketch::new(0);
        s.observe((0, 1));
        assert!(s.top().is_empty());
        assert!(!s.enabled());
    }

    #[test]
    fn eviction_inherits_min_count_as_error() {
        let s = HotKeySketch::new(2);
        s.observe_n((0, 1), 10);
        s.observe_n((0, 2), 4);
        s.observe((0, 3)); // evicts (0,2): count 4+1, err 4
        let top = s.top();
        assert_eq!(top[0].key, (0, 1));
        assert_eq!(top[1], HotKey { key: (0, 3), count: 5, err: 4 });
    }

    #[test]
    fn zipfian_skew_survives_the_sketch() {
        // Frequencies ~ 1/rank over 200 keys, k = 16: every true
        // heavy hitter must surface, in order, with valid error bounds.
        let s = HotKeySketch::new(16);
        let n_keys = 200u64;
        for r in 0..n_keys {
            let freq = 2000 / (r + 1);
            for _ in 0..freq {
                s.observe((0, r));
            }
        }
        let top = s.top();
        assert_eq!(top.len(), 16);
        // The top-4 true hitters (2000, 1000, 666, 500) dominate any
        // possible overestimate of the tail; they must lead, in order.
        for (i, h) in top.iter().take(4).enumerate() {
            assert_eq!(h.key, (0, i as u64), "rank {i}: {top:?}");
            let true_freq = 2000 / (i as u64 + 1);
            assert!(h.count >= true_freq, "undercount at rank {i}");
            assert!(h.count - h.err <= true_freq, "bound broken at rank {i}");
        }
    }

    #[test]
    fn entries_flatten_and_parse_back() {
        let s = HotKeySketch::new(4);
        s.observe_n((3, 99), 7);
        let mut out = Vec::new();
        s.entries("hot.g.", &mut out);
        assert_eq!(out, vec![("hot.g.3:99".to_string(), 7)]);
        assert_eq!(parse_hot_entry("hot.g.3:99", "hot.g."), Some((3, 99)));
        assert_eq!(parse_hot_entry("hot.g.3:99", "hot.u."), None);
        assert_eq!(parse_hot_entry("hot.g.x:99", "hot.g."), None);
    }

    #[test]
    fn property_estimates_bracket_exact_counts() {
        // Deterministic pseudo-random stream; sketch estimates must
        // bracket exact counts for every tracked key, and every key with
        // frequency > N/k must be tracked (the space-saving guarantee).
        let mut x = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        let k = 24;
        let s = HotKeySketch::new(k);
        let mut exact: std::collections::HashMap<Key, u64> = std::collections::HashMap::new();
        let n = 20_000u64;
        for _ in 0..n {
            // Skewed: half the stream hits 8 keys, half spreads over 256.
            let r = next();
            let key = if r % 2 == 0 {
                (0u32, r % 8)
            } else {
                (0u32, 8 + r % 256)
            };
            s.observe(key);
            *exact.entry(key).or_default() += 1;
        }
        let top = s.top();
        for h in &top {
            let t = exact.get(&h.key).copied().unwrap_or(0);
            assert!(h.count >= t, "undercount for {:?}", h.key);
            assert!(h.count - h.err <= t, "lower bound broken for {:?}", h.key);
        }
        for (key, &t) in &exact {
            if t > n / k as u64 {
                assert!(
                    top.iter().any(|h| h.key == *key),
                    "heavy hitter {key:?} (freq {t}) missing from sketch"
                );
            }
        }
    }
}
