//! Structured event tracing: a bounded per-node ring buffer of rare,
//! high-signal lifecycle events — the flight recorder for postmortems.
//!
//! What gets recorded (the event catalog lives in `ps::server`
//! § Observability): placement epoch activations, migration fences,
//! replica promotions, WAL generation rolls, fault-plan firings
//! (pause/crash/kill), and transport peer lifecycle transitions. These
//! are *rare* events — a handful per run — so the ring takes a plain
//! mutex: it is never on the GET/update/apply hot path. Per-packet
//! fault verdicts (drop/delay/reorder) are deliberately counters, not
//! trace events, so a lossy link cannot flood the ring.
//!
//! Events carry a logical-clock timestamp (the shard's table clock or
//! the client's work clock; -1 when no clock applies, e.g. transport
//! events) rather than wall time, so traces from a deterministic run
//! are themselves deterministic and diffable across runs.
//!
//! The ring is bounded: when full, the oldest event is evicted and a
//! drop counter increments, so a chatty debug trace can never exhaust
//! memory. `dump_jsonl` writes one JSON object per line, oldest first.

use std::collections::VecDeque;
use std::io::{self, Write};
use std::path::Path;
use std::sync::Mutex;

use crate::util::json::{num, obj, str as jstr};

/// One recorded event. `seq` is a per-ring monotone sequence number
/// assigned at record time (survives eviction, so gaps in a dump reveal
/// how much history was lost).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    /// Node label, e.g. `"shard0"`, `"worker2"`, `"tcp"`.
    pub node: String,
    /// Logical clock at record time; -1 when no logical clock applies.
    pub clock: i64,
    /// Event kind, e.g. `"promotion"`, `"migrate_commit"`, `"peer_up"`.
    pub kind: String,
    /// Free-form human-readable detail.
    pub detail: String,
}

struct RingInner {
    next_seq: u64,
    dropped: u64,
    buf: VecDeque<TraceEvent>,
}

/// Bounded event ring. Shared via `Arc` by every component of one node
/// (in multi-process runs, one ring per OS process; in-process clusters
/// share one ring with the `node` field telling events apart).
pub struct TraceRing {
    cap: usize,
    debug: bool,
    inner: Mutex<RingInner>,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        write!(
            f,
            "TraceRing(cap={}, len={}, dropped={})",
            self.cap,
            g.buf.len(),
            g.dropped
        )
    }
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        Self::with_debug(cap, false)
    }

    /// `debug = true` additionally admits high-volume diagnostics
    /// (e.g. per-event TCP writer backpressure) via [`record_debug`].
    ///
    /// [`record_debug`]: TraceRing::record_debug
    pub fn with_debug(cap: usize, debug: bool) -> Self {
        Self {
            cap: cap.max(1),
            debug,
            inner: Mutex::new(RingInner {
                next_seq: 0,
                dropped: 0,
                buf: VecDeque::new(),
            }),
        }
    }

    pub fn debug_enabled(&self) -> bool {
        self.debug
    }

    pub fn record(&self, node: &str, clock: i64, kind: &str, detail: String) {
        let mut g = self.inner.lock().unwrap();
        let seq = g.next_seq;
        g.next_seq += 1;
        if g.buf.len() == self.cap {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(TraceEvent {
            seq,
            node: node.to_string(),
            clock,
            kind: kind.to_string(),
            detail,
        });
    }

    /// Debug-level event: recorded only when the ring was built with
    /// `debug = true`; otherwise a no-op (and callers should avoid even
    /// formatting `detail` by checking [`debug_enabled`] first).
    ///
    /// [`debug_enabled`]: TraceRing::debug_enabled
    pub fn record_debug(&self, node: &str, clock: i64, kind: &str, detail: String) {
        if self.debug {
            self.record(node, clock, kind, detail);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted due to the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Copy of the retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.inner.lock().unwrap().buf.iter().cloned().collect()
    }

    /// Write the retained events as JSONL (one object per line, oldest
    /// first) to `w`.
    pub fn write_jsonl(&self, w: &mut impl Write) -> io::Result<()> {
        for ev in self.events() {
            let line = obj(vec![
                ("seq", num(ev.seq as f64)),
                ("node", jstr(ev.node)),
                ("clock", num(ev.clock as f64)),
                ("kind", jstr(ev.kind)),
                ("detail", jstr(ev.detail)),
            ]);
            writeln!(w, "{}", line.to_string_pretty(0))?;
        }
        Ok(())
    }

    /// Dump to a file path (created or truncated).
    pub fn dump_jsonl(&self, path: &Path) -> io::Result<()> {
        let mut f = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_jsonl(&mut f)?;
        f.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn ring_bounds_and_sequences() {
        let r = TraceRing::new(3);
        for i in 0..5 {
            r.record("shard0", i, "ev", format!("e{i}"));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let evs = r.events();
        assert_eq!(evs[0].seq, 2); // oldest two evicted
        assert_eq!(evs[2].seq, 4);
        assert_eq!(evs[2].clock, 4);
    }

    #[test]
    fn debug_events_gated() {
        let quiet = TraceRing::new(8);
        quiet.record_debug("tcp", -1, "backpressure", "w0->s1".into());
        assert!(quiet.is_empty());
        let loud = TraceRing::with_debug(8, true);
        loud.record_debug("tcp", -1, "backpressure", "w0->s1".into());
        assert_eq!(loud.len(), 1);
    }

    #[test]
    fn jsonl_lines_parse() {
        let r = TraceRing::new(8);
        r.record("shard1", 7, "promotion", "replica 0 -> primary".into());
        r.record("worker0", 9, "placement", "epoch 2".into());
        let mut out = Vec::new();
        r.write_jsonl(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "promotion");
        assert_eq!(j.get("clock").unwrap().as_u64().unwrap(), 7);
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.get("node").unwrap().as_str().unwrap(), "worker0");
    }
}
