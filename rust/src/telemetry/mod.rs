//! Live telemetry plane: per-node metrics registries, wire-shipped
//! snapshots, admin scrape sockets, structured event tracing, causal
//! request spans and the hot-key profiler.
//!
//! Five pieces, each usable alone:
//!
//!   * [`registry`] — the measurement primitives: relaxed atomic
//!     [`Counter`]s/[`Gauge`]s and log2-bucket [`LogHist`]ograms with
//!     p50/p99/p999 readout, flattened into uniform `(name, value)`
//!     [`Snapshot`]s that merge associatively across nodes. Hot-path
//!     cost is one relaxed RMW per event; snapshots happen on the
//!     scrape path only.
//!   * [`admin`] — the `--metrics-addr` TCP socket serving a JSON
//!     snapshot (`GET /json`) and a Prometheus-style text exposition
//!     (`GET /metrics`, real `_bucket{le=...}`/`_sum`/`_count`
//!     histogram families with `# TYPE` headers), plus the client-side
//!     [`scrape`] used by the `ps-top` subcommand.
//!   * [`trace`] — the bounded per-node [`TraceRing`] flight recorder
//!     for rare lifecycle events (placement epochs, migration fences,
//!     promotions, WAL rolls, fault firings, peer transitions), dumped
//!     as JSONL via `--trace-out`.
//!   * [`spans`] — causal request tracing (wire v9): a deterministic
//!     1-in-N sampler piggybacks a 12-byte [`SpanCtx`] on
//!     `Get`/`Update`/`Row`/`Push` frames, every hop appends timed
//!     segments (client issue, transport enqueue/flush, shard queue
//!     wait, policy admission, apply/serve, reply decode, cache
//!     install) to a [`SpanRing`], and the result exports as Chrome
//!     trace-event JSON (`--trace-spans`) plus a live p50/p99
//!     per-segment breakdown.
//!   * [`profile`] — the space-saving top-K [`HotKeySketch`]: per-key
//!     GET/update heavy hitters per shard in fixed memory, flattened as
//!     `hot.g.<t>:<r>` / `hot.u.<t>:<r>` entries — the sensor half of
//!     ROADMAP item 1's placement controller.
//!
//! Registries live inside `ShardCore` / `PsClient` / the transports and
//! snapshots additionally travel the data plane as
//! `ToShard::StatsPull` / `ToWorker::StatsReport` (wire v6), so a
//! worker — or `run-cluster` across real processes — can aggregate live
//! cluster-wide state. Telemetry is strictly out-of-band: it never
//! feeds back into protocol decisions, and the deterministic replay
//! suites are bit-identical with it enabled (proven by
//! `tests/integration_telemetry.rs` and, for spans + profiling,
//! `tests/integration_spans.rs`).
//!
//! [`Counter`]: registry::Counter
//! [`Gauge`]: registry::Gauge
//! [`LogHist`]: registry::LogHist
//! [`Snapshot`]: registry::Snapshot
//! [`scrape`]: admin::scrape
//! [`TraceRing`]: trace::TraceRing
//! [`SpanCtx`]: spans::SpanCtx
//! [`SpanRing`]: spans::SpanRing
//! [`HotKeySketch`]: profile::HotKeySketch

pub mod admin;
pub mod profile;
pub mod registry;
pub mod spans;
pub mod trace;
