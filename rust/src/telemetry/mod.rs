//! Live telemetry plane: per-node metrics registries, wire-shipped
//! snapshots, admin scrape sockets, and structured event tracing.
//!
//! Three pieces, each usable alone:
//!
//!   * [`registry`] — the measurement primitives: relaxed atomic
//!     [`Counter`]s/[`Gauge`]s and log2-bucket [`LogHist`]ograms with
//!     p50/p99/p999 readout, flattened into uniform `(name, value)`
//!     [`Snapshot`]s that merge associatively across nodes. Hot-path
//!     cost is one relaxed RMW per event; snapshots happen on the
//!     scrape path only.
//!   * [`admin`] — the `--metrics-addr` TCP socket serving a JSON
//!     snapshot (`GET /json`) and a Prometheus-style text exposition
//!     (`GET /metrics`), plus the client-side [`scrape`] used by the
//!     `ps-top` subcommand.
//!   * [`trace`] — the bounded per-node [`TraceRing`] flight recorder
//!     for rare lifecycle events (placement epochs, migration fences,
//!     promotions, WAL rolls, fault firings, peer transitions), dumped
//!     as JSONL via `--trace-out`.
//!
//! Registries live inside `ShardCore` / `PsClient` / the transports and
//! snapshots additionally travel the data plane as
//! `ToShard::StatsPull` / `ToWorker::StatsReport` (wire v6), so a
//! worker — or `run-cluster` across real processes — can aggregate live
//! cluster-wide state. Telemetry is strictly out-of-band: it never
//! feeds back into protocol decisions, and the deterministic replay
//! suites are bit-identical with it enabled (proven by
//! `tests/integration_telemetry.rs`).
//!
//! [`Counter`]: registry::Counter
//! [`Gauge`]: registry::Gauge
//! [`LogHist`]: registry::LogHist
//! [`Snapshot`]: registry::Snapshot
//! [`scrape`]: admin::scrape
//! [`TraceRing`]: trace::TraceRing

pub mod admin;
pub mod registry;
pub mod trace;
