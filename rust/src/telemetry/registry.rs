//! The metrics registry primitives: relaxed atomic counters/gauges and
//! fixed-bucket log2 latency histograms.
//!
//! Design constraints (see `ps::server` § Observability):
//!
//!   * **No locks or allocation on hot paths.** Every update is one (or
//!     two) relaxed atomic RMW ops on a fixed-layout struct. Registries
//!     are *structs with named fields*, not name-keyed maps — the names
//!     only materialize at snapshot time, on the scrape path.
//!   * **Scrape-safe sharing.** A registry lives behind an `Arc`; the
//!     admin socket thread reads the same atomics the hot path writes.
//!     Relaxed ordering is sufficient: a scrape is a statistical sample,
//!     not a synchronization point, and monotonicity per counter is
//!     guaranteed by the RMW itself.
//!   * **Uniform snapshot form.** Every registry flattens to
//!     `Vec<(String, u64)>` entries — the exact payload of the
//!     `ToWorker::StatsReport` wire message — with histograms encoded as
//!     `name#b<i>` / `name#count` / `name#sum` entries so per-worker
//!     snapshots merge into cluster aggregates by bucket addition
//!     (associative, order-free).
//!
//! The histogram buckets by `bit_width(value)` — bucket `i` holds values
//! in `[2^(i-1), 2^i - 1]` (bucket 0 holds exactly 0) — so a recorded
//! quantile *brackets* the true quantile within a factor of 2, which is
//! the right fidelity for p50/p99/p999 latency at nanosecond resolution.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{arr, num, obj, str as jstr, Json};

/// Number of log2 buckets: one per possible `u64::bit_width` (0..=64).
pub const HIST_BUCKETS: usize = 65;

/// A monotone counter. Relaxed increments; safe to read from any thread.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A non-negative level gauge with a high-water mark. `set` records the
/// current level and folds it into the high-water mark in one pass.
#[derive(Debug, Default)]
pub struct Gauge {
    cur: AtomicU64,
    hwm: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.cur.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cur.load(Ordering::Relaxed)
    }

    pub fn hwm(&self) -> u64 {
        self.hwm.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log2 histogram over `u64` samples (latencies in ns, wave
/// fan-out counts, ...). Recording is two relaxed RMWs plus a bucket RMW;
/// no locks, no allocation, no floating point.
pub struct LogHist {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl std::fmt::Debug for LogHist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "LogHist(count={}, sum={})", s.count, s.sum)
    }
}

impl Default for LogHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHist {
    pub fn new() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Bucket index for a sample: its bit width (0 for 0, 64 for MSB-set).
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive value range covered by bucket `i`.
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        match i {
            0 => (0, 0),
            64 => (1u64 << 63, u64::MAX),
            _ => (1u64 << (i - 1), (1u64 << i) - 1),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// A consistent-enough copy for scraping (buckets are read one by
    /// one; a concurrent record may straddle the read, which is fine for
    /// a statistical sample).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            buckets[i] = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// A plain (non-atomic) histogram copy: what travels in snapshots, merges
/// across workers, and answers quantile queries.
#[derive(Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

impl std::fmt::Debug for HistSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "HistSnapshot(count={}, sum={}, p50<={}, p99<={})",
            self.count,
            self.sum,
            self.quantile(0.50),
            self.quantile(0.99)
        )
    }
}

impl HistSnapshot {
    /// Bucket-wise merge. Addition per bucket, so merging is commutative
    /// and associative: per-worker snapshots fold into a global aggregate
    /// in any order with the same result.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v;
        self.buckets[LogHist::bucket_of(v)] += 1;
    }

    /// The inclusive value range of the bucket holding the q-quantile
    /// (rank `ceil(q * count)`, so q=0.5 of 2 samples is the 1st). The
    /// true quantile of the recorded stream lies within these bounds.
    pub fn quantile_bounds(&self, q: f64) -> (u64, u64) {
        if self.count == 0 {
            return (0, 0);
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return LogHist::bucket_bounds(i);
            }
        }
        LogHist::bucket_bounds(HIST_BUCKETS - 1)
    }

    /// Conservative (upper-bound) quantile estimate.
    pub fn quantile(&self, q: f64) -> u64 {
        self.quantile_bounds(q).1
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Flatten into snapshot entries under `name`: `name#count`,
    /// `name#sum`, and one `name#b<i>` per non-empty bucket. `#` cannot
    /// occur in a plain metric name, so the grouping is unambiguous.
    pub fn entries(&self, name: &str, out: &mut Vec<(String, u64)>) {
        out.push((format!("{name}#count"), self.count));
        out.push((format!("{name}#sum"), self.sum));
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                out.push((format!("{name}#b{i}"), c));
            }
        }
    }
}

/// One node's flattened metrics: the unit the admin socket renders and
/// the `StatsReport` wire message carries.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Node label, e.g. `"shard0"`, `"worker2"`.
    pub node: String,
    /// Flat `(name, value)` pairs; histogram entries use the `#` suffix
    /// convention of [`HistSnapshot::entries`].
    pub entries: Vec<(String, u64)>,
}

impl Snapshot {
    /// Value of a plain (non-histogram) entry.
    pub fn get(&self, name: &str) -> Option<u64> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// Reassemble the histogram flattened under `name` (empty histogram
    /// if no entries carry the prefix).
    pub fn hist(&self, name: &str) -> HistSnapshot {
        let mut h = HistSnapshot::default();
        for (n, v) in &self.entries {
            let Some(suffix) = n.strip_prefix(name).and_then(|r| r.strip_prefix('#')) else {
                continue;
            };
            match suffix {
                "count" => h.count = *v,
                "sum" => h.sum = *v,
                s => {
                    if let Some(i) = s.strip_prefix('b').and_then(|d| d.parse::<usize>().ok()) {
                        if i < HIST_BUCKETS {
                            h.buckets[i] = *v;
                        }
                    }
                }
            }
        }
        h
    }

    /// Names (prefixes) of the histograms present in this snapshot, in
    /// first-appearance order.
    pub fn hist_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for (n, _) in &self.entries {
            if let Some((prefix, _)) = n.split_once('#') {
                if !names.iter().any(|x| x == prefix) {
                    names.push(prefix.to_string());
                }
            }
        }
        names
    }
}

/// Anything that can be scraped: a registry (or a group of them) that
/// yields per-node snapshots on demand. Implemented by the shard/client
/// registries, the transport stats, and the worker-side mirror of pulled
/// shard reports.
pub trait MetricsSource: Send + Sync {
    fn snapshots(&self) -> Vec<Snapshot>;
}

/// Merge snapshots that share a node label: plain entries from the same
/// node are summed (they are disjoint in practice), histogram entries add
/// bucket-wise — which is exactly histogram merge.
pub fn merge_snapshots(snaps: Vec<Snapshot>) -> Vec<Snapshot> {
    let mut out: Vec<Snapshot> = Vec::new();
    for s in snaps {
        match out.iter_mut().find(|o| o.node == s.node) {
            None => out.push(s),
            Some(o) => {
                for (n, v) in s.entries {
                    match o.entries.iter_mut().find(|(en, _)| *en == n) {
                        Some((_, ev)) => *ev += v,
                        None => o.entries.push((n, v)),
                    }
                }
            }
        }
    }
    out
}

// ------------------------------------------------------------- rendering

/// JSON scrape document: `{"nodes": [{"node": ..., "metrics": {...},
/// "hists": {name: {count, sum, mean, p50, p99, p999}}}]}`. Quantiles are
/// the conservative upper bounds of [`HistSnapshot::quantile`].
pub fn to_json(snaps: &[Snapshot]) -> Json {
    let nodes: Vec<Json> = snaps
        .iter()
        .map(|s| {
            let mut metrics: Vec<(String, Json)> = Vec::new();
            for (n, v) in &s.entries {
                if !n.contains('#') {
                    metrics.push((n.clone(), num(*v as f64)));
                }
            }
            let mut hists: Vec<(String, Json)> = Vec::new();
            for name in s.hist_names() {
                let h = s.hist(&name);
                hists.push((
                    name.clone(),
                    obj(vec![
                        ("count", num(h.count as f64)),
                        ("sum", num(h.sum as f64)),
                        ("mean", num(h.mean())),
                        ("p50", num(h.quantile(0.50) as f64)),
                        ("p99", num(h.quantile(0.99) as f64)),
                        ("p999", num(h.quantile(0.999) as f64)),
                    ]),
                ));
            }
            obj(vec![
                ("node", jstr(s.node.clone())),
                (
                    "metrics",
                    Json::Obj(metrics.into_iter().collect()),
                ),
                ("hists", Json::Obj(hists.into_iter().collect())),
            ])
        })
        .collect();
    obj(vec![("nodes", arr(nodes))])
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Prometheus text exposition. Samples are grouped into metric
/// *families* first — the exposition format wants every sample of one
/// family contiguous under a single `# TYPE` header, across all nodes —
/// then rendered as `esspt_<name>{node="..."} <value>` gauges and real
/// histogram families (cumulative `_bucket{le="..."}` lines plus
/// `_sum` / `_count` per node). The JSON scrape document is unaffected.
pub fn to_prometheus(snaps: &[Snapshot]) -> String {
    use std::fmt::Write as _;
    // First-appearance order keeps the rendered family sequence stable
    // across scrapes of an unchanged node set.
    let mut plain: Vec<(String, Vec<(String, u64)>)> = Vec::new();
    let mut hists: Vec<(String, Vec<(String, HistSnapshot)>)> = Vec::new();
    for s in snaps {
        for (n, v) in &s.entries {
            if n.contains('#') {
                continue;
            }
            let fam = sanitize(n);
            match plain.iter_mut().find(|(f, _)| *f == fam) {
                Some((_, rows)) => rows.push((s.node.clone(), *v)),
                None => plain.push((fam, vec![(s.node.clone(), *v)])),
            }
        }
        for name in s.hist_names() {
            let fam = sanitize(&name);
            let h = s.hist(&name);
            match hists.iter_mut().find(|(f, _)| *f == fam) {
                Some((_, rows)) => rows.push((s.node.clone(), h)),
                None => hists.push((fam, vec![(s.node.clone(), h)])),
            }
        }
    }
    let mut out = String::new();
    for (fam, rows) in &plain {
        let _ = writeln!(out, "# HELP esspt_{fam} essptable metric {fam}");
        let _ = writeln!(out, "# TYPE esspt_{fam} gauge");
        for (node, v) in rows {
            let _ = writeln!(out, "esspt_{fam}{{node=\"{node}\"}} {v}");
        }
    }
    for (fam, rows) in &hists {
        let _ = writeln!(out, "# HELP esspt_{fam} essptable log2-bucket histogram {fam}");
        let _ = writeln!(out, "# TYPE esspt_{fam} histogram");
        for (node, h) in rows {
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                let (_, hi) = LogHist::bucket_bounds(i);
                let _ = writeln!(
                    out,
                    "esspt_{fam}_bucket{{node=\"{node}\",le=\"{hi}\"}} {cum}"
                );
            }
            let _ = writeln!(
                out,
                "esspt_{fam}_bucket{{node=\"{node}\",le=\"+Inf\"}} {}",
                h.count
            );
            let _ = writeln!(out, "esspt_{fam}_sum{{node=\"{node}\"}} {}", h.sum);
            let _ = writeln!(out, "esspt_{fam}_count{{node=\"{node}\"}} {}", h.count);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
        assert_eq!(g.hwm(), 7);
    }

    #[test]
    fn hist_buckets_cover_the_u64_range() {
        assert_eq!(LogHist::bucket_of(0), 0);
        assert_eq!(LogHist::bucket_of(1), 1);
        assert_eq!(LogHist::bucket_of(2), 2);
        assert_eq!(LogHist::bucket_of(3), 2);
        assert_eq!(LogHist::bucket_of(u64::MAX), 64);
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = LogHist::bucket_bounds(i);
            assert!(lo <= hi);
            assert_eq!(LogHist::bucket_of(lo), i, "lo of bucket {i}");
            assert_eq!(LogHist::bucket_of(hi), i, "hi of bucket {i}");
        }
    }

    #[test]
    fn hist_quantiles_bracket_known_values() {
        let h = LogHist::new();
        for v in [1u64, 2, 3, 100, 1000, 100_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        let (lo, hi) = s.quantile_bounds(0.5);
        // True p50 (rank 3 of 6) is 3.
        assert!(lo <= 3 && 3 <= hi, "p50 bounds [{lo}, {hi}]");
        let (lo, hi) = s.quantile_bounds(1.0);
        assert!(lo <= 100_000 && 100_000 <= hi, "max bounds [{lo}, {hi}]");
        assert_eq!(s.quantile_bounds(0.0).0, 0); // rank clamps to 1 -> value 1's bucket
    }

    #[test]
    fn hist_entry_flattening_roundtrips() {
        let h = LogHist::new();
        for v in [0u64, 5, 5, 900] {
            h.record(v);
        }
        let snap = h.snapshot();
        let mut entries = Vec::new();
        snap.entries("lat_ns", &mut entries);
        let s = Snapshot {
            node: "n".into(),
            entries,
        };
        assert_eq!(s.hist("lat_ns"), snap);
        assert_eq!(s.hist_names(), vec!["lat_ns".to_string()]);
        // A different prefix reassembles empty.
        assert_eq!(s.hist("other").count, 0);
    }

    #[test]
    fn snapshot_merge_sums_entries() {
        let a = Snapshot {
            node: "w0".into(),
            entries: vec![("gets".into(), 3), ("lat#count".into(), 1)],
        };
        let b = Snapshot {
            node: "w0".into(),
            entries: vec![("gets".into(), 2), ("pulls".into(), 9)],
        };
        let c = Snapshot {
            node: "w1".into(),
            entries: vec![("gets".into(), 1)],
        };
        let merged = merge_snapshots(vec![a, b, c]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].get("gets"), Some(5));
        assert_eq!(merged[0].get("pulls"), Some(9));
        assert_eq!(merged[1].get("gets"), Some(1));
    }

    #[test]
    fn renders_json_and_prometheus() {
        let h = LogHist::new();
        h.record(10);
        h.record(1000);
        let mut entries = vec![("gets_served".into(), 42u64)];
        h.snapshot().entries("read_ns", &mut entries);
        let snaps = vec![Snapshot {
            node: "shard0".into(),
            entries,
        }];
        let j = to_json(&snaps);
        let nodes = j.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes[0].get("node").unwrap().as_str().unwrap(), "shard0");
        assert_eq!(
            nodes[0]
                .get("metrics")
                .unwrap()
                .get("gets_served")
                .unwrap()
                .as_u64()
                .unwrap(),
            42
        );
        assert_eq!(
            nodes[0]
                .get("hists")
                .unwrap()
                .get("read_ns")
                .unwrap()
                .get("count")
                .unwrap()
                .as_u64()
                .unwrap(),
            2
        );
        let text = to_prometheus(&snaps);
        assert!(text.contains("esspt_gets_served{node=\"shard0\"} 42"), "{text}");
        assert!(text.contains("esspt_read_ns_count{node=\"shard0\"} 2"), "{text}");
        assert!(text.contains("le=\"+Inf\""), "{text}");
        // Both parse: JSON through the parser, text line-by-line.
        assert!(Json::parse(&j.to_string_pretty(0)).is_ok());
        for line in text.lines() {
            assert!(line.contains(' '), "malformed line {line:?}");
        }
    }

    #[test]
    fn prometheus_groups_families_across_nodes() {
        // Two nodes sharing metric names: every sample of one family
        // must sit contiguously under a single # TYPE header.
        let h = LogHist::new();
        h.record(10);
        let mk = |node: &str, v: u64| {
            let mut entries = vec![("gets_served".into(), v)];
            h.snapshot().entries("read_ns", &mut entries);
            Snapshot {
                node: node.into(),
                entries,
            }
        };
        let text = to_prometheus(&[mk("shard0", 42), mk("shard1", 7)]);
        assert_eq!(text.matches("# TYPE esspt_gets_served gauge").count(), 1, "{text}");
        assert_eq!(text.matches("# TYPE esspt_read_ns histogram").count(), 1, "{text}");
        // Both node samples of the gauge family are contiguous: nothing
        // but samples of that family between header and last sample.
        let lines: Vec<&str> = text.lines().collect();
        let hdr = lines
            .iter()
            .position(|l| *l == "# TYPE esspt_gets_served gauge")
            .unwrap();
        assert_eq!(lines[hdr + 1], "esspt_gets_served{node=\"shard0\"} 42");
        assert_eq!(lines[hdr + 2], "esspt_gets_served{node=\"shard1\"} 7");
        // Histogram families carry per-node _bucket/_sum/_count series.
        assert!(text.contains("esspt_read_ns_sum{node=\"shard1\"}"), "{text}");
        assert!(text.contains("esspt_read_ns_bucket{node=\"shard1\",le=\"+Inf\"} 1"), "{text}");
        // Headers precede every sample of their family.
        let first_sample = lines
            .iter()
            .position(|l| l.starts_with("esspt_read_ns_bucket"))
            .unwrap();
        let type_line = lines
            .iter()
            .position(|l| *l == "# TYPE esspt_read_ns histogram")
            .unwrap();
        assert!(type_line < first_sample, "{text}");
    }
}
