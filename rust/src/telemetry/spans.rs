//! Causal request spans: sampled, wire-propagated timing of individual
//! PS requests across every hop they touch.
//!
//! A [`SpanCtx`] is 12 bytes — `trace_id: u64 | parent: u32` — carried
//! as an optional trailing extension on `ToShard::Get` / `ToShard::Update`
//! and `ToWorker::Row` / `ToWorker::Push` frames (wire v9). Sampling is
//! client-side and **deterministic**: each endpoint runs a plain modular
//! counter ([`SpanSampler`]), so the same ops of the same run are sampled
//! every time — replayable runs stay replayable, and an unsampled frame
//! is byte-identical to its wire-v8 encoding (zero overhead when off).
//!
//! Every hop that handles a sampled request appends a timed *segment* to
//! its process-local [`SpanRing`]:
//!
//! | segment             | recorded by | meaning                                |
//! |---------------------|-------------|----------------------------------------|
//! | `client_issue`      | client      | building + sending the request         |
//! | `transport_enqueue` | transport   | handing the frame to the send path     |
//! | `transport_flush`   | transport   | frame left the sender (sim: delivered) |
//! | `shard_queue`       | shard       | inbox wait: arrival -> handler start   |
//! | `policy_admission`  | shard       | read admission wait (0 if immediate)   |
//! | `serve`             | shard       | building + sending the Row reply       |
//! | `apply`             | shard       | staging/applying an Update batch       |
//! | `reply_decode`      | client      | reply arrival -> client apply          |
//! | `cache_install`     | client      | installing the payload in the cache    |
//!
//! Segments are (a) accumulated into per-segment log2 histograms — the
//! p50/p99 breakdown shown in `RunReport`, `ps-top` and the admin
//! endpoints ([`SpanRing`] is a [`MetricsSource`]) — and (b) kept in a
//! bounded ring of raw events exportable as Chrome trace-event JSON
//! (`--trace-spans FILE`, loadable in `chrome://tracing` / Perfetto).
//! Timestamps are wall-clock microseconds since the Unix epoch, so the
//! per-process exports of a `run-cluster` merge on one timeline and the
//! client/shard segments of one request share one `trace_id` across
//! process boundaries.
//!
//! Like the rest of the telemetry plane the spans are strictly
//! out-of-band: nothing here feeds back into protocol decisions, and
//! final model state is bit-identical with sampling on or off (proven by
//! `tests/integration_spans.rs` over both transports).

use std::collections::HashMap;
use std::io;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use super::registry::{HistSnapshot, MetricsSource, Snapshot};
use crate::util::json::{arr, num, obj, str as jstr, Json};

/// The wire-propagated span context (12 bytes on the wire; see
/// `transport::wire` v9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanCtx {
    /// Globally unique per sampled request: the originator's node id in
    /// the high bits (shard-originated waves set the top bit), its local
    /// sample sequence number in the low bits. Deterministic — no
    /// randomness, so replayed runs produce identical ids.
    pub trace_id: u64,
    /// The originating endpoint's id (worker id, or shard id with the
    /// top bit set), so a hop can label the origin without decoding
    /// `trace_id`.
    pub parent: u32,
}

/// Encoded size of a span context on the wire.
pub const SPAN_WIRE_BYTES: usize = 12;

/// Marks `parent` / `trace_id` as shard-originated (eager push waves).
pub const SPAN_SHARD_ORIGIN: u32 = 1 << 31;

impl SpanCtx {
    /// Span for the `seq`-th sampled request of worker `worker`.
    pub fn for_worker(worker: u32, seq: u64) -> Self {
        Self {
            trace_id: ((worker as u64) << 40) | (seq & ((1 << 40) - 1)),
            parent: worker,
        }
    }

    /// Span for the `seq`-th sampled push wave of shard `shard`.
    pub fn for_shard(shard: u32, seq: u64) -> Self {
        Self {
            trace_id: (1 << 63) | ((shard as u64) << 40) | (seq & ((1 << 40) - 1)),
            parent: shard | SPAN_SHARD_ORIGIN,
        }
    }
}

/// Deterministic 1-in-N sampler: a plain counter, no clocks, no rng —
/// the same op sequence samples the same ops on every run.
#[derive(Debug)]
pub struct SpanSampler {
    /// Sample every `every`-th op (0 = never).
    every: u64,
    /// Ops seen so far.
    n: u64,
}

impl SpanSampler {
    pub fn new(every: u64) -> Self {
        Self { every, n: 0 }
    }

    /// Whether sampling is enabled at all.
    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    /// Count one op; `Some(sample_index)` when this op is sampled.
    pub fn tick(&mut self) -> Option<u64> {
        if self.every == 0 {
            return None;
        }
        let n = self.n;
        self.n += 1;
        (n % self.every == 0).then_some(n / self.every)
    }
}

/// One recorded segment of a sampled request.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub trace_id: u64,
    pub parent: u32,
    /// Node label, e.g. `"worker0"`, `"shard2"`.
    pub node: String,
    /// Segment name (one of the table in the module docs).
    pub seg: &'static str,
    /// Microseconds since the Unix epoch.
    pub start_us: u64,
    pub dur_us: u64,
}

/// Cross-thread arrival marks: the transport stamps a sampled frame's
/// arrival, the handler turns the stamp into a queue-wait segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mark {
    /// Frame handed to the transport send path (consumed by the same
    /// process's flush/delivery hook to time the in-transport segment).
    Enqueue,
    /// Frame delivered into a shard inbox.
    ArriveShard,
    /// Frame delivered into a worker inbox.
    ArriveWorker,
}

/// Marks held at most this long before being garbage-collected (a mark
/// whose consumer died — e.g. a reply to a finished worker — must not
/// leak).
const MARK_CAP: usize = 4096;

#[derive(Default)]
struct Inner {
    ring: Vec<SpanEvent>,
    /// Next overwrite position once the ring is full.
    head: usize,
    /// Per-segment duration histograms (µs), for the p50/p99 breakdown.
    segs: Vec<(&'static str, HistSnapshot)>,
    marks: HashMap<(u64, Mark), u64>,
}

/// Process-local bounded recorder of sampled request segments. Shared
/// `Arc`-style between clients, shards, the transports and the admin
/// scrape thread; recording locks a mutex, which only sampled (1-in-N)
/// requests ever touch — the unsampled hot path never takes it.
pub struct SpanRing {
    cap: usize,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for SpanRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.inner.lock().unwrap();
        write!(f, "SpanRing(events={}, cap={})", g.ring.len(), self.cap)
    }
}

impl SpanRing {
    /// `cap` bounds the raw-event ring (oldest events overwritten); the
    /// per-segment histograms aggregate everything regardless.
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Wall-clock microseconds since the Unix epoch — the shared
    /// timeline that lets per-process exports merge.
    pub fn now_us() -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_micros() as u64
    }

    /// Append one timed segment.
    pub fn record(&self, span: SpanCtx, node: &str, seg: &'static str, start_us: u64, dur_us: u64) {
        let ev = SpanEvent {
            trace_id: span.trace_id,
            parent: span.parent,
            node: node.to_string(),
            seg,
            start_us,
            dur_us,
        };
        let mut g = self.inner.lock().unwrap();
        match g.segs.iter_mut().find(|(n, _)| *n == seg) {
            Some((_, h)) => h.record(dur_us),
            None => {
                let mut h = HistSnapshot::default();
                h.record(dur_us);
                g.segs.push((seg, h));
            }
        }
        if g.ring.len() < self.cap {
            g.ring.push(ev);
        } else {
            let head = g.head;
            g.ring[head] = ev;
            g.head = (head + 1) % self.cap;
        }
    }

    /// Stamp a frame arrival (transport side).
    pub fn mark(&self, trace_id: u64, tag: Mark, ts_us: u64) {
        let mut g = self.inner.lock().unwrap();
        if g.marks.len() >= MARK_CAP {
            g.marks.clear();
        }
        g.marks.insert((trace_id, tag), ts_us);
    }

    /// Consume a frame-arrival stamp (handler side).
    pub fn take_mark(&self, trace_id: u64, tag: Mark) -> Option<u64> {
        self.inner.lock().unwrap().marks.remove(&(trace_id, tag))
    }

    /// Raw events, oldest first.
    pub fn events(&self) -> Vec<SpanEvent> {
        let g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(g.ring.len());
        if g.ring.len() == self.cap {
            out.extend_from_slice(&g.ring[g.head..]);
            out.extend_from_slice(&g.ring[..g.head]);
        } else {
            out.extend_from_slice(&g.ring);
        }
        out
    }

    /// Per-segment duration histograms (µs), first-appearance order.
    pub fn segment_hists(&self) -> Vec<(String, HistSnapshot)> {
        self.inner
            .lock()
            .unwrap()
            .segs
            .iter()
            .map(|(n, h)| (n.to_string(), h.clone()))
            .collect()
    }

    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` flavor).
    /// Each segment becomes one complete (`"ph": "X"`) event under
    /// process `pid`; node labels map to synthetic thread ids with
    /// `thread_name` metadata, so `chrome://tracing` / Perfetto shows
    /// one lane per node. The `trace` arg carries the shared trace id —
    /// the cross-process causal link.
    pub fn chrome_events(&self, pid: u64) -> Vec<Json> {
        let events = self.events();
        let mut tids: Vec<String> = Vec::new();
        let mut out = Vec::new();
        for ev in &events {
            let tid = match tids.iter().position(|n| *n == ev.node) {
                Some(i) => i,
                None => {
                    tids.push(ev.node.clone());
                    out.push(obj(vec![
                        ("name", jstr("thread_name".to_string())),
                        ("ph", jstr("M".to_string())),
                        ("pid", num(pid as f64)),
                        ("tid", num((tids.len() - 1) as f64)),
                        (
                            "args",
                            obj(vec![("name", jstr(ev.node.clone()))]),
                        ),
                    ]));
                    tids.len() - 1
                }
            };
            out.push(obj(vec![
                ("name", jstr(ev.seg.to_string())),
                ("ph", jstr("X".to_string())),
                ("pid", num(pid as f64)),
                ("tid", num(tid as f64)),
                ("ts", num(ev.start_us as f64)),
                ("dur", num(ev.dur_us.max(1) as f64)),
                (
                    "args",
                    obj(vec![
                        ("trace", jstr(format!("{:#x}", ev.trace_id))),
                        ("parent", num(ev.parent as f64)),
                    ]),
                ),
            ]));
        }
        out
    }

    /// Write the Chrome trace JSON document for this ring to `path`.
    pub fn dump_chrome(&self, path: &str, pid: u64) -> io::Result<()> {
        let doc = obj(vec![("traceEvents", arr(self.chrome_events(pid)))]);
        std::fs::write(path, doc.to_string_pretty(0))
    }
}

impl MetricsSource for SpanRing {
    /// Expose the per-segment histograms as a scrapeable node, so the
    /// admin endpoints and `ps-top` show the breakdown live
    /// (`span.<segment>_us` histogram families).
    fn snapshots(&self) -> Vec<Snapshot> {
        let mut entries = Vec::new();
        for (name, h) in self.segment_hists() {
            h.entries(&format!("span.{name}_us"), &mut entries);
        }
        vec![Snapshot {
            node: "spans".into(),
            entries,
        }]
    }
}

/// Merge per-process Chrome trace documents (as written by
/// [`SpanRing::dump_chrome`]) into one, reassigning each input a
/// distinct pid and naming it via `process_name` metadata — the
/// `run-cluster` post-run step that makes client and shard segments of
/// one trace land in one loadable file.
pub fn merge_chrome_docs(parts: &[(String, Json)]) -> Json {
    let mut events = Vec::new();
    for (pid, (label, doc)) in parts.iter().enumerate() {
        events.push(obj(vec![
            ("name", jstr("process_name".to_string())),
            ("ph", jstr("M".to_string())),
            ("pid", num(pid as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", jstr(label.clone()))])),
        ]));
        let Some(evs) = doc.get("traceEvents").ok().and_then(|e| e.as_arr().ok()) else {
            continue;
        };
        for ev in evs {
            // Re-pid the event; everything else passes through.
            let mut fields: Vec<(String, Json)> = Vec::new();
            for key in ["name", "ph", "tid", "ts", "dur", "args"] {
                if let Ok(v) = ev.get(key) {
                    fields.push((key.to_string(), v.clone()));
                }
            }
            fields.push(("pid".to_string(), num(pid as f64)));
            events.push(Json::Obj(fields.into_iter().collect()));
        }
    }
    obj(vec![("traceEvents", arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_is_deterministic_and_1_in_n() {
        let mut s = SpanSampler::new(4);
        let picks: Vec<Option<u64>> = (0..9).map(|_| s.tick()).collect();
        assert_eq!(
            picks,
            vec![
                Some(0),
                None,
                None,
                None,
                Some(1),
                None,
                None,
                None,
                Some(2)
            ]
        );
        let mut off = SpanSampler::new(0);
        assert!(!off.enabled());
        assert_eq!(off.tick(), None);
    }

    #[test]
    fn trace_ids_are_distinct_across_origins() {
        let w = SpanCtx::for_worker(3, 7);
        let s = SpanCtx::for_shard(3, 7);
        assert_ne!(w.trace_id, s.trace_id);
        assert_eq!(w.parent, 3);
        assert_eq!(s.parent, 3 | SPAN_SHARD_ORIGIN);
    }

    #[test]
    fn ring_overwrites_oldest_and_keeps_hists() {
        let r = SpanRing::new(3);
        for i in 0..5u64 {
            r.record(SpanCtx::for_worker(0, i), "worker0", "serve", i * 10, i + 1);
        }
        let evs = r.events();
        assert_eq!(evs.len(), 3);
        // Oldest two (seq 0, 1) were overwritten.
        assert_eq!(evs[0].start_us, 20);
        assert_eq!(evs[2].start_us, 40);
        let hists = r.segment_hists();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "serve");
        assert_eq!(hists[0].1.count, 5); // histograms see everything
    }

    #[test]
    fn marks_roundtrip_once() {
        let r = SpanRing::new(8);
        r.mark(42, Mark::ArriveShard, 1000);
        assert_eq!(r.take_mark(42, Mark::ArriveShard), Some(1000));
        assert_eq!(r.take_mark(42, Mark::ArriveShard), None);
        assert_eq!(r.take_mark(42, Mark::ArriveWorker), None);
    }

    #[test]
    fn chrome_export_parses_and_carries_trace_ids() {
        let r = SpanRing::new(8);
        r.record(SpanCtx::for_worker(1, 0), "worker1", "client_issue", 100, 5);
        r.record(SpanCtx::for_worker(1, 0), "shard0", "serve", 120, 7);
        let doc = obj(vec![("traceEvents", arr(r.chrome_events(0)))]);
        let parsed = Json::parse(&doc.to_string_pretty(0)).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata + 2 segments.
        assert_eq!(evs.len(), 4);
        let xs: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .collect();
        assert_eq!(xs.len(), 2);
        let t0 = xs[0].get("args").unwrap().get("trace").unwrap();
        let t1 = xs[1].get("args").unwrap().get("trace").unwrap();
        assert_eq!(t0.as_str().unwrap(), t1.as_str().unwrap());
    }

    #[test]
    fn merged_docs_get_distinct_pids() {
        let r1 = SpanRing::new(4);
        r1.record(SpanCtx::for_worker(0, 0), "worker0", "client_issue", 1, 1);
        let r2 = SpanRing::new(4);
        r2.record(SpanCtx::for_worker(0, 0), "shard0", "serve", 2, 1);
        let d1 = obj(vec![("traceEvents", arr(r1.chrome_events(0)))]);
        let d2 = obj(vec![("traceEvents", arr(r2.chrome_events(0)))]);
        let merged = merge_chrome_docs(&[("worker0".into(), d1), ("shard0".into(), d2)]);
        let evs = merged.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: std::collections::HashSet<u64> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .map(|e| e.get("pid").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(pids.len(), 2);
    }

    #[test]
    fn metrics_source_exposes_segment_hists() {
        let r = SpanRing::new(4);
        r.record(SpanCtx::for_worker(0, 0), "worker0", "serve", 0, 9);
        let snaps = r.snapshots();
        assert_eq!(snaps[0].node, "spans");
        assert_eq!(snaps[0].hist("span.serve_us").count, 1);
    }
}
