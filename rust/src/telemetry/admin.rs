//! Per-node admin scrape socket.
//!
//! A tiny dependency-free TCP server bound to `--metrics-addr` that
//! answers two read-only endpoints and closes the connection:
//!
//!   * `GET /json`    → JSON snapshot document ([`registry::to_json`])
//!   * `GET /metrics` → Prometheus-style text exposition
//!                      ([`registry::to_prometheus`])
//!
//! Requests are a single HTTP/1.0-shaped line (anything `curl` or
//! `ps-top` sends); any path other than `/metrics` serves JSON, so a
//! bare `nc` works too. Responses carry minimal HTTP headers so both
//! browsers and scripts parse them.
//!
//! The server owns a list of [`MetricsSource`]s and snapshots them per
//! request — scraping reads the same relaxed atomics the hot paths
//! write, so a scrape never blocks or perturbs the data plane. Sources
//! sharing a node label are merged ([`registry::merge_snapshots`]),
//! letting e.g. a shard registry and the transport stats render as one
//! node.
//!
//! [`registry::to_json`]: crate::telemetry::registry::to_json
//! [`registry::to_prometheus`]: crate::telemetry::registry::to_prometheus
//! [`registry::merge_snapshots`]: crate::telemetry::registry::merge_snapshots

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{merge_snapshots, to_json, to_prometheus, MetricsSource, Snapshot};

/// Running admin server. Dropping the handle leaves the thread serving
/// until process exit; call [`shutdown`] for an orderly stop (tests).
///
/// [`shutdown`]: AdminHandle::shutdown
pub struct AdminHandle {
    /// The bound address (useful with port 0).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl AdminHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for AdminHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Do not join in drop: the accept loop notices within one poll
        // interval and exits on its own.
    }
}

/// Bind `addr` (e.g. `127.0.0.1:0`) and serve the sources until
/// shutdown. Returns once the listener is bound, so a caller printing
/// `handle.addr` is immediately scrapeable.
pub fn serve(addr: &str, sources: Vec<Arc<dyn MetricsSource>>) -> io::Result<AdminHandle> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let join = std::thread::Builder::new()
        .name("telemetry-admin".into())
        .spawn(move || accept_loop(listener, sources, stop2))?;
    Ok(AdminHandle {
        addr: bound,
        stop,
        join: Some(join),
    })
}

fn accept_loop(
    listener: TcpListener,
    sources: Vec<Arc<dyn MetricsSource>>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = handle_conn(stream, &sources);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn gather(sources: &[Arc<dyn MetricsSource>]) -> Vec<Snapshot> {
    let mut snaps = Vec::new();
    for s in sources {
        snaps.extend(s.snapshots());
    }
    merge_snapshots(snaps)
}

fn handle_conn(mut stream: TcpStream, sources: &[Arc<dyn MetricsSource>]) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    // Read the request line; tolerate clients that send nothing more.
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf).unwrap_or(0);
    let req = String::from_utf8_lossy(&buf[..n]);
    let first = req.lines().next().unwrap_or("");
    let snaps = gather(sources);
    let (body, ctype) = if first.contains("/metrics") {
        (to_prometheus(&snaps), "text/plain; version=0.0.4")
    } else {
        (
            to_json(&snaps).to_string_pretty(2),
            "application/json",
        )
    };
    let resp = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

/// One admin-socket scrape as a client: connect, request `path`
/// (`"/json"` or `"/metrics"`), return the response body with HTTP
/// headers stripped. Used by `ps-top` and the telemetry tests.
///
/// `timeout` bounds the connect AND each socket read/write, so a hung
/// or half-dead endpoint costs a poller at most ~2x `timeout` rather
/// than blocking it forever; every error names the endpoint and the
/// stage that failed (`ps-top` polls many addrs — a bare "timed out"
/// would leave the operator guessing which one).
pub fn scrape(addr: &str, path: &str, timeout: Duration) -> io::Result<String> {
    let stage = |what: &str| {
        let addr = addr.to_string();
        let what = what.to_string();
        move |e: io::Error| io::Error::new(e.kind(), format!("scrape {addr}{what}: {e}"))
    };
    let sock: SocketAddr = addr
        .parse()
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, format!("{addr}: {e}")))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)
        .map_err(stage(": connect"))?;
    stream.set_read_timeout(Some(timeout)).map_err(stage(": set read timeout"))?;
    stream.set_write_timeout(Some(timeout)).map_err(stage(": set write timeout"))?;
    stream
        .write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
        .map_err(stage(&format!("{path}: send request")))?;
    let mut out = String::new();
    stream
        .read_to_string(&mut out)
        .map_err(stage(&format!("{path}: read response")))?;
    match out.find("\r\n\r\n") {
        Some(i) => Ok(out[i + 4..].to_string()),
        None => Ok(out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::Counter;
    use crate::util::json::Json;

    struct FakeSource {
        c: Counter,
    }

    impl MetricsSource for FakeSource {
        fn snapshots(&self) -> Vec<Snapshot> {
            vec![Snapshot {
                node: "shard0".into(),
                entries: vec![("gets_served".into(), self.c.get())],
            }]
        }
    }

    #[test]
    fn serves_json_and_text() {
        let src = Arc::new(FakeSource { c: Counter::new() });
        src.c.add(11);
        let h = serve("127.0.0.1:0", vec![src.clone()]).unwrap();
        let addr = h.addr.to_string();
        let json = scrape(&addr, "/json", Duration::from_secs(5)).unwrap();
        let j = Json::parse(&json).unwrap();
        let nodes = j.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(
            nodes[0]
                .get("metrics")
                .unwrap()
                .get("gets_served")
                .unwrap()
                .as_u64()
                .unwrap(),
            11
        );
        src.c.add(1);
        let text = scrape(&addr, "/metrics", Duration::from_secs(5)).unwrap();
        assert!(
            text.contains("esspt_gets_served{node=\"shard0\"} 12"),
            "{text}"
        );
        h.shutdown();
    }

    #[test]
    fn scrape_errors_name_the_endpoint() {
        // A dead endpoint (bind-then-drop guarantees nothing listens):
        // the error must say which addr failed, not just "refused".
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let e = scrape(&addr, "/json", Duration::from_millis(500)).unwrap_err();
        assert!(e.to_string().contains(&addr), "{e}");
        assert!(e.to_string().contains("connect"), "{e}");
    }
}
