//! Transport layer: how PS messages travel between workers and shards.
//!
//! The paper's ESSPTable runs one server process per physical machine over
//! 1 Gbps Ethernet. This module makes that boundary explicit: everything
//! above it (client, shard, consistency models) addresses peers as
//! [`NodeId`]s and hands [`Packet`]s to a [`Transport`]; everything below
//! it is swappable:
//!
//!   * [`sim::net::SimNet`](crate::sim::net::SimNet) — the in-process
//!     router thread with modeled latency/bandwidth/FIFO links (the
//!     simulated substitution for the paper's testbed), and
//!   * [`tcp::TcpTransport`] — real TCP sockets speaking the [`wire`]
//!     binary codec, so a cluster can run as separate OS processes over
//!     loopback or a LAN (the paper's actual deployment shape).
//!
//! Both deliver into per-node `mpsc` inboxes, and both charge bytes via
//! the *same* codec ([`Packet::wire_bytes`] is the exact encoded frame
//! size), so the simulated serialization-time model and the real framing
//! agree byte-for-byte.

pub mod tcp;
pub mod wire;

pub use self::tcp::PeerEvent;

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context as _, Result};

use crate::ps::msg::{ToShard, ToWorker};
use crate::sim::fault::FaultInjector;
use crate::sim::net::{NetConfig, SimNet};
use self::tcp::{LocalSink, TcpTransport};

/// A network endpoint: worker `w`, shard `s`, or the cluster coordinator
/// (the launcher; source of migration and failover control messages, and
/// the destination of the heartbeat `StatsReport` replies its failure
/// detector polls for — see `ps::failover`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeId {
    Worker(usize),
    Shard(usize),
    Coordinator,
}

/// Payload variants carried by any transport.
#[derive(Debug, Clone, PartialEq)]
pub enum Packet {
    ToShard(ToShard),
    ToWorker(ToWorker),
}

impl Packet {
    /// Exact encoded frame size in bytes — the single source of truth
    /// (in [`wire`]) shared by the SimNet bandwidth model and TCP framing.
    pub fn wire_bytes(&self) -> usize {
        wire::packet_frame_len(self)
    }

    /// The sampled span context riding this packet, if any (wire v9):
    /// what the transports hook to time enqueue/flush segments without
    /// knowing message semantics.
    pub fn span(&self) -> Option<crate::telemetry::spans::SpanCtx> {
        match self {
            Packet::ToShard(m) => m.span(),
            Packet::ToWorker(m) => m.span(),
        }
    }
}

/// A one-way message fabric: carries a packet from `src` toward `dst`'s
/// inbox. Reliability and per-(src, dst) FIFO ordering are part of the
/// contract — the PS protocol depends on Update-before-ClockTick order
/// within each (worker, shard) link.
pub trait Transport: Send + Sync {
    fn send(&self, src: NodeId, dst: NodeId, packet: Packet);
}

/// Cloneable shared handle to a transport backend; what clients and
/// shards hold (they never see the concrete backend).
#[derive(Clone)]
pub struct TransportHandle(Arc<dyn Transport>);

impl TransportHandle {
    pub fn new<T: Transport + 'static>(t: T) -> Self {
        Self(Arc::new(t))
    }

    pub fn from_arc(t: Arc<dyn Transport>) -> Self {
        Self(t)
    }

    #[inline]
    pub fn send(&self, src: NodeId, dst: NodeId, packet: Packet) {
        self.0.send(src, dst, packet)
    }
}

/// Which data plane a cluster run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportSel {
    /// In-process router thread with modeled latency/bandwidth (`sim::net`).
    #[default]
    Sim,
    /// Real loopback TCP sockets through [`tcp::TcpTransport`]: the same
    /// worker/shard threads, but every message is wire-encoded and crosses
    /// the OS network stack. `NetConfig` delay modeling does not apply —
    /// the sockets *are* the network.
    Tcp,
}

impl TransportSel {
    pub fn parse(s: &str) -> std::result::Result<Self, String> {
        match s {
            "sim" => Ok(Self::Sim),
            "tcp" => Ok(Self::Tcp),
            other => Err(format!("unknown transport {other:?} (expected sim|tcp)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Tcp => "tcp",
        }
    }
}

/// The assembled data plane of one in-process cluster run: either the
/// simulated network, or a pair of real TCP endpoints talking over
/// loopback (server side hosting every shard inbox, client side hosting
/// every worker inbox).
pub enum Fabric {
    Sim(SimNet),
    Tcp {
        client: TcpTransport,
        server: TcpTransport,
    },
}

impl Fabric {
    /// Build the selected data plane around the given per-node inboxes.
    pub fn build(
        sel: TransportSel,
        net: NetConfig,
        worker_tx: Vec<Sender<ToWorker>>,
        shard_tx: Vec<Sender<ToShard>>,
    ) -> Result<Fabric> {
        Self::build_with_faults(sel, net, worker_tx, shard_tx, None)
    }

    /// [`Fabric::build`] with a link-fault injector threaded into the
    /// backend: the SimNet router or the TCP per-connection writers
    /// evaluate it against every packet (see `sim::fault`).
    pub fn build_with_faults(
        sel: TransportSel,
        net: NetConfig,
        worker_tx: Vec<Sender<ToWorker>>,
        shard_tx: Vec<Sender<ToShard>>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Fabric> {
        Self::build_with_control(sel, net, worker_tx, shard_tx, faults, None, None)
    }

    /// [`Fabric::build_with_faults`] with the failover control plane
    /// attached: `coordinator` receives packets addressed to
    /// [`NodeId::Coordinator`] (heartbeat `StatsReport` replies), and
    /// `events` receives [`PeerEvent`]s — a node whose inbox hung up
    /// (killed shard thread) surfaces as an unclean `Disconnected` on
    /// both backends, feeding the coordinator's failure detector.
    #[allow(clippy::too_many_arguments)]
    pub fn build_with_control(
        sel: TransportSel,
        net: NetConfig,
        worker_tx: Vec<Sender<ToWorker>>,
        shard_tx: Vec<Sender<ToShard>>,
        faults: Option<Arc<FaultInjector>>,
        coordinator: Option<Sender<ToWorker>>,
        events: Option<Sender<tcp::PeerEvent>>,
    ) -> Result<Fabric> {
        match sel {
            TransportSel::Sim => Ok(Fabric::Sim(SimNet::with_control(
                net,
                worker_tx,
                shard_tx,
                faults,
                coordinator,
                events,
            ))),
            TransportSel::Tcp => {
                if !net.is_instant() {
                    eprintln!(
                        "note: modeled net delays are ignored over the tcp transport \
                         (real sockets are the network)"
                    );
                }
                let n_shards = shard_tx.len();
                let mut server_locals: Vec<(NodeId, LocalSink)> = shard_tx
                    .into_iter()
                    .enumerate()
                    .map(|(s, tx)| (NodeId::Shard(s), LocalSink::Shard(tx)))
                    .collect();
                // The in-process TCP fabric hosts every shard on one
                // endpoint; the coordinator inbox rides the same endpoint
                // so shard->coordinator heartbeat replies deliver locally.
                if let Some(tx) = coordinator {
                    server_locals.push((NodeId::Coordinator, LocalSink::Worker(tx)));
                }
                let workers = worker_tx.len();
                let (server, addr) = TcpTransport::server_with_faults(
                    "127.0.0.1:0",
                    server_locals,
                    events,
                    workers,
                    faults.clone(),
                )
                .context("binding loopback shard endpoint")?;
                let client_locals: Vec<(NodeId, LocalSink)> = worker_tx
                    .into_iter()
                    .enumerate()
                    .map(|(w, tx)| (NodeId::Worker(w), LocalSink::Worker(tx)))
                    .collect();
                let conns: Vec<(usize, usize, std::net::SocketAddr)> = (0..workers)
                    .flat_map(|w| (0..n_shards).map(move |s| (w, s, addr)))
                    .collect();
                let client = TcpTransport::client_with_faults(
                    client_locals,
                    &conns,
                    Duration::from_secs(10),
                    faults,
                )
                .context("dialing loopback shard endpoint")?;
                Ok(Fabric::Tcp { client, server })
            }
        }
    }

    /// Install the span recorder (wire v9) on whichever backend is
    /// live: sampled frames then get transport enqueue/flush segments
    /// and inbox-arrival marks. One-shot per backend.
    pub fn set_spans(&self, ring: Arc<crate::telemetry::spans::SpanRing>) {
        match self {
            Fabric::Sim(net) => net.set_spans(ring),
            Fabric::Tcp { client, server } => {
                client.set_spans(ring.clone());
                server.set_spans(ring);
            }
        }
    }

    /// Handle workers send through.
    pub fn worker_handle(&self) -> TransportHandle {
        match self {
            Fabric::Sim(net) => TransportHandle::new(net.handle()),
            Fabric::Tcp { client, .. } => client.handle(),
        }
    }

    /// Handle shards send through.
    pub fn shard_handle(&self) -> TransportHandle {
        match self {
            Fabric::Sim(net) => TransportHandle::new(net.handle()),
            Fabric::Tcp { server, .. } => server.handle(),
        }
    }

    pub fn messages(&self) -> u64 {
        match self {
            Fabric::Sim(net) => net.messages(),
            Fabric::Tcp { client, server } => {
                client.stats().messages() + server.stats().messages()
            }
        }
    }

    pub fn bytes(&self) -> u64 {
        match self {
            Fabric::Sim(net) => net.bytes(),
            Fabric::Tcp { client, server } => client.stats().bytes() + server.stats().bytes(),
        }
    }

    /// Block until every message sent so far has settled (delivered to its
    /// destination inbox, or — TCP error paths only — counted dropped).
    pub fn flush(&self) {
        match self {
            Fabric::Sim(net) => net.flush(),
            Fabric::Tcp { client, server } => {
                // Frames already written into a link that subsequently
                // dies settle nowhere, so unlike SimNet this wait must be
                // bounded: after the deadline, report and move on rather
                // than hanging the run on a broken connection.
                let deadline = std::time::Instant::now() + Duration::from_secs(30);
                loop {
                    // Settled counters are read BEFORE the sent counters:
                    // settled <= sent always holds, so settled(t1) >=
                    // sent(t2) with t1 < t2 proves true quiescence (see
                    // SimNet::flush).
                    let settled = client.stats().settled() + server.stats().settled();
                    let sent = client.stats().messages() + server.stats().messages();
                    if settled >= sent {
                        return;
                    }
                    if std::time::Instant::now() > deadline {
                        eprintln!(
                            "transport: flush timed out with {} of {sent} messages \
                             unsettled (a connection died mid-run?); continuing",
                            sent - settled
                        );
                        return;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }

    /// Tear the data plane down (joins all transport threads).
    pub fn shutdown(self) {
        match self {
            Fabric::Sim(net) => net.shutdown(),
            Fabric::Tcp { client, server } => {
                // Stop outbound traffic on both ends first: each side's
                // readers only exit once the *remote* write half closes.
                client.close_send();
                server.close_send();
                client.join();
                server.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_sel_parses() {
        assert_eq!(TransportSel::parse("sim").unwrap(), TransportSel::Sim);
        assert_eq!(TransportSel::parse("tcp").unwrap(), TransportSel::Tcp);
        assert!(TransportSel::parse("rdma").is_err());
        assert_eq!(TransportSel::default().label(), "sim");
    }
}
