//! Versioned, length-prefixed little-endian binary codec for PS messages.
//!
//! This is the single source of truth for message sizes: `ToShard::
//! wire_bytes` / `ToWorker::wire_bytes` (which feed the SimNet
//! serialization-time model) delegate to [`to_shard_frame_len`] /
//! [`to_worker_frame_len`], so the simulated byte counts and the real TCP
//! framing agree exactly.
//!
//! ## Frame layout (all integers little-endian)
//!
//! ```text
//! frame := len:u32 | src:node | dst:node | kind:u8 | body
//! node  := kind:u8 (0 = worker, 1 = shard, 2 = coordinator) | id:u32
//! ```
//!
//! `len` counts every byte after the length prefix. Message kinds 0–13
//! are the `ToShard` variants (Get, Update, ClockTick, Register, PushAck,
//! VapAck, Shutdown, NormReport, Detach, MigrateBegin, RowHandoff,
//! MigrateCommit, Promote, StatsPull), 16–21 the `ToWorker` variants
//! (Row, Push, VapPush, Bound, Placement, StatsReport).
//! Row payloads are raw `f32` little-endian; on little-endian targets the
//! encoder writes them straight from the shared `Arc<[f32]>` storage —
//! encoding a push wave stages no intermediate payload copy.
//!
//! Update rows are representation-polymorphic (wire v3): each row carries
//! the `RowDelta` the client coalesced, never densified in transit:
//!
//! ```text
//! row    := key | delta
//! key    := table:u32 | row:u64
//! delta  := repr:u8 | body
//! dense  (repr 0): len:u32 | f32 * len
//! sparse (repr 1): len:u32 | nnz:u32 | (idx:u32 | val:f32) * nnz
//! ```
//!
//! Sparse indices must ascend strictly and land inside `len`, and `nnz`
//! is bounded by both `len` and the bytes actually present — all checked
//! before any allocation. Per-row sizes come from
//! `ps::types::row_wire_bytes`, which this codec's Update body length
//! delegates to: one function is the source of truth for the client's
//! pending-bytes estimate, the SimNet serialization-time model, and the
//! TCP frames on the socket, so the three can never drift apart.
//!
//! ## Delta push waves (wire v7)
//!
//! Eager wave rows (`Push` / `VapPush`) are *hybrid*: each row ships
//! either a full snapshot or the ordered deltas applied since the wave
//! the reader last certified:
//!
//! ```text
//! pushrow  := key | fresh:i64 | payload:u8 | body
//! snapshot (payload 0): len:u32 | f32 * len
//! deltas   (payload 1): base:i64 | m:u32 | delta * m
//! ```
//!
//! `base` names the reader's expected starting point — the vclock of the
//! previous clock wave (ESSP) or the per-key seq of the previous eager
//! wave (VAP). The deltas are the exact sequence the shard folded into
//! its own row, in order, never a coalesced sum: f32 addition is
//! non-associative, so only replaying the identical sequence keeps the
//! client's cached copy bit-for-bit equal to the shard's row. A client
//! whose cached copy is not exactly at `base` (evicted, freshly pulled,
//! sourced from a different shard after a migration — the PR-5
//! source-shard tag is part of the check) discards the row and re-pulls;
//! the shard, which clears its seeded-reader bit whenever it serves that
//! reader a pull, answers the next wave for that key with a snapshot.
//! Snapshots are also sent on first push after registration and after
//! migration/promotion/crash-recovery (the shard's delta log is
//! conservative: when in doubt, re-seed). A lying `base` therefore never
//! corrupts state — at worst it forces a snapshot round-trip.
//!
//! `RowHandoff` row payloads use the same hybrid idea spatially: the
//! migrated row snapshot is encoded as a keyless `delta` (sparse iff
//! that is smaller), decoded back to a dense row by *placing* pairs into
//! a zero fill, which preserves every bit pattern.
//!
//! ## Request spans (wire v9)
//!
//! The four data-plane kinds (Get, Update, Row, Push) may carry an
//! optional trailing span context:
//!
//! ```text
//! span := trace_id:u64 | parent:u32        (12 bytes, at body end)
//! ```
//!
//! Presence is inferred from the body length: each of the four bodies is
//! otherwise fully self-describing (Get/Row are fixed-size, Update/Push
//! count their rows), so exactly 12 leftover bytes after the base decode
//! are the span and 0 leftover bytes mean unsampled. An unsampled frame
//! is therefore byte-identical to its wire-v8 encoding — tracing is
//! provably free when off and costs 12 bytes per *sampled* message when
//! on (see `telemetry::spans` for the sampling discipline).
//!
//! Connections start with a fixed-size handshake:
//!
//! ```text
//! hello  := magic "ESSPWIR1" (8) | version:u16 | src:node | dst:node
//! reject := magic "ESSPREJ1" (8) | peer_version:u16 | min:u16 | max:u16
//! ```
//!
//! A version mismatch is negotiated *loudly*: the acceptor answers a
//! well-magic'd hello of an unsupported version with the `reject` blob —
//! echoing the dialer's version and naming its own supported range — and
//! closes; the dialer decodes the blob into an error carrying both peer
//! versions plus this binary's range, so a mixed-version cluster fails
//! with a diagnosis instead of a silent drop (the ROADMAP's negotiation
//! stopgap until multi-version support exists).
//!
//! Decoding is defensive: every length field is bounds-checked against the
//! bytes actually present *before* any allocation, so a truncated or
//! corrupt frame yields a context-rich error, never a multi-GB
//! preallocation or a panic.

use std::io::{self, Read, Write};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::{NodeId, Packet};
use crate::ps::msg::{PushPayload, PushRow, ToShard, ToWorker};
use crate::ps::placement::PlacementDelta;
use crate::ps::types::{
    delta_wire_bytes, hybrid_snapshot_wire_bytes, row_wire_bytes, Clock, Key, RowDelta, WorkerId,
};
use crate::telemetry::spans::{SpanCtx, SPAN_WIRE_BYTES};

/// Handshake magic: protocol name + wire revision byte.
pub const MAGIC: [u8; 8] = *b"ESSPWIR1";
/// Protocol version carried in the handshake; bumped on layout changes
/// (v2: NormReport/Detach/Bound — the distributed value-bound protocol;
/// v3: hybrid dense/sparse Update rows; v4: the elastic shard plane —
/// MigrateBegin/RowHandoff/MigrateCommit/Placement and the coordinator
/// node kind; v5: crash tolerance — the Promote control message and the
/// placement delta's replica-promotion field; v6: the telemetry plane —
/// the out-of-band StatsPull/StatsReport snapshot pair; v7: delta push
/// waves — hybrid snapshot/delta payloads on Push/VapPush rows and the
/// sparse-capable RowHandoff row encoding; v8: self-healing failover —
/// the ReplicaSync/ReplicaCatchUp re-replication pair and the placement
/// delta's attach/dead fields; v9: causal request spans — an optional
/// trailing 12-byte span context, `trace_id:u64 | parent:u32`, on
/// Get/Update/Row/Push bodies, present iff the message was sampled, so
/// unsampled frames stay byte-identical to v8).
pub const VERSION: u16 = 9;
/// Versions this binary can speak (currently exactly [`VERSION`]; kept a
/// range so the reject blob's negotiation surface survives a future
/// multi-version binary).
pub const VERSION_MIN: u16 = VERSION;
pub const VERSION_MAX: u16 = VERSION;
/// Upper bound on one frame's encoded size (a push wave of ~16M f32s);
/// anything larger is rejected as corrupt before allocation.
pub const MAX_FRAME: usize = 1 << 28;

/// Encoded size of a `node` field.
const NODE_LEN: usize = 5;
/// Bytes before the body in every frame: length prefix + src + dst + kind.
pub const FRAME_OVERHEAD: usize = 4 + 2 * NODE_LEN + 1;
/// Total handshake size.
pub const HELLO_LEN: usize = 8 + 2 + 2 * NODE_LEN;

const K_GET: u8 = 0;
const K_UPDATE: u8 = 1;
const K_TICK: u8 = 2;
const K_REGISTER: u8 = 3;
const K_PUSH_ACK: u8 = 4;
const K_VAP_ACK: u8 = 5;
const K_SHUTDOWN: u8 = 6;
const K_NORM_REPORT: u8 = 7;
const K_DETACH: u8 = 8;
const K_MIGRATE_BEGIN: u8 = 9;
const K_ROW_HANDOFF: u8 = 10;
const K_MIGRATE_COMMIT: u8 = 11;
const K_PROMOTE: u8 = 12;
const K_STATS_PULL: u8 = 13;
const K_REPLICA_SYNC: u8 = 14;
const K_REPLICA_CATCH_UP: u8 = 15;
const K_ROW: u8 = 16;
const K_PUSH: u8 = 17;
const K_VAP_PUSH: u8 = 18;
const K_BOUND: u8 = 19;
const K_PLACEMENT: u8 = 20;
const K_STATS_REPORT: u8 = 21;

/// Longest metric name a `StatsReport` entry may carry: generous for the
/// fixed registries (names are `shard.wal_fsync_ns#b33`-shaped) while
/// keeping a corrupt length field from masquerading as a name.
const MAX_STAT_NAME: usize = 256;

/// Update-row representation tags (see module docs).
const REPR_DENSE: u8 = 0;
const REPR_SPARSE: u8 = 1;

/// Push-row payload tags (wire v7, see module docs).
const PAYLOAD_SNAPSHOT: u8 = 0;
const PAYLOAD_DELTAS: u8 = 1;

// ------------------------------------------------------------------ sizes

/// Bytes the optional trailing span context adds to a body (wire v9).
#[inline]
fn span_len(span: &Option<SpanCtx>) -> usize {
    span.map_or(0, |_| SPAN_WIRE_BYTES)
}

/// Exact body size of a `ToShard` message.
pub fn to_shard_body_len(m: &ToShard) -> usize {
    match m {
        ToShard::Get { span, .. } => 24 + span_len(span),
        ToShard::Update { rows, span, .. } => {
            // Per-row accounting delegates to `row_wire_bytes`: the one
            // source of truth shared with the client's pending estimate.
            16 + rows.iter().map(|(_, d)| row_wire_bytes(d)).sum::<usize>() + span_len(span)
        }
        ToShard::ClockTick { .. } => 12,
        ToShard::Register { .. } => 16,
        ToShard::PushAck { .. } => 12,
        ToShard::VapAck { .. } => 12,
        ToShard::NormReport { .. } => 16,
        ToShard::Detach { .. } => 4,
        ToShard::MigrateBegin {
            outgoing, incoming, ..
        } => 24 + 16 * outgoing.len() + 12 * incoming.len(),
        ToShard::RowHandoff { data, staged, .. } => {
            // Header 41 = epoch 8 + key 12 + vclock 8 + fresh 8 + exists 1
            // + staged count 4; the row snapshot travels as a keyless
            // hybrid delta (sparse iff smaller — wire v7). Per staged
            // entry: clock (8) + worker (4) + repr-tagged delta body —
            // numerically `row_wire_bytes` (its key header is also 12
            // bytes), reused so the two accountings cannot drift.
            41 + hybrid_snapshot_wire_bytes(data)
                + staged.iter().map(|(_, _, d)| row_wire_bytes(d)).sum::<usize>()
        }
        ToShard::MigrateCommit { .. } => 8,
        ToShard::Promote { delta } => placement_delta_body_len(delta),
        ToShard::ReplicaSync { .. } => 20,
        ToShard::ReplicaCatchUp { .. } => 21,
        ToShard::StatsPull { .. } => 4,
        ToShard::Shutdown => 0,
    }
}

/// Encoded size of a `PlacementDelta` body (shared by the `ToWorker::
/// Placement` broadcast and the `ToShard::Promote` control message):
/// epoch 8 + at_clock 8 + grow flag/value 5 + promote flag/pair 9 +
/// attach flag/pair 9 + dead count 4 + move count 4, then 4 bytes per
/// dead id and 16 per move.
fn placement_delta_body_len(delta: &PlacementDelta) -> usize {
    47 + 4 * delta.dead.len() + 16 * delta.moves.len()
}

/// Exact body size of a `ToWorker` message.
pub fn to_worker_body_len(m: &ToWorker) -> usize {
    match m {
        ToWorker::Row { data, span, .. } => 32 + 4 * data.len() + span_len(span),
        ToWorker::Push { rows, span, .. } => {
            16 + rows.iter().map(push_row_wire_bytes).sum::<usize>() + span_len(span)
        }
        ToWorker::VapPush { rows, .. } => {
            16 + rows.iter().map(push_row_wire_bytes).sum::<usize>()
        }
        ToWorker::Bound { .. } => 5,
        ToWorker::Placement { delta } => placement_delta_body_len(delta),
        ToWorker::StatsReport { entries, .. } => {
            // shard 4 + count 4, then per entry: name-len u16 + bytes +
            // value u64.
            8 + entries.iter().map(|(n, _)| 10 + n.len()).sum::<usize>()
        }
    }
}

/// Exact encoded size of one hybrid push-wave row (wire v7): key 12 +
/// fresh 8 + payload tag 1, then either a dense snapshot (`len:u32` +
/// 4 bytes/element) or the delta chain (`base:i64 | m:u32` + each delta's
/// keyless `delta_wire_bytes`).
pub fn push_row_wire_bytes(r: &PushRow) -> usize {
    21 + match &r.payload {
        PushPayload::Snapshot(data) => 4 + 4 * data.len(),
        PushPayload::Deltas { deltas, .. } => {
            12 + deltas.iter().map(delta_wire_bytes).sum::<usize>()
        }
    }
}

/// Exact size of the full encoded frame for a `ToShard` message.
pub fn to_shard_frame_len(m: &ToShard) -> usize {
    FRAME_OVERHEAD + to_shard_body_len(m)
}

/// Exact size of the full encoded frame for a `ToWorker` message.
pub fn to_worker_frame_len(m: &ToWorker) -> usize {
    FRAME_OVERHEAD + to_worker_body_len(m)
}

/// Exact size of the full encoded frame for a packet.
pub fn packet_frame_len(p: &Packet) -> usize {
    match p {
        Packet::ToShard(m) => to_shard_frame_len(m),
        Packet::ToWorker(m) => to_worker_frame_len(m),
    }
}

// ----------------------------------------------------------------- encode

#[inline]
fn w8(w: &mut impl Write, v: u8) -> io::Result<()> {
    w.write_all(&[v])
}

#[inline]
fn w32(w: &mut impl Write, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

#[inline]
fn w64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

#[inline]
fn wi64(w: &mut impl Write, v: i64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

#[inline]
fn wkey(w: &mut impl Write, key: &Key) -> io::Result<()> {
    w32(w, key.0)?;
    w64(w, key.1)
}

fn write_node(w: &mut impl Write, n: NodeId) -> io::Result<()> {
    match n {
        NodeId::Worker(i) => {
            w8(w, 0)?;
            w32(w, i as u32)
        }
        NodeId::Shard(i) => {
            w8(w, 1)?;
            w32(w, i as u32)
        }
        NodeId::Coordinator => {
            w8(w, 2)?;
            w32(w, 0)
        }
    }
}

/// Write a row payload. On little-endian targets this is one `write_all`
/// straight from the `f32` storage (no intermediate per-element buffer),
/// so pushing an `Arc<[f32]>` wave copies payload bytes exactly once —
/// into the socket.
pub fn write_f32s(w: &mut impl Write, xs: &[f32]) -> io::Result<()> {
    #[cfg(target_endian = "little")]
    {
        // Safety: `f32` is 4 bytes with no padding and any bit pattern is
        // a valid byte; the slice is live for the duration of the call.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        w.write_all(bytes)
    }
    #[cfg(not(target_endian = "little"))]
    {
        for x in xs {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

/// Write one repr-tagged row delta (`repr:u8 | dense(len|f32*) or
/// sparse(len|nnz|(idx,val)*)`) — shared by Update rows and RowHandoff
/// staged entries.
fn write_row_delta(w: &mut impl Write, delta: &RowDelta) -> io::Result<()> {
    match delta {
        RowDelta::Dense(v) => {
            w8(w, REPR_DENSE)?;
            w32(w, v.len() as u32)?;
            write_f32s(w, v)
        }
        RowDelta::Sparse { len, pairs } => {
            w8(w, REPR_SPARSE)?;
            w32(w, *len)?;
            w32(w, pairs.len() as u32)?;
            for (i, x) in pairs {
                w32(w, *i)?;
                w.write_all(&x.to_le_bytes())?;
            }
            Ok(())
        }
    }
}

/// Write a dense row snapshot as a keyless hybrid delta: the sparse pair
/// encoding iff it is smaller (same break-even as
/// `ps::types::hybrid_snapshot_wire_bytes`, which sizes this function's
/// output — keep the two in lockstep). -0.0 counts as nonzero (its bits
/// differ from the implicit zero fill), so the decoded dense row is
/// bit-identical to `data`.
fn write_hybrid_snapshot(w: &mut impl Write, data: &[f32]) -> io::Result<()> {
    let nnz = data.iter().filter(|x| x.to_bits() != 0).count();
    if 8 + 8 * nnz < 4 + 4 * data.len() {
        w8(w, REPR_SPARSE)?;
        w32(w, data.len() as u32)?;
        w32(w, nnz as u32)?;
        for (i, x) in data.iter().enumerate() {
            if x.to_bits() != 0 {
                w32(w, i as u32)?;
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    } else {
        w8(w, REPR_DENSE)?;
        w32(w, data.len() as u32)?;
        write_f32s(w, data)
    }
}

/// Append the optional trailing span context (wire v9): 12 bytes when
/// sampled, nothing at all when not.
fn write_span(w: &mut impl Write, span: &Option<SpanCtx>) -> io::Result<()> {
    if let Some(s) = span {
        w64(w, s.trace_id)?;
        w32(w, s.parent)?;
    }
    Ok(())
}

fn write_to_shard(w: &mut impl Write, m: &ToShard) -> io::Result<()> {
    match m {
        ToShard::Get {
            key,
            worker,
            min_vclock,
            span,
        } => {
            w8(w, K_GET)?;
            wkey(w, key)?;
            w32(w, *worker as u32)?;
            wi64(w, *min_vclock)?;
            write_span(w, span)
        }
        ToShard::Update {
            worker,
            clock,
            rows,
            span,
        } => {
            w8(w, K_UPDATE)?;
            w32(w, *worker as u32)?;
            wi64(w, *clock)?;
            w32(w, rows.len() as u32)?;
            for (key, delta) in rows {
                wkey(w, key)?;
                write_row_delta(w, delta)?;
            }
            write_span(w, span)
        }
        ToShard::ClockTick { worker, clock } => {
            w8(w, K_TICK)?;
            w32(w, *worker as u32)?;
            wi64(w, *clock)
        }
        ToShard::Register { key, worker } => {
            w8(w, K_REGISTER)?;
            wkey(w, key)?;
            w32(w, *worker as u32)
        }
        ToShard::PushAck { worker, vclock } => {
            w8(w, K_PUSH_ACK)?;
            w32(w, *worker as u32)?;
            wi64(w, *vclock)
        }
        ToShard::VapAck { worker, seq } => {
            w8(w, K_VAP_ACK)?;
            w32(w, *worker as u32)?;
            w64(w, *seq)
        }
        ToShard::NormReport {
            worker,
            clock,
            inf_norm,
        } => {
            w8(w, K_NORM_REPORT)?;
            w32(w, *worker as u32)?;
            wi64(w, *clock)?;
            w.write_all(&inf_norm.to_le_bytes())
        }
        ToShard::Detach { worker } => {
            w8(w, K_DETACH)?;
            w32(w, *worker as u32)
        }
        ToShard::MigrateBegin {
            epoch,
            at_clock,
            outgoing,
            incoming,
        } => {
            w8(w, K_MIGRATE_BEGIN)?;
            w64(w, *epoch)?;
            wi64(w, *at_clock)?;
            w32(w, outgoing.len() as u32)?;
            for (key, dst) in outgoing {
                wkey(w, key)?;
                w32(w, *dst)?;
            }
            w32(w, incoming.len() as u32)?;
            for key in incoming {
                wkey(w, key)?;
            }
            Ok(())
        }
        ToShard::RowHandoff {
            epoch,
            key,
            vclock,
            fresh,
            exists,
            data,
            staged,
        } => {
            w8(w, K_ROW_HANDOFF)?;
            w64(w, *epoch)?;
            wkey(w, key)?;
            wi64(w, *vclock)?;
            wi64(w, *fresh)?;
            w8(w, u8::from(*exists))?;
            write_hybrid_snapshot(w, data)?;
            w32(w, staged.len() as u32)?;
            for (clock, worker, delta) in staged {
                wi64(w, *clock)?;
                w32(w, *worker as u32)?;
                write_row_delta(w, delta)?;
            }
            Ok(())
        }
        ToShard::MigrateCommit { epoch } => {
            w8(w, K_MIGRATE_COMMIT)?;
            w64(w, *epoch)
        }
        ToShard::Promote { delta } => {
            w8(w, K_PROMOTE)?;
            write_placement_delta(w, delta)
        }
        ToShard::ReplicaSync {
            epoch,
            at_clock,
            target,
        } => {
            w8(w, K_REPLICA_SYNC)?;
            w64(w, *epoch)?;
            wi64(w, *at_clock)?;
            w32(w, *target)
        }
        ToShard::ReplicaCatchUp {
            epoch,
            at_clock,
            source,
            from_disk,
        } => {
            w8(w, K_REPLICA_CATCH_UP)?;
            w64(w, *epoch)?;
            wi64(w, *at_clock)?;
            w32(w, *source)?;
            w8(w, u8::from(*from_disk))
        }
        ToShard::StatsPull { worker } => {
            w8(w, K_STATS_PULL)?;
            w32(w, *worker as u32)
        }
        ToShard::Shutdown => w8(w, K_SHUTDOWN),
    }
}

/// Write a `PlacementDelta` body — shared by `ToWorker::Placement` and
/// `ToShard::Promote` so the two cannot drift.
fn write_placement_delta(w: &mut impl Write, delta: &PlacementDelta) -> io::Result<()> {
    w64(w, delta.epoch)?;
    wi64(w, delta.at_clock)?;
    // grow flag + value (0 when absent): fixed-size for a simple
    // body-length formula; likewise the promote flag + pair.
    w8(w, u8::from(delta.grow_active.is_some()))?;
    w32(w, delta.grow_active.unwrap_or(0))?;
    let (primary, node) = delta.promote.unwrap_or((0, 0));
    w8(w, u8::from(delta.promote.is_some()))?;
    w32(w, primary)?;
    w32(w, node)?;
    let (a_primary, a_node) = delta.attach.unwrap_or((0, 0));
    w8(w, u8::from(delta.attach.is_some()))?;
    w32(w, a_primary)?;
    w32(w, a_node)?;
    w32(w, delta.dead.len() as u32)?;
    for node in &delta.dead {
        w32(w, *node)?;
    }
    w32(w, delta.moves.len() as u32)?;
    for (key, dst) in &delta.moves {
        wkey(w, key)?;
        w32(w, *dst)?;
    }
    Ok(())
}

fn write_push_rows(w: &mut impl Write, rows: &[PushRow]) -> io::Result<()> {
    w32(w, rows.len() as u32)?;
    for r in rows {
        wkey(w, &r.key)?;
        wi64(w, r.fresh)?;
        match &r.payload {
            PushPayload::Snapshot(data) => {
                w8(w, PAYLOAD_SNAPSHOT)?;
                w32(w, data.len() as u32)?;
                write_f32s(w, data)?;
            }
            PushPayload::Deltas { base, deltas } => {
                w8(w, PAYLOAD_DELTAS)?;
                wi64(w, *base)?;
                w32(w, deltas.len() as u32)?;
                for d in deltas.iter() {
                    write_row_delta(w, d)?;
                }
            }
        }
    }
    Ok(())
}

fn write_to_worker(w: &mut impl Write, m: &ToWorker) -> io::Result<()> {
    match m {
        ToWorker::Row {
            key,
            data,
            vclock,
            fresh,
            span,
        } => {
            w8(w, K_ROW)?;
            wkey(w, key)?;
            wi64(w, *vclock)?;
            wi64(w, *fresh)?;
            w32(w, data.len() as u32)?;
            write_f32s(w, data)?;
            write_span(w, span)
        }
        ToWorker::Push {
            shard,
            vclock,
            rows,
            span,
        } => {
            w8(w, K_PUSH)?;
            w32(w, *shard as u32)?;
            wi64(w, *vclock)?;
            write_push_rows(w, rows)?;
            write_span(w, span)
        }
        ToWorker::VapPush { shard, seq, rows } => {
            w8(w, K_VAP_PUSH)?;
            w32(w, *shard as u32)?;
            w64(w, *seq)?;
            write_push_rows(w, rows)
        }
        ToWorker::Bound { shard, granted } => {
            w8(w, K_BOUND)?;
            w32(w, *shard as u32)?;
            w8(w, u8::from(*granted))
        }
        ToWorker::Placement { delta } => {
            w8(w, K_PLACEMENT)?;
            write_placement_delta(w, delta)
        }
        ToWorker::StatsReport { shard, entries } => {
            w8(w, K_STATS_REPORT)?;
            w32(w, *shard as u32)?;
            w32(w, entries.len() as u32)?;
            for (name, value) in entries {
                debug_assert!(name.len() <= MAX_STAT_NAME);
                w.write_all(&(name.len() as u16).to_le_bytes())?;
                w.write_all(name.as_bytes())?;
                w64(w, *value)?;
            }
            Ok(())
        }
    }
}

/// Encode one full frame (length prefix, addressing, body) to `w`.
///
/// Frames larger than [`MAX_FRAME`] are rejected with `InvalidInput`
/// *before any byte is written* (the stream stays clean): the decoder
/// would drop the connection on such a length, and beyond u32 the prefix
/// would wrap. The TCP sender asserts this bound before enqueueing (an
/// oversized message fails the run loudly rather than losing a gradient
/// batch); this error is the encoder-level backstop.
pub fn write_frame(
    w: &mut impl Write,
    src: NodeId,
    dst: NodeId,
    p: &Packet,
) -> io::Result<()> {
    let total = packet_frame_len(p);
    if total > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame of {total} bytes exceeds MAX_FRAME ({MAX_FRAME}); \
                 split the wave/update into smaller batches"
            ),
        ));
    }
    let len = (total - 4) as u32;
    w32(w, len)?;
    write_node(w, src)?;
    write_node(w, dst)?;
    match p {
        Packet::ToShard(m) => write_to_shard(w, m),
        Packet::ToWorker(m) => write_to_worker(w, m),
    }
}

/// Encode one full `ToShard` frame without a wrapping [`Packet`] — the
/// WAL appends borrowed messages straight off the shard's inbox, so this
/// avoids cloning row payloads just to frame them. Layout and limits are
/// identical to [`write_frame`].
pub fn write_to_shard_frame(
    w: &mut impl Write,
    src: NodeId,
    dst: NodeId,
    m: &ToShard,
) -> io::Result<()> {
    let total = to_shard_frame_len(m);
    if total > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame of {total} bytes exceeds MAX_FRAME ({MAX_FRAME}); \
                 split the wave/update into smaller batches"
            ),
        ));
    }
    let len = (total - 4) as u32;
    w32(w, len)?;
    write_node(w, src)?;
    write_node(w, dst)?;
    write_to_shard(w, m)
}

// ----------------------------------------------------------------- decode

/// Bounds-checked little-endian reads over a frame body.
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.b.len() >= n,
            "frame truncated: wanted {n} more bytes, have {}",
            self.b.len()
        );
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn rem(&self) -> usize {
        self.b.len()
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("bad bool byte {b}"),
        }
    }

    fn key(&mut self) -> Result<Key> {
        Ok((self.u32()?, self.u64()?))
    }

    /// Read the optional trailing span context (wire v9). The four bodies
    /// that carry one are otherwise fully self-describing, so exactly
    /// [`SPAN_WIRE_BYTES`] leftover bytes are a span and 0 mean
    /// unsampled; any other remainder falls through to the frame-level
    /// trailing-bytes check and errors there.
    fn span_tail(&mut self) -> Result<Option<SpanCtx>> {
        if self.rem() != SPAN_WIRE_BYTES {
            return Ok(None);
        }
        Ok(Some(SpanCtx {
            trace_id: self.u64()?,
            parent: self.u32()?,
        }))
    }

    fn worker(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }

    /// Read `n` f32s; the byte bound is checked before any allocation, so
    /// a lying length field cannot trigger a huge preallocation.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).context("payload length overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Read `n` f32s straight into their final shared allocation: the
    /// chunk iterator is exact-size, so collecting into `Arc<[f32]>`
    /// allocates the Arc storage once and writes every element in place —
    /// no staging `Vec`, no Vec→Arc re-copy. With this, a decoded row
    /// reaching the client cache costs exactly one payload copy (frame
    /// buffer → Arc). The byte bound is still checked before any
    /// allocation, as in [`Cur::f32s`].
    fn f32s_arc(&mut self, n: usize) -> Result<Arc<[f32]>> {
        let bytes = self.take(n.checked_mul(4).context("payload length overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn node(&mut self) -> Result<NodeId> {
        let kind = self.u8()?;
        let id = self.u32()? as usize;
        match kind {
            0 => Ok(NodeId::Worker(id)),
            1 => Ok(NodeId::Shard(id)),
            2 => Ok(NodeId::Coordinator),
            k => bail!("bad node kind {k}"),
        }
    }

    /// Read one hybrid update-row delta. Every bound is verified before
    /// any allocation: a sparse pair count is checked against both the
    /// bytes actually present and the declared row length, and each index
    /// must land inside the row and ascend strictly — a lying `nnz` or
    /// out-of-range index can neither trigger a huge preallocation nor
    /// corrupt a row at apply time.
    fn row_delta(&mut self) -> Result<RowDelta> {
        match self.u8()? {
            REPR_DENSE => {
                let len = self.u32()? as usize;
                Ok(RowDelta::Dense(self.f32s(len)?))
            }
            REPR_SPARSE => {
                let len = self.u32()?;
                // A sparse row's `len` is a *claim* about the dense width
                // it will expand to at apply time (`vec![0.0; len]` for a
                // not-yet-materialized key), so bound it by the widest row
                // the dense encoding could ever ship: otherwise a ~40-byte
                // frame could demand a 16 GiB allocation downstream.
                ensure!(
                    (len as usize) * 4 <= MAX_FRAME,
                    "sparse row claims dense width {len} (> MAX_FRAME/4)"
                );
                let nnz = self.u32()? as usize;
                ensure!(
                    nnz <= self.rem() / 8,
                    "sparse row claims {nnz} pairs but only {} bytes remain",
                    self.rem()
                );
                ensure!(
                    nnz as u64 <= len as u64,
                    "sparse row claims {nnz} pairs for a row of len {len}"
                );
                let mut pairs = Vec::with_capacity(nnz);
                let mut prev: Option<u32> = None;
                for p in 0..nnz {
                    let i = self.u32()?;
                    let v = self.f32()?;
                    ensure!(
                        i < len,
                        "sparse pair {p}: index {i} out of range for row len {len}"
                    );
                    if let Some(q) = prev {
                        ensure!(
                            i > q,
                            "sparse pair {p}: index {i} not strictly ascending after {q}"
                        );
                    }
                    prev = Some(i);
                    pairs.push((i, v));
                }
                Ok(RowDelta::Sparse { len, pairs })
            }
            r => bail!("bad row representation byte {r}"),
        }
    }
}

fn decode_placement_delta(c: &mut Cur) -> Result<PlacementDelta> {
    let epoch = c.u64()?;
    let at_clock = c.i64()?;
    let has_grow = c.bool()?;
    let grow = c.u32()?;
    let grow_active = has_grow.then_some(grow);
    let has_promote = c.bool()?;
    let primary = c.u32()?;
    let node = c.u32()?;
    let promote = has_promote.then_some((primary, node));
    let has_attach = c.bool()?;
    let a_primary = c.u32()?;
    let a_node = c.u32()?;
    let attach = has_attach.then_some((a_primary, a_node));
    let n_dead = c.u32()? as usize;
    ensure!(
        n_dead <= c.rem() / 4,
        "placement claims {n_dead} dead nodes but only {} bytes remain",
        c.rem()
    );
    let mut dead = Vec::with_capacity(n_dead);
    for _ in 0..n_dead {
        dead.push(c.u32()?);
    }
    let n_moves = c.u32()? as usize;
    ensure!(
        n_moves <= c.rem() / 16,
        "placement claims {n_moves} moves but only {} bytes remain",
        c.rem()
    );
    let mut moves = Vec::with_capacity(n_moves);
    for i in 0..n_moves {
        let key = c.key().with_context(|| format!("placement move {i}"))?;
        moves.push((key, c.u32()?));
    }
    Ok(PlacementDelta {
        epoch,
        at_clock,
        grow_active,
        promote,
        attach,
        dead,
        moves,
    })
}

fn decode_push_rows(c: &mut Cur) -> Result<Vec<PushRow>> {
    let n = c.u32()? as usize;
    // Each row needs >= 25 header bytes (key 12 + fresh 8 + tag 1 + the
    // smaller arm's 4-byte length): bound the count (and hence the Vec
    // preallocation) by what the frame can actually hold.
    ensure!(
        n <= c.rem() / 25,
        "push wave claims {n} rows but only {} bytes remain",
        c.rem()
    );
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let key = c.key().with_context(|| format!("push row {i}"))?;
        let fresh = c.i64()?;
        let payload = match c.u8().with_context(|| format!("push row {i} payload tag"))? {
            PAYLOAD_SNAPSHOT => {
                let len = c.u32()? as usize;
                PushPayload::Snapshot(
                    c.f32s_arc(len)
                        .with_context(|| format!("push row {i} payload"))?,
                )
            }
            PAYLOAD_DELTAS => {
                let base = c.i64()?;
                let m = c.u32()? as usize;
                // Each delta needs >= 5 bytes (repr 1 + len 4): bound the
                // chain length by the bytes present before preallocating.
                ensure!(
                    m <= c.rem() / 5,
                    "push row {i} claims {m} deltas but only {} bytes remain",
                    c.rem()
                );
                let mut deltas = Vec::with_capacity(m);
                for j in 0..m {
                    deltas.push(
                        c.row_delta()
                            .with_context(|| format!("push row {i} delta {j}"))?,
                    );
                }
                PushPayload::Deltas {
                    base,
                    deltas: deltas.into(),
                }
            }
            t => bail!("push row {i}: bad payload tag {t}"),
        };
        rows.push(PushRow { key, payload, fresh });
    }
    Ok(rows)
}

/// Decode a frame body (everything after the length prefix).
pub fn decode_frame(body: &[u8]) -> Result<(NodeId, NodeId, Packet)> {
    let mut c = Cur { b: body };
    let src = c.node().context("frame src address")?;
    let dst = c.node().context("frame dst address")?;
    let kind = c.u8().context("frame kind")?;
    let packet = match kind {
        K_GET => Packet::ToShard(ToShard::Get {
            key: c.key()?,
            worker: c.worker()?,
            min_vclock: c.i64()?,
            span: c.span_tail()?,
        }),
        K_UPDATE => {
            let worker = c.worker()?;
            let clock = c.i64()?;
            let n = c.u32()? as usize;
            // Each row needs >= 17 header bytes (key 12, repr 1, len 4):
            // bound the count (and the Vec preallocation) by what the
            // frame can actually hold.
            ensure!(
                n <= c.rem() / 17,
                "update claims {n} rows but only {} bytes remain",
                c.rem()
            );
            let mut rows = Vec::with_capacity(n);
            for i in 0..n {
                let key = c.key().with_context(|| format!("update row {i}"))?;
                let delta = c
                    .row_delta()
                    .with_context(|| format!("update row {i} delta"))?;
                rows.push((key, delta));
            }
            Packet::ToShard(ToShard::Update {
                worker,
                clock,
                rows,
                span: c.span_tail()?,
            })
        }
        K_TICK => Packet::ToShard(ToShard::ClockTick {
            worker: c.worker()?,
            clock: c.i64()?,
        }),
        K_REGISTER => Packet::ToShard(ToShard::Register {
            key: c.key()?,
            worker: c.worker()?,
        }),
        K_PUSH_ACK => Packet::ToShard(ToShard::PushAck {
            worker: c.worker()?,
            vclock: c.i64()?,
        }),
        K_VAP_ACK => Packet::ToShard(ToShard::VapAck {
            worker: c.worker()?,
            seq: c.u64()?,
        }),
        K_NORM_REPORT => Packet::ToShard(ToShard::NormReport {
            worker: c.worker()?,
            clock: c.i64()?,
            inf_norm: c.f32()?,
        }),
        K_DETACH => Packet::ToShard(ToShard::Detach {
            worker: c.worker()?,
        }),
        K_MIGRATE_BEGIN => {
            let epoch = c.u64()?;
            let at_clock = c.i64()?;
            let n_out = c.u32()? as usize;
            // Each outgoing entry is 16 bytes (key 12 + dst 4): bound the
            // count (and the Vec preallocation) by the bytes present.
            ensure!(
                n_out <= c.rem() / 16,
                "migrate-begin claims {n_out} outgoing keys but only {} bytes remain",
                c.rem()
            );
            let mut outgoing = Vec::with_capacity(n_out);
            for i in 0..n_out {
                let key = c.key().with_context(|| format!("outgoing key {i}"))?;
                outgoing.push((key, c.u32()?));
            }
            let n_in = c.u32()? as usize;
            ensure!(
                n_in <= c.rem() / 12,
                "migrate-begin claims {n_in} incoming keys but only {} bytes remain",
                c.rem()
            );
            let mut incoming = Vec::with_capacity(n_in);
            for i in 0..n_in {
                incoming.push(c.key().with_context(|| format!("incoming key {i}"))?);
            }
            Packet::ToShard(ToShard::MigrateBegin {
                epoch,
                at_clock,
                outgoing,
                incoming,
            })
        }
        K_ROW_HANDOFF => {
            let epoch = c.u64()?;
            let key = c.key()?;
            let vclock = c.i64()?;
            let fresh = c.i64()?;
            let exists = c.bool()?;
            // The row snapshot travels as a keyless hybrid delta (wire
            // v7). Sparse payloads expand by *placing* pairs into a zero
            // fill (`to_dense`), so every bit pattern survives.
            let data: Arc<[f32]> = match c.row_delta().context("handoff payload")? {
                RowDelta::Dense(v) => v.into(),
                sparse => sparse.to_dense().into(),
            };
            let n_staged = c.u32()? as usize;
            // Minimum staged entry: clock 8 + worker 4 + repr 1 + len 4.
            ensure!(
                n_staged <= c.rem() / 17,
                "handoff claims {n_staged} staged deltas but only {} bytes remain",
                c.rem()
            );
            let mut staged: Vec<(Clock, WorkerId, RowDelta)> = Vec::with_capacity(n_staged);
            for i in 0..n_staged {
                let clock = c.i64()?;
                let worker = c.worker()?;
                let delta = c
                    .row_delta()
                    .with_context(|| format!("handoff staged delta {i}"))?;
                staged.push((clock, worker, delta));
            }
            Packet::ToShard(ToShard::RowHandoff {
                epoch,
                key,
                vclock,
                fresh,
                exists,
                data,
                staged,
            })
        }
        K_MIGRATE_COMMIT => Packet::ToShard(ToShard::MigrateCommit { epoch: c.u64()? }),
        K_PROMOTE => Packet::ToShard(ToShard::Promote {
            delta: decode_placement_delta(&mut c)?,
        }),
        K_REPLICA_SYNC => Packet::ToShard(ToShard::ReplicaSync {
            epoch: c.u64()?,
            at_clock: c.i64()?,
            target: c.u32()?,
        }),
        K_REPLICA_CATCH_UP => Packet::ToShard(ToShard::ReplicaCatchUp {
            epoch: c.u64()?,
            at_clock: c.i64()?,
            source: c.u32()?,
            from_disk: c.bool()?,
        }),
        K_STATS_PULL => Packet::ToShard(ToShard::StatsPull {
            worker: c.worker()?,
        }),
        K_SHUTDOWN => Packet::ToShard(ToShard::Shutdown),
        K_ROW => {
            let key = c.key()?;
            let vclock = c.i64()?;
            let fresh = c.i64()?;
            let len = c.u32()? as usize;
            Packet::ToWorker(ToWorker::Row {
                key,
                data: c.f32s_arc(len).context("row payload")?,
                vclock,
                fresh,
                span: c.span_tail()?,
            })
        }
        K_PUSH => Packet::ToWorker(ToWorker::Push {
            shard: c.u32()? as usize,
            vclock: c.i64()?,
            rows: decode_push_rows(&mut c)?,
            span: c.span_tail()?,
        }),
        K_VAP_PUSH => Packet::ToWorker(ToWorker::VapPush {
            shard: c.u32()? as usize,
            seq: c.u64()?,
            rows: decode_push_rows(&mut c)?,
        }),
        K_BOUND => Packet::ToWorker(ToWorker::Bound {
            shard: c.u32()? as usize,
            granted: c.bool()?,
        }),
        K_PLACEMENT => Packet::ToWorker(ToWorker::Placement {
            delta: decode_placement_delta(&mut c)?,
        }),
        K_STATS_REPORT => {
            let shard = c.u32()? as usize;
            let n = c.u32()? as usize;
            // Each entry needs >= 10 bytes (name-len 2 + value 8): bound
            // the count (and the Vec preallocation) by the bytes present.
            ensure!(
                n <= c.rem() / 10,
                "stats report claims {n} entries but only {} bytes remain",
                c.rem()
            );
            let mut entries = Vec::with_capacity(n);
            for i in 0..n {
                let len = c.u16()? as usize;
                ensure!(
                    len <= MAX_STAT_NAME,
                    "stats entry {i}: name of {len} bytes (> {MAX_STAT_NAME})"
                );
                let name = std::str::from_utf8(c.take(len)?)
                    .with_context(|| format!("stats entry {i} name"))?
                    .to_string();
                entries.push((name, c.u64()?));
            }
            Packet::ToWorker(ToWorker::StatsReport { shard, entries })
        }
        k => bail!("unknown message kind {k}"),
    };
    ensure!(
        c.rem() == 0,
        "frame has {} trailing bytes after a complete message",
        c.rem()
    );
    Ok((src, dst, packet))
}

/// Read the next frame from a stream. `Ok(None)` means a clean EOF at a
/// frame boundary (the peer closed); mid-frame EOF is an error. `scratch`
/// is a reusable body buffer.
pub fn read_frame(
    r: &mut impl Read,
    scratch: &mut Vec<u8>,
) -> Result<Option<(NodeId, NodeId, Packet)>> {
    let mut prefix = [0u8; 4];
    if !read_full_or_eof(r, &mut prefix)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(prefix) as usize;
    ensure!(
        (FRAME_OVERHEAD - 4..=MAX_FRAME).contains(&len),
        "bad frame length {len} (corrupt stream?)"
    );
    scratch.clear();
    scratch.resize(len, 0);
    r.read_exact(scratch)
        .with_context(|| format!("reading {len}-byte frame body"))?;
    decode_frame(scratch).map(Some)
}

/// Fill `buf` completely; `Ok(false)` = clean EOF before the first byte.
fn read_full_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                bail!(
                    "connection closed mid-frame ({filled} of {} prefix bytes)",
                    buf.len()
                );
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

// -------------------------------------------------------------- handshake

/// Magic of the version-reject blob an acceptor answers with (then
/// closes) when a well-magic'd hello announces a version outside
/// [`VERSION_MIN`]..=[`VERSION_MAX`].
pub const REJECT_MAGIC: [u8; 8] = *b"ESSPREJ1";
/// Total reject blob size: magic | peer_version (echoed) | min | max.
pub const REJECT_LEN: usize = 8 + 3 * 2;

/// Decoded version-reject blob: both sides' versions in one diagnosis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VersionReject {
    /// The version the rejected dialer announced (echoed back so the
    /// dialer's error can name what *it* said, even across restarts).
    pub peer_version: u16,
    /// The rejecting binary's supported range.
    pub min_supported: u16,
    pub max_supported: u16,
}

/// Write the reject blob for a peer that announced `peer_version`.
pub fn write_version_reject(w: &mut impl Write, peer_version: u16) -> io::Result<()> {
    w.write_all(&REJECT_MAGIC)?;
    w.write_all(&peer_version.to_le_bytes())?;
    w.write_all(&VERSION_MIN.to_le_bytes())?;
    w.write_all(&VERSION_MAX.to_le_bytes())?;
    w.flush()
}

/// Decode a reject blob's tail (the bytes after its 8-byte magic).
pub fn decode_version_reject(tail: &[u8]) -> Result<VersionReject> {
    ensure!(
        tail.len() == REJECT_LEN - 8,
        "version-reject blob has {} tail bytes, expected {}",
        tail.len(),
        REJECT_LEN - 8
    );
    Ok(VersionReject {
        peer_version: u16::from_le_bytes(tail[0..2].try_into().unwrap()),
        min_supported: u16::from_le_bytes(tail[2..4].try_into().unwrap()),
        max_supported: u16::from_le_bytes(tail[4..6].try_into().unwrap()),
    })
}

/// What an acceptor read off the wire: a speakable peer hello, or a
/// correctly-magic'd hello of a version we cannot speak (the caller
/// should answer with [`write_version_reject`] and close the socket).
#[derive(Debug)]
pub enum HelloOutcome {
    Peer(NodeId, NodeId),
    BadVersion(u16),
}

/// Write the connection handshake: magic, version, and the (src, dst)
/// node pair this connection will carry.
pub fn write_hello(w: &mut impl Write, src: NodeId, dst: NodeId) -> io::Result<()> {
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_node(w, src)?;
    write_node(w, dst)?;
    w.flush()
}

/// Acceptor-side handshake read: surfaces a version mismatch as
/// [`HelloOutcome::BadVersion`] instead of a bare error, so the acceptor
/// can answer with the reject blob before dropping the connection.
pub fn read_hello_outcome(r: &mut impl Read) -> Result<HelloOutcome> {
    let mut buf = [0u8; HELLO_LEN];
    r.read_exact(&mut buf).context("reading transport handshake")?;
    ensure!(
        buf[..8] == MAGIC,
        "bad handshake magic {:02x?} (not an essptable peer?)",
        &buf[..8]
    );
    let version = u16::from_le_bytes(buf[8..10].try_into().unwrap());
    if !(VERSION_MIN..=VERSION_MAX).contains(&version) {
        return Ok(HelloOutcome::BadVersion(version));
    }
    let mut c = Cur { b: &buf[10..] };
    Ok(HelloOutcome::Peer(c.node()?, c.node()?))
}

/// Dialer-side handshake read (also validates an acceptor's ack). A
/// version mismatch — ours detected locally, or the peer's reject blob —
/// produces an error naming BOTH sides' versions and this binary's
/// supported range, never a silent drop.
pub fn read_hello(r: &mut impl Read) -> Result<(NodeId, NodeId)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading transport handshake")?;
    if magic == REJECT_MAGIC {
        let mut tail = [0u8; REJECT_LEN - 8];
        r.read_exact(&mut tail).context("reading version-reject blob")?;
        let rej = decode_version_reject(&tail)?;
        bail!(
            "wire protocol version rejected by peer: we announced \
             v{}, peer supports v{}..v{} (this binary supports \
             v{VERSION_MIN}..v{VERSION_MAX})",
            rej.peer_version,
            rej.min_supported,
            rej.max_supported
        );
    }
    ensure!(
        magic == MAGIC,
        "bad handshake magic {magic:02x?} (not an essptable peer?)"
    );
    let mut rest = [0u8; HELLO_LEN - 8];
    r.read_exact(&mut rest).context("reading handshake body")?;
    let version = u16::from_le_bytes(rest[..2].try_into().unwrap());
    ensure!(
        (VERSION_MIN..=VERSION_MAX).contains(&version),
        "wire protocol version mismatch: peer speaks v{version}, this \
         binary supports v{VERSION_MIN}..v{VERSION_MAX}"
    );
    let mut c = Cur { b: &rest[2..] };
    Ok((c.node()?, c.node()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encoded(src: NodeId, dst: NodeId, p: &Packet) -> Vec<u8> {
        let mut v = Vec::new();
        write_frame(&mut v, src, dst, p).unwrap();
        v
    }

    #[test]
    fn frame_len_is_exact_for_every_variant() {
        let rows = vec![
            PushRow::snapshot((1, 2), vec![1.0f32, 2.0, 3.0].into(), 7),
            PushRow::snapshot((1, 3), Vec::<f32>::new().into(), -1),
            PushRow::deltas(
                (1, 4),
                5,
                vec![
                    RowDelta::Dense(vec![0.25, -0.5]),
                    RowDelta::sparse(4096, vec![(0, 1.5), (17, -0.25)]),
                    RowDelta::sparse(8, vec![]),
                ]
                .into(),
                9,
            ),
            PushRow::deltas((1, 5), -1, Vec::new().into(), -1),
        ];
        let msgs: Vec<Packet> = vec![
            Packet::ToShard(ToShard::Get {
                key: (0, 9),
                worker: 3,
                min_vclock: -5,
                span: None,
            }),
            Packet::ToShard(ToShard::Get {
                key: (0, 9),
                worker: 3,
                min_vclock: -5,
                span: Some(SpanCtx::for_worker(3, 17)),
            }),
            Packet::ToShard(ToShard::Update {
                worker: 1,
                clock: 4,
                rows: vec![
                    ((2, 8), vec![0.5f32; 5].into()),
                    ((2, 9), RowDelta::Dense(vec![])),
                    ((2, 10), RowDelta::sparse(4096, vec![(0, 1.5), (17, -0.25)])),
                    ((2, 11), RowDelta::sparse(8, vec![])),
                ],
                span: None,
            }),
            Packet::ToShard(ToShard::Update {
                // Zero rows + a span: the decoder must not mistake the
                // trailing 12 bytes for row data.
                worker: 1,
                clock: 4,
                rows: vec![],
                span: Some(SpanCtx::for_worker(1, 0)),
            }),
            Packet::ToShard(ToShard::ClockTick { worker: 0, clock: 0 }),
            Packet::ToShard(ToShard::Register {
                key: (1, 1),
                worker: 2,
            }),
            Packet::ToShard(ToShard::PushAck {
                worker: 2,
                vclock: 3,
            }),
            Packet::ToShard(ToShard::VapAck { worker: 0, seq: 99 }),
            Packet::ToShard(ToShard::NormReport {
                worker: 1,
                clock: 8,
                inf_norm: 0.75,
            }),
            Packet::ToShard(ToShard::Detach { worker: 3 }),
            Packet::ToShard(ToShard::MigrateBegin {
                epoch: 1,
                at_clock: 6,
                outgoing: vec![((0, 1), 3), ((0, 9), 2)],
                incoming: vec![(4, 4)],
            }),
            Packet::ToShard(ToShard::MigrateBegin {
                epoch: 2,
                at_clock: 0,
                outgoing: vec![],
                incoming: vec![],
            }),
            Packet::ToShard(ToShard::RowHandoff {
                epoch: 1,
                key: (2, 7),
                vclock: 5,
                fresh: 6,
                exists: true,
                data: vec![1.0f32, -2.5].into(),
                staged: vec![
                    (6, 0, RowDelta::Dense(vec![0.5, 0.5])),
                    (7, 2, RowDelta::sparse(64, vec![(3, 1.0), (9, -1.0)])),
                ],
            }),
            Packet::ToShard(ToShard::RowHandoff {
                // Mostly-zero wide row: the hybrid snapshot encoder must
                // pick the sparse arm (and -0.0 must survive as an
                // explicit pair — to_bits() != 0).
                epoch: 2,
                key: (2, 9),
                vclock: 8,
                fresh: 9,
                exists: true,
                data: {
                    let mut v = vec![0.0f32; 1024];
                    v[3] = 1.5;
                    v[900] = -0.0;
                    v.into()
                },
                staged: vec![],
            }),
            Packet::ToShard(ToShard::RowHandoff {
                epoch: 3,
                key: (2, 8),
                vclock: -1,
                fresh: -1,
                exists: false,
                data: Vec::<f32>::new().into(),
                staged: vec![],
            }),
            Packet::ToShard(ToShard::MigrateCommit { epoch: 9 }),
            Packet::ToShard(ToShard::Promote {
                delta: PlacementDelta {
                    epoch: 1,
                    at_clock: 0,
                    grow_active: None,
                    promote: Some((0, 2)),
                    attach: None,
                    dead: vec![0, 7],
                    moves: vec![],
                },
            }),
            Packet::ToShard(ToShard::ReplicaSync {
                epoch: 3,
                at_clock: 12,
                target: 4,
            }),
            Packet::ToShard(ToShard::ReplicaCatchUp {
                epoch: 3,
                at_clock: 12,
                source: 2,
                from_disk: false,
            }),
            Packet::ToShard(ToShard::ReplicaCatchUp {
                epoch: 4,
                at_clock: -1,
                source: 0,
                from_disk: true,
            }),
            Packet::ToShard(ToShard::StatsPull { worker: 3 }),
            Packet::ToShard(ToShard::StatsPull {
                worker: crate::ps::msg::COORD_STATS_WORKER,
            }),
            Packet::ToShard(ToShard::Shutdown),
            Packet::ToWorker(ToWorker::Row {
                key: (3, 1),
                data: vec![1.5f32; 4].into(),
                vclock: 2,
                fresh: 3,
                span: None,
            }),
            Packet::ToWorker(ToWorker::Row {
                key: (3, 1),
                data: vec![1.5f32; 4].into(),
                vclock: 2,
                fresh: 3,
                span: Some(SpanCtx::for_worker(9, 1 << 39)),
            }),
            Packet::ToWorker(ToWorker::Push {
                shard: 1,
                vclock: 6,
                rows: rows.clone(),
                span: None,
            }),
            Packet::ToWorker(ToWorker::Push {
                shard: 1,
                vclock: 6,
                rows: rows.clone(),
                span: Some(SpanCtx::for_shard(1, 5)),
            }),
            Packet::ToWorker(ToWorker::VapPush {
                shard: 0,
                seq: 11,
                rows,
            }),
            Packet::ToWorker(ToWorker::Bound {
                shard: 1,
                granted: true,
            }),
            Packet::ToWorker(ToWorker::Bound {
                shard: 0,
                granted: false,
            }),
            Packet::ToWorker(ToWorker::Placement {
                delta: PlacementDelta {
                    epoch: 1,
                    at_clock: 6,
                    grow_active: Some(4),
                    promote: None,
                    attach: None,
                    dead: vec![],
                    moves: vec![((0, 1), 3)],
                },
            }),
            Packet::ToWorker(ToWorker::Placement {
                delta: PlacementDelta {
                    epoch: 2,
                    at_clock: 11,
                    grow_active: None,
                    promote: Some((1, 3)),
                    attach: Some((1, 4)),
                    dead: vec![1],
                    moves: vec![],
                },
            }),
            Packet::ToWorker(ToWorker::StatsReport {
                shard: 1,
                entries: vec![
                    ("shard.gets_served".into(), 42),
                    ("shard.read_ns#b12".into(), u64::MAX),
                    (String::new(), 0),
                ],
            }),
            Packet::ToWorker(ToWorker::StatsReport {
                shard: 0,
                entries: vec![],
            }),
        ];
        for p in &msgs {
            let bytes = encoded(NodeId::Worker(1), NodeId::Shard(0), p);
            assert_eq!(bytes.len(), p.wire_bytes(), "size mismatch for {p:?}");
            let (src, dst, back) = decode_frame(&bytes[4..]).unwrap();
            assert_eq!(src, NodeId::Worker(1));
            assert_eq!(dst, NodeId::Shard(0));
            assert_eq!(&back, p);
        }
    }

    #[test]
    fn borrowing_to_shard_writer_matches_packet_writer() {
        // The WAL's borrowing encoder must be byte-identical to the
        // Packet-wrapping one — they are the same on-disk format.
        let m = ToShard::Update {
            worker: 2,
            clock: 9,
            rows: vec![
                ((1, 4), vec![1.0f32, 2.0].into()),
                ((1, 5), RowDelta::sparse(128, vec![(7, 0.5)])),
            ],
            span: Some(SpanCtx::for_worker(2, 3)),
        };
        let mut via_packet = Vec::new();
        write_frame(
            &mut via_packet,
            NodeId::Coordinator,
            NodeId::Shard(1),
            &Packet::ToShard(m.clone()),
        )
        .unwrap();
        let mut borrowed = Vec::new();
        write_to_shard_frame(&mut borrowed, NodeId::Coordinator, NodeId::Shard(1), &m)
            .unwrap();
        assert_eq!(via_packet, borrowed);
    }

    #[test]
    fn unsampled_frames_carry_zero_span_bytes() {
        // The v9 invariant: span == None must encode byte-identically to
        // the v8 layout — 12 extra bytes appear only when sampled.
        let bare = Packet::ToShard(ToShard::Get {
            key: (1, 2),
            worker: 0,
            min_vclock: 3,
            span: None,
        });
        let sampled = Packet::ToShard(ToShard::Get {
            key: (1, 2),
            worker: 0,
            min_vclock: 3,
            span: Some(SpanCtx::for_worker(0, 0)),
        });
        let a = encoded(NodeId::Worker(0), NodeId::Shard(0), &bare);
        let b = encoded(NodeId::Worker(0), NodeId::Shard(0), &sampled);
        assert_eq!(b.len(), a.len() + SPAN_WIRE_BYTES);
        // Everything but the length prefix and the trailing span matches.
        assert_eq!(a[4..], b[4..a.len()]);
        // Truncating a sampled span mid-way is a decode error, not a
        // silently shorter message.
        assert!(decode_frame(&b[4..b.len() - 5]).is_err());
    }

    #[test]
    fn hello_roundtrip_and_rejection() {
        let mut buf = Vec::new();
        write_hello(&mut buf, NodeId::Worker(7), NodeId::Shard(2)).unwrap();
        assert_eq!(buf.len(), HELLO_LEN);
        let (src, dst) = read_hello(&mut &buf[..]).unwrap();
        assert_eq!(src, NodeId::Worker(7));
        assert_eq!(dst, NodeId::Shard(2));
        // Corrupt magic.
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_hello(&mut &bad[..]).is_err());
        // Future version.
        let mut newer = buf.clone();
        newer[8] = 0xEE;
        assert!(read_hello(&mut &newer[..]).is_err());
    }

    #[test]
    fn coordinator_node_roundtrips_on_frames() {
        let p = Packet::ToShard(ToShard::MigrateCommit { epoch: 4 });
        let bytes = encoded(NodeId::Coordinator, NodeId::Shard(2), &p);
        assert_eq!(bytes.len(), p.wire_bytes());
        let (src, dst, back) = decode_frame(&bytes[4..]).unwrap();
        assert_eq!(src, NodeId::Coordinator);
        assert_eq!(dst, NodeId::Shard(2));
        assert_eq!(back, p);
    }

    #[test]
    fn version_mismatch_surfaces_as_outcome_and_reject_names_both_sides() {
        // Acceptor side: a hello announcing an unsupported version is a
        // BadVersion outcome (so the acceptor can answer), not a bare
        // error and not a Peer.
        let mut hello = Vec::new();
        write_hello(&mut hello, NodeId::Worker(0), NodeId::Shard(1)).unwrap();
        hello[8..10].copy_from_slice(&0xBEEFu16.to_le_bytes());
        match read_hello_outcome(&mut &hello[..]).unwrap() {
            HelloOutcome::BadVersion(v) => assert_eq!(v, 0xBEEF),
            other => panic!("unexpected {other:?}"),
        }
        // The reject blob decodes back to both peer versions plus the
        // rejecting binary's supported range...
        let mut blob = Vec::new();
        write_version_reject(&mut blob, 0xBEEF).unwrap();
        assert_eq!(blob.len(), REJECT_LEN);
        let rej = decode_version_reject(&blob[8..]).unwrap();
        assert_eq!(
            rej,
            VersionReject {
                peer_version: 0xBEEF,
                min_supported: VERSION_MIN,
                max_supported: VERSION_MAX,
            }
        );
        // ...and the dialer reading it gets an error that names its own
        // announced version AND the peer's supported range.
        let err = read_hello(&mut &blob[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&format!("v{}", 0xBEEFu16)), "{msg}");
        assert!(
            msg.contains(&format!("v{VERSION_MIN}..v{VERSION_MAX}")),
            "{msg}"
        );
        // Local detection (no reject blob in play) still reports both
        // the peer's version and our range.
        let err = read_hello(&mut &hello[..]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&format!("v{}", 0xBEEFu16)), "{msg}");
        assert!(
            msg.contains(&format!("v{VERSION_MIN}..v{VERSION_MAX}")),
            "{msg}"
        );
        // A truncated blob tail is a clean error.
        assert!(decode_version_reject(&blob[8..12]).is_err());
    }

    #[test]
    fn oversize_and_undersize_length_prefixes_rejected() {
        let huge = [0xFFu8, 0xFF, 0xFF, 0xFF, 0, 0];
        assert!(read_frame(&mut &huge[..], &mut Vec::new()).is_err());
        let tiny = 3u32.to_le_bytes();
        assert!(read_frame(&mut &tiny[..], &mut Vec::new()).is_err());
    }
}
