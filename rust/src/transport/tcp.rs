//! Real TCP data plane: per-peer writer threads, per-connection reader/
//! demux threads, a reconnect-on-start handshake, and backpressure via
//! bounded writer queues.
//!
//! Topology mirrors the PS protocol: workers dial shards (one connection
//! per (worker, shard) link — the unit of FIFO ordering the protocol
//! requires). When a migration is armed, shards additionally dial their
//! higher-indexed peers so `RowHandoff` traffic has a FIFO link; a
//! destination hosted by the *same* process (the in-process TCP fabric
//! hosts every shard on one endpoint) is delivered directly, no socket.
//! Each connection carries both directions: the dialing side's `ToShard`
//! traffic and the accepting side's replies.
//!
//! Threads per endpoint:
//!   * server only: one acceptor (non-blocking poll so shutdown can join it),
//!   * per connection: one writer — owns the (src, dst) route's bounded
//!     queue; each wakeup drains every queued frame, encodes them
//!     back-to-back into one reusable batch buffer, and pushes the whole
//!     coalesced batch to the socket in a single `write_all` (flushing
//!     early at the [`COALESCE`] boundary) — and one reader — decodes
//!     frames and demuxes them into local node inboxes.
//!
//! Lifecycle: a process stops sending by dropping its writer queues
//! (`close_send`), which flushes and closes the write half of every
//! socket; the remote reader then sees a clean EOF at a frame boundary.
//! `serve-shard` uses the [`PeerEvent`] stream to exit once every
//! expected worker has connected and later disconnected.
//!
//! Telemetry: besides the endpoint-wide [`TcpStats`], every registered
//! link carries a [`LinkStats`] (frames/bytes written, writer-queue depth
//! and high-water mark, backpressure stalls); [`TcpTransport::metrics_source`]
//! exposes both to the admin scrape endpoint, and an attached
//! [`TraceRing`] records peer lifecycle transitions plus (debug level)
//! per-link backpressure events.

use std::io::{self, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::wire;
use super::{NodeId, Packet, Transport, TransportHandle};
use crate::ps::msg::{ToShard, ToWorker};
use crate::sim::fault::FaultInjector;
use crate::telemetry::registry::{MetricsSource, Snapshot};
use crate::telemetry::spans::{Mark, SpanRing};
use crate::telemetry::trace::TraceRing;
use crate::util::hash::{FxHashMap, FxHashSet};

/// Bounded depth of each per-peer writer queue. A full queue blocks the
/// producing thread (client/shard), which is the backpressure that keeps
/// a fast producer from buffering unbounded memory behind a slow link.
/// (Unit tests shrink the bound so the backpressure path is exercisable
/// without queueing thousands of frames.)
const WRITER_QUEUE: usize = if cfg!(test) { 8 } else { 4096 };
/// Socket buffer size for the reader side's `BufReader`.
const SOCK_BUF: usize = 64 * 1024;
/// Frame-coalescing boundary of the per-peer writer: frames drained at
/// one wakeup are encoded back-to-back into a reusable batch buffer and
/// hit the socket in a single `write_all` — but once the batch crosses
/// this size it is flushed immediately, bounding both the writer's
/// memory and how long the first coalesced frame waits behind the rest.
/// (A batch may exceed the boundary by at most one frame: the check runs
/// after each encode.)
const COALESCE: usize = 64 * 1024;
/// How long either side of the handshake may keep the other waiting.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Where locally-hosted nodes receive their inbound traffic.
#[derive(Clone)]
pub enum LocalSink {
    Worker(Sender<ToWorker>),
    Shard(Sender<ToShard>),
}

/// Outcome of a local (same-process) delivery attempt.
enum LocalDelivery {
    Delivered,
    /// The node's inbox hung up: its thread exited (orderly shutdown or
    /// a kill fault). Surfaced once per node as an unclean peer-down, so
    /// the in-process TCP fabric feeds the failure detector the same
    /// signal a dead remote process would.
    HungUp,
    /// A `ToShard` addressed to a worker, or vice versa.
    Mismatch,
}

impl LocalSink {
    fn deliver(&self, packet: Packet) -> LocalDelivery {
        match (self, packet) {
            (LocalSink::Worker(tx), Packet::ToWorker(m)) => match tx.send(m) {
                Ok(()) => LocalDelivery::Delivered,
                Err(_) => LocalDelivery::HungUp,
            },
            (LocalSink::Shard(tx), Packet::ToShard(m)) => match tx.send(m) {
                Ok(()) => LocalDelivery::Delivered,
                Err(_) => LocalDelivery::HungUp,
            },
            _ => LocalDelivery::Mismatch,
        }
    }
}

/// Peer lifecycle notifications (server side), used by `serve-shard` to
/// exit once every expected worker has come and gone. `clean` is true
/// for an orderly EOF at a frame boundary; false means the link died on
/// an I/O or decode error, so traffic may have been lost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerEvent {
    Connected(NodeId),
    Disconnected { node: NodeId, clean: bool },
}

/// Traffic counters; bytes are exact encoded frame sizes from the codec.
#[derive(Default)]
pub struct TcpStats {
    messages: AtomicU64,
    bytes: AtomicU64,
    delivered: AtomicU64,
    dropped: AtomicU64,
    backpressure: AtomicU64,
    dial_retries: AtomicU64,
    /// Span recorder for sampled frames (wire v9), installed once via
    /// [`TcpTransport::set_spans`]. Lives here so the writer/reader loops
    /// (which hold the shared stats) can record without new plumbing;
    /// absent in untraced runs — one `OnceLock` load on the frame path.
    spans: OnceLock<Arc<SpanRing>>,
}

impl TcpStats {
    /// The installed span ring paired with a sampled frame's context, or
    /// `None` on either miss — callers hook in one `if let`.
    fn span_of(&self, packet: &Packet) -> Option<(&Arc<SpanRing>, crate::telemetry::spans::SpanCtx)> {
        let ring = self.spans.get()?;
        Some((ring, packet.span()?))
    }
}

impl TcpStats {
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Acquire)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Acquire)
    }

    pub fn delivered(&self) -> u64 {
        self.delivered.load(Ordering::Acquire)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Acquire)
    }

    /// Sends that found their writer queue full and had to block. Before
    /// this counter existed, a producer stalling behind a slow link was
    /// invisible — the run just got slower with nothing to scrape.
    pub fn backpressure(&self) -> u64 {
        self.backpressure.load(Ordering::Acquire)
    }

    /// Failed connect attempts that were retried by the dial backoff
    /// loop. Nonzero during normal any-order startup; steadily climbing
    /// afterwards means a peer address is wrong or a peer is flapping.
    pub fn dial_retries(&self) -> u64 {
        self.dial_retries.load(Ordering::Acquire)
    }

    /// Messages that finished their journey: delivered to an inbox, or
    /// dropped on a dead/unknown route (error paths only).
    pub fn settled(&self) -> u64 {
        self.delivered() + self.dropped()
    }
}

/// Per-link traffic counters, one per registered (src -> dst) route.
/// Registered at connection setup and kept for the endpoint's lifetime
/// (a disconnected link's final counters stay scrapeable).
#[derive(Default)]
pub struct LinkStats {
    /// Frames actually written to the socket by this link's writer.
    frames: AtomicU64,
    /// Encoded bytes of those frames.
    bytes: AtomicU64,
    /// Sends that found this link's writer queue full.
    backpressure: AtomicU64,
    /// Frames currently sitting in the writer queue.
    queue_depth: AtomicU64,
    /// Deepest the writer queue ever got.
    queue_hwm: AtomicU64,
}

impl LinkStats {
    fn note_enqueued(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::AcqRel) + 1;
        self.queue_hwm.fetch_max(depth, Ordering::AcqRel);
    }

    fn note_dequeued(&self) {
        self.queue_depth.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Short human name for a node, used in link labels and trace details
/// (`w0`, `s3`, `coord`).
fn node_name(n: NodeId) -> String {
    match n {
        NodeId::Worker(w) => format!("w{w}"),
        NodeId::Shard(s) => format!("s{s}"),
        NodeId::Coordinator => "coord".into(),
    }
}

fn link_name(src: NodeId, dst: NodeId) -> String {
    format!("{}->{}", node_name(src), node_name(dst))
}

type Frame = (NodeId, NodeId, Packet);

/// A registered outbound link: the writer queue plus its counters.
#[derive(Clone)]
struct Route {
    q: SyncSender<Frame>,
    link: Arc<LinkStats>,
}

struct Inner {
    /// (src, dst) -> the writer queue of the connection carrying that
    /// link. One entry per direction per connection.
    routes: RwLock<FxHashMap<(NodeId, NodeId), Route>>,
    /// Latched by `close_send` (under the routes write lock): no new
    /// connection may register afterwards, so a dial that races shutdown
    /// cannot resurrect a route whose writer would then never be joined.
    closed: AtomicBool,
    /// One handle per live connection, so `join` can force-shutdown
    /// sockets and unblock readers whose peer never closes.
    socks: Mutex<Vec<TcpStream>>,
    /// Nodes hosted in this process and their inboxes.
    local: FxHashMap<NodeId, LocalSink>,
    stats: Arc<TcpStats>,
    events: Option<Sender<PeerEvent>>,
    /// Link-fault injector (`--fault-plan`): writers consult it per frame
    /// — `delay` stalls the link (FIFO preserved), `drop` discards the
    /// frame (counted, so flush converges). `reorder` is sim-only; a TCP
    /// stream cannot reorder.
    faults: Option<Arc<FaultInjector>>,
    /// Every link ever registered, in registration order, kept past
    /// disconnect so the scrape endpoint can report final counters.
    links: Mutex<Vec<((NodeId, NodeId), Arc<LinkStats>)>>,
    /// Locally-hosted nodes whose inbox hung up (thread exited), so the
    /// unclean peer-down each one triggers fires exactly once.
    local_down: Mutex<FxHashSet<NodeId>>,
    /// Structured event ring (`--trace-out`): peer lifecycle transitions
    /// and (debug level) per-link backpressure stalls. Attached after
    /// construction via [`TcpTransport::set_trace`], hence the lock —
    /// only touched on rare events, never on the per-frame path.
    trace: Mutex<Option<Arc<TraceRing>>>,
}

impl Inner {
    fn trace_event(&self, kind: &str, detail: String) {
        let ring = self.trace.lock().unwrap().clone();
        if let Some(t) = ring {
            // -1: the transport has no logical clock.
            t.record("tcp", -1, kind, detail);
        }
    }

    fn trace_debug(&self, kind: &str, detail: String) {
        let ring = self.trace.lock().unwrap().clone();
        if let Some(t) = ring {
            t.record_debug("tcp", -1, kind, detail);
        }
    }

    /// A locally-hosted node's inbox hung up: report it once, exactly as
    /// the reader loop reports a dead remote peer.
    fn note_local_down(&self, node: NodeId) {
        if !self.local_down.lock().unwrap().insert(node) {
            return;
        }
        if let Some(ev) = &self.events {
            let _ = ev.send(PeerEvent::Disconnected { node, clean: false });
        }
        self.trace_event(
            "peer_down",
            format!("local node {node:?} inbox hung up (thread exited)"),
        );
    }
}

impl Transport for Inner {
    fn send(&self, src: NodeId, dst: NodeId, packet: Packet) {
        let bytes = packet.wire_bytes();
        // Reliability is part of the Transport contract: a message too
        // large to frame must fail the run loudly in the sender's thread
        // (where it can be diagnosed and the batch size fixed), never be
        // silently dropped to train on a missing gradient.
        assert!(
            bytes <= wire::MAX_FRAME,
            "message {src:?} -> {dst:?} encodes to {bytes} bytes, over the \
             wire MAX_FRAME ({}); shrink per-clock update/push batches",
            wire::MAX_FRAME
        );
        self.stats.messages.fetch_add(1, Ordering::AcqRel);
        self.stats
            .bytes
            .fetch_add(bytes as u64, Ordering::AcqRel);
        // A sampled frame stamps its enqueue; the writer (or the local
        // fast-path below) turns the stamp into `transport_flush`.
        if let Some((ring, span)) = self.stats.span_of(&packet) {
            let now = SpanRing::now_us();
            ring.record(span, "tcp", "transport_enqueue", now, 0);
            ring.mark(span.trace_id, Mark::Enqueue, now);
        }
        // Same-process peer: deliver straight to the hosted inbox, no
        // socket. This is what carries shard->shard migration handoffs
        // and coordinator control messages inside the in-process TCP
        // fabric (which hosts every shard on one endpoint); a given
        // (src, dst) pair is always local or always remote, so FIFO per
        // link is preserved.
        if let Some(sink) = self.local.get(&dst) {
            // Local delivery is the flush: close the in-transport segment
            // and stamp the inbox arrival for the handler's queue-wait.
            if let Some((ring, span)) = self.stats.span_of(&packet) {
                let now = SpanRing::now_us();
                let start = ring.take_mark(span.trace_id, Mark::Enqueue).unwrap_or(now);
                ring.record(span, "tcp", "transport_flush", start, now.saturating_sub(start));
                match dst {
                    NodeId::Shard(_) => ring.mark(span.trace_id, Mark::ArriveShard, now),
                    NodeId::Worker(_) => ring.mark(span.trace_id, Mark::ArriveWorker, now),
                    NodeId::Coordinator => {}
                }
            }
            match sink.deliver(packet) {
                LocalDelivery::Delivered => {
                    self.stats.delivered.fetch_add(1, Ordering::AcqRel);
                }
                LocalDelivery::HungUp => {
                    self.stats.dropped.fetch_add(1, Ordering::AcqRel);
                    self.note_local_down(dst);
                }
                LocalDelivery::Mismatch => {
                    self.stats.dropped.fetch_add(1, Ordering::AcqRel);
                    eprintln!("transport: local packet for {dst:?} has mismatched direction");
                }
            }
            return;
        }
        let route = self.routes.read().unwrap().get(&(src, dst)).cloned();
        match route {
            Some(Route { q, link }) => match q.try_send((src, dst, packet)) {
                Ok(()) => link.note_enqueued(),
                // Queue full: this send is about to block (the
                // backpressure that keeps a fast producer from buffering
                // unbounded memory behind a slow link). Make the stall
                // visible — count it per endpoint and per link, and at
                // debug trace level name the link — then block.
                Err(TrySendError::Full(frame)) => {
                    self.stats.backpressure.fetch_add(1, Ordering::AcqRel);
                    link.backpressure.fetch_add(1, Ordering::AcqRel);
                    self.trace_debug(
                        "backpressure",
                        format!(
                            "writer queue full ({WRITER_QUEUE} frames) on link {}; \
                             sender blocking",
                            link_name(src, dst)
                        ),
                    );
                    if q.send(frame).is_err() {
                        self.stats.dropped.fetch_add(1, Ordering::AcqRel);
                    } else {
                        link.note_enqueued();
                    }
                }
                // Writer gone mid-send: the link died between the route
                // lookup and the enqueue.
                Err(TrySendError::Disconnected(_)) => {
                    self.stats.dropped.fetch_add(1, Ordering::AcqRel);
                }
            },
            // No route: the peer disconnected (or never existed). Count
            // the drop so flush() still converges.
            None => {
                self.stats.dropped.fetch_add(1, Ordering::AcqRel);
            }
        }
    }
}

/// A TCP transport endpoint (one per process; hosts >= 1 local nodes).
pub struct TcpTransport {
    inner: Arc<Inner>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
}

impl TcpTransport {
    /// Server endpoint: bind `addr` (e.g. `"127.0.0.1:0"`), accept worker
    /// connections, demux inbound `ToShard` traffic into the hosted shard
    /// inboxes. Handshakes from worker ids >= `workers` are rejected —
    /// shard state (MinClock, registration counts) is sized for exactly
    /// that many workers. Returns the transport and the bound address.
    pub fn server(
        addr: &str,
        locals: Vec<(NodeId, LocalSink)>,
        events: Option<Sender<PeerEvent>>,
        workers: usize,
    ) -> Result<(Self, SocketAddr)> {
        Self::server_with_faults(addr, locals, events, workers, None)
    }

    /// [`TcpTransport::server`] with a link-fault injector wired into the
    /// per-connection writers.
    pub fn server_with_faults(
        addr: &str,
        locals: Vec<(NodeId, LocalSink)>,
        events: Option<Sender<PeerEvent>>,
        workers: usize,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<(Self, SocketAddr)> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let inner = Arc::new(Inner {
            routes: RwLock::new(FxHashMap::default()),
            closed: AtomicBool::new(false),
            socks: Mutex::new(Vec::new()),
            local: locals.into_iter().collect(),
            stats: Arc::new(TcpStats::default()),
            events,
            faults,
            links: Mutex::new(Vec::new()),
            local_down: Mutex::new(FxHashSet::default()),
            trace: Mutex::new(None),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let threads = Arc::new(Mutex::new(Vec::new()));
        let (acc_inner, acc_stop, acc_threads) =
            (inner.clone(), stop.clone(), threads.clone());
        let acceptor = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || accept_loop(listener, acc_inner, acc_stop, acc_threads, workers))
            .context("spawning acceptor")?;
        threads.lock().unwrap().push(acceptor);
        Ok((
            TcpTransport {
                inner,
                threads,
                stop,
            },
            bound,
        ))
    }

    /// Client endpoint: dial every (worker, shard, addr) link, with
    /// connect retries until `timeout` (peers may start in any order).
    pub fn client(
        locals: Vec<(NodeId, LocalSink)>,
        conns: &[(usize, usize, SocketAddr)],
        timeout: Duration,
    ) -> Result<Self> {
        Self::client_with_faults(locals, conns, timeout, None)
    }

    /// [`TcpTransport::client`] with a link-fault injector wired into the
    /// per-connection writers.
    pub fn client_with_faults(
        locals: Vec<(NodeId, LocalSink)>,
        conns: &[(usize, usize, SocketAddr)],
        timeout: Duration,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Self> {
        let t = Self::endpoint_with_faults(locals, faults);
        for &(w, s, addr) in conns {
            t.dial(NodeId::Worker(w), NodeId::Shard(s), addr, timeout)
                .with_context(|| format!("worker {w}: connecting to shard {s} at {addr}"))?;
        }
        Ok(t)
    }

    /// A dial-only endpoint with no listener (the client side above, and
    /// shard processes dialing their migration peers).
    pub fn endpoint(locals: Vec<(NodeId, LocalSink)>) -> Self {
        Self::endpoint_with_faults(locals, None)
    }

    /// [`TcpTransport::endpoint`] with a link-fault injector wired into
    /// the per-connection writers.
    pub fn endpoint_with_faults(
        locals: Vec<(NodeId, LocalSink)>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Self {
        Self::endpoint_with_events(locals, None, faults)
    }

    /// [`TcpTransport::endpoint_with_faults`] with a peer-event sink:
    /// the coordinator's dialing endpoint subscribes its failure
    /// detector to the lifecycle of every heartbeat connection it owns
    /// (a dead shard process surfaces as an unclean `Disconnected`).
    pub fn endpoint_with_events(
        locals: Vec<(NodeId, LocalSink)>,
        events: Option<Sender<PeerEvent>>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Self {
        let inner = Arc::new(Inner {
            routes: RwLock::new(FxHashMap::default()),
            closed: AtomicBool::new(false),
            socks: Mutex::new(Vec::new()),
            local: locals.into_iter().collect(),
            stats: Arc::new(TcpStats::default()),
            events,
            faults,
            links: Mutex::new(Vec::new()),
            local_down: Mutex::new(FxHashSet::default()),
            trace: Mutex::new(None),
        });
        TcpTransport {
            inner,
            threads: Arc::new(Mutex::new(Vec::new())),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Dial one (src -> dst) link to a peer endpoint, with connect
    /// retries until `timeout`. Used for every worker->shard link and —
    /// when a migration is armed — for shard->shard handoff links (a
    /// shard dials every higher-indexed peer, so each unordered pair
    /// shares one connection carrying both directions).
    pub fn dial(
        &self,
        src: NodeId,
        dst: NodeId,
        addr: SocketAddr,
        timeout: Duration,
    ) -> Result<()> {
        let mut stream =
            connect_with_retry(addr, dst, timeout, &self.inner.stats.dial_retries)?;
        stream.set_nodelay(true)?;
        // Bound the ack wait: a connect can succeed against something
        // that is not an essptable peer and never answers.
        stream.set_read_timeout(Some(HELLO_TIMEOUT))?;
        wire::write_hello(&mut stream, src, dst)?;
        let (ack_src, ack_dst) = wire::read_hello(&mut stream)
            .with_context(|| format!("handshake ack from {dst:?} at {addr}"))?;
        stream.set_read_timeout(None)?;
        ensure!(
            ack_src == dst && ack_dst == src,
            "peer at {addr} identified as {ack_src:?} -> {ack_dst:?}, expected \
             {dst:?} -> {src:?} (cluster address list mismatch?)"
        );
        register_conn(stream, src, dst, &self.inner, &self.threads)
    }

    /// Cloneable send handle for clients/shards.
    pub fn handle(&self) -> TransportHandle {
        TransportHandle::from_arc(self.inner.clone() as Arc<dyn Transport>)
    }

    pub fn stats(&self) -> Arc<TcpStats> {
        self.inner.stats.clone()
    }

    /// Attach a structured event ring: peer lifecycle transitions
    /// (`peer_up`/`peer_down`) are recorded at normal level, per-link
    /// backpressure stalls at debug level.
    pub fn set_trace(&self, ring: Arc<TraceRing>) {
        *self.inner.trace.lock().unwrap() = Some(ring);
    }

    /// Install the span recorder (wire v9): sampled frames then get
    /// `transport_enqueue`/`transport_flush` segments and arrival marks.
    /// One-shot; a second call is ignored.
    pub fn set_spans(&self, ring: Arc<SpanRing>) {
        let _ = self.inner.stats.spans.set(ring);
    }

    /// Scrape adapter for the admin endpoint: one snapshot for the
    /// endpoint-wide [`TcpStats`] (node `tcp`) plus one per registered
    /// link (node `tcp:w0->s1` style) with frames/bytes/backpressure and
    /// writer-queue depth/high-water mark.
    pub fn metrics_source(&self) -> Arc<TcpMetrics> {
        Arc::new(TcpMetrics {
            inner: self.inner.clone(),
        })
    }

    /// Stop outbound traffic: drop every writer queue. Writers drain what
    /// is queued, flush, and shut down the socket write halves — remote
    /// readers then see clean EOFs. Sends after this count as dropped,
    /// and no new connection may register.
    pub fn close_send(&self) {
        let mut routes = self.inner.routes.write().unwrap();
        self.inner.closed.store(true, Ordering::Release);
        routes.clear();
    }

    /// Join all transport threads. Readers normally exit when the
    /// *remote* write half closes, so on a loopback pair call
    /// `close_send` on both endpoints before joining either; as a
    /// backstop against peers that never close, remaining sockets are
    /// force-shut after a grace period so `join` always terminates.
    pub fn join(self) {
        self.stop.store(true, Ordering::Release);
        // Grace: let orderly EOFs propagate first (covers the common
        // path where both endpoints just called close_send).
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let all_done = self
                .threads
                .lock()
                .unwrap()
                .iter()
                .all(|h| h.is_finished());
            if all_done || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // Force-shutdown anything still alive (stray peers that never
        // close their end): readers then error out and exit.
        for s in self.inner.socks.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles = {
            let mut t = self.threads.lock().unwrap();
            std::mem::take(&mut *t)
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Dial with bounded exponential backoff: waits start at 10 ms, double up
/// to a 500 ms cap, and carry deterministic jitter (0.5x–1.5x, derived
/// from the attempt count and port — no shared rng) so a fleet of workers
/// restarting together doesn't re-dial in lockstep. On exhaustion the
/// error names the peer, the address, the attempt count, and the last
/// OS error.
fn connect_with_retry(
    addr: SocketAddr,
    dst: NodeId,
    timeout: Duration,
    retries: &AtomicU64,
) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(10);
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        let err = match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => e,
        };
        // Every failed attempt counts, whether or not it will be retried:
        // the counter is a liveness signal, not a success predictor.
        retries.fetch_add(1, Ordering::AcqRel);
        let now = Instant::now();
        if now >= deadline {
            return Err(anyhow::Error::from(err).context(format!(
                "peer {dst:?} at {addr} unreachable after {attempts} connect \
                 attempts over {timeout:?}"
            )));
        }
        let mut s = (attempts as u64) ^ ((addr.port() as u64) << 32);
        let jitter = 0.5 + (crate::util::rng::splitmix64(&mut s) % 1024) as f64 / 1024.0;
        let wait = backoff
            .mul_f64(jitter)
            .min(deadline.saturating_duration_since(now));
        std::thread::sleep(wait);
        backoff = (backoff * 2).min(Duration::from_millis(500));
    }
}

fn accept_loop(
    listener: TcpListener,
    inner: Arc<Inner>,
    stop: Arc<AtomicBool>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    workers: usize,
) {
    crate::sim::priority::infrastructure_thread();
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Handshake off-thread: one silent peer must not hold the
                // acceptor's (and thus every concurrent dialer's) 10s
                // handshake budget hostage.
                let (hs_inner, hs_threads) = (inner.clone(), threads.clone());
                let hs = std::thread::Builder::new().name("tcp-hs".into()).spawn(
                    move || {
                        if let Err(e) =
                            setup_server_conn(stream, &hs_inner, &hs_threads, workers)
                        {
                            eprintln!("transport: rejected connection: {e:#}");
                        }
                    },
                );
                match hs {
                    Ok(h) => threads.lock().unwrap().push(h),
                    Err(e) => eprintln!("transport: handshake thread spawn failed: {e}"),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("transport: accept error: {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn setup_server_conn(
    mut stream: TcpStream,
    inner: &Arc<Inner>,
    threads: &Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
) -> Result<()> {
    // The accepted socket must be blocking regardless of the listener.
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    // The handshake runs on the acceptor thread: bound it so an idle
    // connection (port scanner, health check) cannot stall the whole
    // cluster bootstrap behind one silent peer.
    stream.set_read_timeout(Some(HELLO_TIMEOUT))?;
    let (peer, target) = match wire::read_hello_outcome(&mut stream)
        .context("reading peer handshake")?
    {
        wire::HelloOutcome::Peer(src, dst) => (src, dst),
        wire::HelloOutcome::BadVersion(v) => {
            // Loud negotiation: echo the dialer's version plus our
            // supported range before closing, so the mixed-version
            // cluster fails with a diagnosis on BOTH ends.
            let _ = wire::write_version_reject(&mut stream, v);
            anyhow::bail!(
                "peer speaks wire v{v}, this binary supports v{}..v{}; \
                 sent version reject",
                wire::VERSION_MIN,
                wire::VERSION_MAX
            );
        }
    };
    ensure!(
        inner.local.contains_key(&target),
        "handshake targets {target:?}, which is not hosted here"
    );
    // Shard-side state (MinClock, registration counts) is sized for
    // `workers`: an out-of-range id must be refused at the door, not
    // allowed to panic the shard thread later. Shard peers (migration
    // handoff links) and the coordinator (failure-detector heartbeat
    // links: StatsPull in, StatsReport back on the same connection) are
    // accepted as long as they are not impersonating a locally-hosted
    // node.
    ensure!(
        match peer {
            NodeId::Worker(w) => w < workers,
            NodeId::Shard(_) | NodeId::Coordinator => !inner.local.contains_key(&peer),
        },
        "handshake from {peer:?}, expected a worker id below {workers}, a \
         remote shard peer, or the coordinator"
    );
    // Clear the handshake timeout before the reader thread exists: the
    // option lives on the shared socket description, and a reader poll
    // started under it would turn >10s of idle into a spurious error.
    stream.set_read_timeout(None)?;
    // Register first, ack after: a rejected dialer (duplicate link,
    // transport already closed) must see its connection die during the
    // handshake, not a success ack followed by silence.
    register_conn(
        stream.try_clone().context("cloning stream")?,
        target,
        peer,
        inner,
        threads,
    )?;
    wire::write_hello(&mut stream, target, peer)?;
    Ok(())
}

/// Wire one established connection into the transport: a writer thread
/// owning the (local -> peer) route's bounded queue, and a reader thread
/// demuxing inbound frames into local inboxes.
fn register_conn(
    stream: TcpStream,
    local: NodeId,
    peer: NodeId,
    inner: &Arc<Inner>,
    threads: &Mutex<Vec<JoinHandle<()>>>,
) -> Result<()> {
    let (qtx, qrx) = sync_channel::<Frame>(WRITER_QUEUE);
    let link = Arc::new(LinkStats::default());
    {
        // Same lock `close_send` clears under: a dial racing shutdown is
        // either registered-then-cleared or rejected here, never leaked.
        let mut routes = inner.routes.write().unwrap();
        ensure!(
            !inner.closed.load(Ordering::Acquire),
            "transport already closed; rejecting late connection from {peer:?}"
        );
        // One live connection per link: a duplicate dial (e.g. a
        // re-launched worker id) must not displace the existing route or
        // impersonate the peer's lifecycle events.
        ensure!(
            !routes.contains_key(&(local, peer)),
            "duplicate connection for live link {local:?} -> {peer:?}; rejecting"
        );
        routes.insert(
            (local, peer),
            Route {
                q: qtx,
                link: link.clone(),
            },
        );
    }
    inner
        .links
        .lock()
        .unwrap()
        .push(((local, peer), link.clone()));
    if let Ok(clone) = stream.try_clone() {
        inner.socks.lock().unwrap().push(clone);
    }
    if let Some(ev) = &inner.events {
        let _ = ev.send(PeerEvent::Connected(peer));
    }
    inner.trace_event(
        "peer_up",
        format!("link {} registered", link_name(local, peer)),
    );
    let wstream = stream.try_clone().context("cloning stream for writer")?;
    let wstats = inner.stats.clone();
    let wfaults = inner.faults.clone();
    let wlink = link;
    let wh = std::thread::Builder::new()
        .name(format!("tcp-w-{peer:?}"))
        .spawn(move || writer_loop(wstream, qrx, wstats, wfaults, wlink))
        .context("spawning writer")?;
    let rinner = inner.clone();
    let rh = std::thread::Builder::new()
        .name(format!("tcp-r-{peer:?}"))
        .spawn(move || reader_loop(stream, local, peer, rinner))
        .context("spawning reader")?;
    let mut t = threads.lock().unwrap();
    t.push(wh);
    t.push(rh);
    Ok(())
}

/// Push the coalesced batch onto the wire in one `write_all` and reset
/// it for reuse. A dead link swallows the bytes (their frames were
/// already counted when encoded — same semantics as a buffered write
/// whose later flush fails).
fn flush_batch(stream: &mut TcpStream, batch: &mut Vec<u8>, dead: &mut bool) {
    if batch.is_empty() {
        return;
    }
    if !*dead && stream.write_all(batch).is_err() {
        *dead = true;
    }
    batch.clear();
}

fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<Frame>,
    stats: Arc<TcpStats>,
    faults: Option<Arc<FaultInjector>>,
    link: Arc<LinkStats>,
) {
    crate::sim::priority::infrastructure_thread();
    let shutdown_handle = stream.try_clone().ok();
    // One reusable encode buffer for the connection's lifetime: frames
    // drained at a wakeup coalesce here and reach the socket as a single
    // vectored-style write per batch, alloc-free in steady state (the
    // buffer keeps its high-water capacity across wakeups).
    let mut batch: Vec<u8> = Vec::with_capacity(COALESCE);
    // After an io error the peer is gone: swallow (and count) the rest so
    // producers never block on a dead link.
    let mut dead = false;
    loop {
        let first = match rx.recv() {
            Ok(f) => f,
            Err(_) => break, // route dropped (close_send): drain done
        };
        let mut next = Some(first);
        while let Some((src, dst, packet)) = next.take() {
            // Every frame taken off the queue — written, faulted, or
            // swallowed on a dead link — leaves the depth gauge here.
            link.note_dequeued();
            // Link faults apply at the writer: this thread owns the FIFO
            // link, so the per-link packet sequence (and with it every
            // probabilistic verdict) is deterministic.
            if let Some(inj) = &faults {
                let verdict = inj.on_packet(src, dst);
                if verdict.drop {
                    stats.dropped.fetch_add(1, Ordering::AcqRel);
                    next = rx.try_recv().ok();
                    continue;
                }
                if !verdict.delay.is_zero() {
                    // Flush the coalesced batch first, then stall the
                    // link — the delay must postpone this packet, not
                    // the traffic batched ahead of it.
                    flush_batch(&mut stream, &mut batch, &mut dead);
                    std::thread::sleep(verdict.delay);
                }
            }
            if dead {
                stats.dropped.fetch_add(1, Ordering::AcqRel);
            } else {
                match wire::write_frame(&mut batch, src, dst, &packet) {
                    Ok(()) => {
                        link.frames.fetch_add(1, Ordering::AcqRel);
                        link.bytes
                            .fetch_add(packet.wire_bytes() as u64, Ordering::AcqRel);
                        // Sampled frame encoded toward the socket: close
                        // its in-transport segment (enqueue stamp -> now).
                        if let Some((ring, span)) = stats.span_of(&packet) {
                            let now = SpanRing::now_us();
                            let start = ring
                                .take_mark(span.trace_id, Mark::Enqueue)
                                .unwrap_or(now);
                            ring.record(
                                span,
                                "tcp",
                                "transport_flush",
                                start,
                                now.saturating_sub(start),
                            );
                        }
                        // Coalescing boundary: a batch past the limit is
                        // flushed now rather than growing unbounded.
                        if batch.len() >= COALESCE {
                            flush_batch(&mut stream, &mut batch, &mut dead);
                        }
                    }
                    // Oversized frame: normally unreachable — the sender
                    // asserts the MAX_FRAME bound in `Inner::send` before
                    // enqueueing — kept as defense in depth for frames
                    // that reach a writer some other way. `write_frame`
                    // validates the length before emitting a byte, so
                    // the batch is untouched and the link stays healthy.
                    Err(e) if e.kind() == io::ErrorKind::InvalidInput => {
                        eprintln!("transport: dropping oversized frame: {e}");
                        stats.dropped.fetch_add(1, Ordering::AcqRel);
                    }
                    Err(_) => {
                        dead = true;
                        stats.dropped.fetch_add(1, Ordering::AcqRel);
                    }
                }
            }
            next = rx.try_recv().ok();
        }
        // Queue drained: one write pushes the whole coalesced batch.
        flush_batch(&mut stream, &mut batch, &mut dead);
    }
    flush_batch(&mut stream, &mut batch, &mut dead);
    if let Some(s) = shutdown_handle {
        let _ = s.shutdown(Shutdown::Write);
    }
}

fn reader_loop(stream: TcpStream, local: NodeId, peer: NodeId, inner: Arc<Inner>) {
    crate::sim::priority::infrastructure_thread();
    let mut r = BufReader::with_capacity(SOCK_BUF, stream);
    let mut scratch = Vec::new();
    let clean = loop {
        match wire::read_frame(&mut r, &mut scratch) {
            Ok(Some((_src, dst, packet))) => {
                // Sampled frame arriving off the socket: stamp its inbox
                // arrival so the handler can time its queue wait.
                if let Some((ring, span)) = inner.stats.span_of(&packet) {
                    let now = SpanRing::now_us();
                    match dst {
                        NodeId::Shard(_) => ring.mark(span.trace_id, Mark::ArriveShard, now),
                        NodeId::Worker(_) => ring.mark(span.trace_id, Mark::ArriveWorker, now),
                        NodeId::Coordinator => {}
                    }
                }
                match inner.local.get(&dst) {
                    Some(sink) => match sink.deliver(packet) {
                        LocalDelivery::Delivered => {
                            inner.stats.delivered.fetch_add(1, Ordering::AcqRel);
                        }
                        // The hosted node's thread exited (orderly
                        // shutdown or a kill fault): count the drop and
                        // report the peer down exactly once, as the
                        // local fast-path does.
                        LocalDelivery::HungUp => {
                            inner.stats.dropped.fetch_add(1, Ordering::AcqRel);
                            inner.note_local_down(dst);
                        }
                        LocalDelivery::Mismatch => {
                            inner.stats.dropped.fetch_add(1, Ordering::AcqRel);
                            eprintln!(
                                "transport: frame for {dst:?} has mismatched direction"
                            );
                        }
                    },
                    None => {
                        inner.stats.dropped.fetch_add(1, Ordering::AcqRel);
                        eprintln!("transport: frame for {dst:?} mis-routed to this process");
                    }
                }
            }
            Ok(None) => break true, // clean EOF: peer closed its write half
            Err(e) => {
                eprintln!("transport: reader for {peer:?} failed: {e:#}");
                break false;
            }
        }
    };
    // The link is gone: retire the route so later sends count as dropped
    // (waking the writer via queue disconnect), then announce the peer.
    inner.routes.write().unwrap().remove(&(local, peer));
    if let Some(ev) = &inner.events {
        let _ = ev.send(PeerEvent::Disconnected { node: peer, clean });
    }
    inner.trace_event(
        "peer_down",
        format!(
            "link {} closed ({})",
            link_name(local, peer),
            if clean { "clean eof" } else { "error" }
        ),
    );
}

/// Scrape adapter returned by [`TcpTransport::metrics_source`].
pub struct TcpMetrics {
    inner: Arc<Inner>,
}

impl MetricsSource for TcpMetrics {
    fn snapshots(&self) -> Vec<Snapshot> {
        let s = &self.inner.stats;
        let mut out = vec![Snapshot {
            node: "tcp".into(),
            entries: vec![
                ("messages".into(), s.messages()),
                ("bytes".into(), s.bytes()),
                ("delivered".into(), s.delivered()),
                ("dropped".into(), s.dropped()),
                ("backpressure".into(), s.backpressure()),
                ("dial_retries".into(), s.dial_retries()),
            ],
        }];
        for ((src, dst), link) in self.inner.links.lock().unwrap().iter() {
            out.push(Snapshot {
                node: format!("tcp:{}", link_name(*src, *dst)),
                entries: vec![
                    ("frames".into(), link.frames.load(Ordering::Acquire)),
                    ("bytes".into(), link.bytes.load(Ordering::Acquire)),
                    (
                        "backpressure".into(),
                        link.backpressure.load(Ordering::Acquire),
                    ),
                    (
                        "queue_depth".into(),
                        link.queue_depth.load(Ordering::Acquire),
                    ),
                    ("queue_hwm".into(), link.queue_hwm.load(Ordering::Acquire)),
                ],
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// A loopback endpoint pair: one shard hosted server-side, one worker
    /// client-side; returns both transports and the two inboxes.
    fn pair() -> (
        TcpTransport,
        TcpTransport,
        Receiver<ToShard>,
        Receiver<ToWorker>,
    ) {
        let (stx, srx) = channel();
        let (server, addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx))],
            None,
            4,
        )
        .unwrap();
        let (wtx, wrx) = channel();
        let client = TcpTransport::client(
            vec![(NodeId::Worker(0), LocalSink::Worker(wtx))],
            &[(0, 0, addr)],
            Duration::from_secs(5),
        )
        .unwrap();
        (client, server, srx, wrx)
    }

    fn teardown(client: TcpTransport, server: TcpTransport) {
        client.close_send();
        server.close_send();
        client.join();
        server.join();
    }

    #[test]
    fn frames_cross_the_socket_both_ways() {
        let (client, server, srx, wrx) = pair();
        client.handle().send(
            NodeId::Worker(0),
            NodeId::Shard(0),
            Packet::ToShard(ToShard::ClockTick { worker: 0, clock: 5 }),
        );
        match srx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToShard::ClockTick { worker: 0, clock: 5 } => {}
            other => panic!("unexpected {other:?}"),
        }
        server.handle().send(
            NodeId::Shard(0),
            NodeId::Worker(0),
            Packet::ToWorker(ToWorker::Row {
                key: (0, 3),
                data: vec![1.0f32, 2.0].into(),
                vclock: 1,
                fresh: 2,
                span: None,
            }),
        );
        match wrx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToWorker::Row { key, data, .. } => {
                assert_eq!(key, (0, 3));
                assert_eq!(&data[..], &[1.0, 2.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        teardown(client, server);
    }

    #[test]
    fn per_link_delivery_is_fifo() {
        let (client, server, srx, _wrx) = pair();
        for c in 0..200 {
            client.handle().send(
                NodeId::Worker(0),
                NodeId::Shard(0),
                Packet::ToShard(ToShard::ClockTick { worker: 0, clock: c }),
            );
        }
        for expect in 0..200 {
            match srx.recv_timeout(Duration::from_secs(5)).unwrap() {
                ToShard::ClockTick { clock, .. } => assert_eq!(clock, expect),
                other => panic!("unexpected {other:?}"),
            }
        }
        teardown(client, server);
    }

    #[test]
    fn stats_settle_after_delivery() {
        let (client, server, srx, _wrx) = pair();
        let msg = Packet::ToShard(ToShard::Register {
            key: (0, 1),
            worker: 0,
        });
        let bytes = msg.wire_bytes() as u64;
        client
            .handle()
            .send(NodeId::Worker(0), NodeId::Shard(0), msg);
        srx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(client.stats().messages(), 1);
        assert_eq!(client.stats().bytes(), bytes);
        // Delivery lands on the server endpoint; give its counter a beat.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.stats().delivered() < 1 {
            assert!(Instant::now() < deadline, "delivery never counted");
            std::thread::sleep(Duration::from_millis(1));
        }
        teardown(client, server);
    }

    #[test]
    fn send_without_route_counts_dropped() {
        let (client, server, _srx, _wrx) = pair();
        client.handle().send(
            NodeId::Worker(9), // no such link
            NodeId::Shard(0),
            Packet::ToShard(ToShard::Shutdown),
        );
        assert_eq!(client.stats().dropped(), 1);
        teardown(client, server);
    }

    #[test]
    fn exhausted_dial_names_the_peer() {
        let t = TcpTransport::endpoint(vec![]);
        // The discard port: nothing listens there, so every connect is
        // refused and the backoff loop runs to exhaustion.
        let addr: SocketAddr = "127.0.0.1:9".parse().unwrap();
        let err = t
            .dial(
                NodeId::Worker(0),
                NodeId::Shard(3),
                addr,
                Duration::from_millis(200),
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("Shard(3)"), "{msg}");
        assert!(msg.contains("connect attempts"), "{msg}");
        // Every refused connect is a visible retry on the counter.
        assert!(t.stats().dial_retries() > 0);
        t.close_send();
        t.join();
    }

    #[test]
    fn metrics_source_exposes_endpoint_and_link_counters() {
        let (client, server, srx, _wrx) = pair();
        client.handle().send(
            NodeId::Worker(0),
            NodeId::Shard(0),
            Packet::ToShard(ToShard::ClockTick { worker: 0, clock: 1 }),
        );
        srx.recv_timeout(Duration::from_secs(5)).unwrap();
        let snaps = client.metrics_source().snapshots();
        let tcp = snaps.iter().find(|s| s.node == "tcp").unwrap();
        assert_eq!(tcp.get("messages"), Some(1));
        assert!(tcp.get("bytes").unwrap() > 0);
        assert_eq!(tcp.get("backpressure"), Some(0));
        // The receiver saw the frame, so the writer counted it (increment
        // precedes the flush the receive depends on).
        let link = snaps.iter().find(|s| s.node == "tcp:w0->s0").unwrap();
        assert_eq!(link.get("frames"), Some(1));
        assert!(link.get("bytes").unwrap() > 0);
        assert!(link.get("queue_hwm").unwrap() >= 1);
        teardown(client, server);
    }

    #[test]
    fn writer_queue_full_is_counted_and_traced() {
        // A 5ms per-frame link delay stalls the writer; the test-sized
        // writer queue (8 frames) must then overrun, and every overrun
        // be visible: endpoint counter, link counter, debug trace event
        // naming the link. Before this path existed the producer just
        // silently blocked.
        let plan = crate::sim::fault::FaultPlan::parse("delay=w0-s0:5ms").unwrap();
        let (stx, srx) = channel();
        let (server, addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx))],
            None,
            4,
        )
        .unwrap();
        let (wtx, _wrx) = channel();
        let client = TcpTransport::client_with_faults(
            vec![(NodeId::Worker(0), LocalSink::Worker(wtx))],
            &[(0, 0, addr)],
            Duration::from_secs(5),
            Some(Arc::new(FaultInjector::new(plan))),
        )
        .unwrap();
        let ring = Arc::new(TraceRing::with_debug(64, true));
        client.set_trace(ring.clone());
        for c in 0..40 {
            client.handle().send(
                NodeId::Worker(0),
                NodeId::Shard(0),
                Packet::ToShard(ToShard::ClockTick { worker: 0, clock: c }),
            );
        }
        assert!(
            client.stats().backpressure() > 0,
            "40 sends through an 8-deep queue behind a 5ms/frame link \
             never tripped backpressure"
        );
        let events = ring.events();
        assert!(
            events
                .iter()
                .any(|e| e.kind == "backpressure" && e.detail.contains("w0->s0")),
            "no backpressure trace event naming the link: {events:?}"
        );
        let link = client
            .metrics_source()
            .snapshots()
            .into_iter()
            .find(|s| s.node == "tcp:w0->s0")
            .unwrap();
        assert!(link.get("backpressure").unwrap() > 0);
        // All 40 frames still arrive, in order: backpressure slows the
        // producer, it never drops.
        for expect in 0..40 {
            match srx.recv_timeout(Duration::from_secs(10)).unwrap() {
                ToShard::ClockTick { clock, .. } => assert_eq!(clock, expect),
                other => panic!("unexpected {other:?}"),
            }
        }
        teardown(client, server);
    }

    #[test]
    fn fault_drop_over_tcp_counts_dropped_and_settles() {
        let plan = crate::sim::fault::FaultPlan::parse("seed=3;drop=w*-s*:1.0").unwrap();
        let (stx, srx) = channel();
        let (server, addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx))],
            None,
            4,
        )
        .unwrap();
        let (wtx, _wrx) = channel();
        let client = TcpTransport::client_with_faults(
            vec![(NodeId::Worker(0), LocalSink::Worker(wtx))],
            &[(0, 0, addr)],
            Duration::from_secs(5),
            Some(Arc::new(FaultInjector::new(plan))),
        )
        .unwrap();
        for c in 0..5 {
            client.handle().send(
                NodeId::Worker(0),
                NodeId::Shard(0),
                Packet::ToShard(ToShard::ClockTick { worker: 0, clock: c }),
            );
        }
        // Every frame dies at the writer, yet all of them settle — the
        // flush contract survives a fully black-holed link.
        let deadline = Instant::now() + Duration::from_secs(5);
        while client.stats().settled() < 5 {
            assert!(Instant::now() < deadline, "drops never settled");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(srx.try_iter().count(), 0);
        teardown(client, server);
    }

    #[test]
    fn version_mismatch_gets_a_loud_reject_from_the_acceptor() {
        let (stx, _srx) = channel::<ToShard>();
        let (server, addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx))],
            None,
            1,
        )
        .unwrap();
        {
            use std::io::Write as _;
            let mut s = TcpStream::connect(addr).unwrap();
            let mut hello = Vec::new();
            wire::write_hello(&mut hello, NodeId::Worker(0), NodeId::Shard(0)).unwrap();
            hello[8..10].copy_from_slice(&999u16.to_le_bytes());
            s.write_all(&hello).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            // The acceptor answers with the reject blob, which read_hello
            // turns into an error naming both versions and our range.
            let err = wire::read_hello(&mut s).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("rejected by peer"), "{msg}");
            assert!(msg.contains("v999"), "{msg}");
            assert!(
                msg.contains(&format!("v{}..v{}", wire::VERSION_MIN, wire::VERSION_MAX)),
                "{msg}"
            );
        }
        server.close_send();
        server.join();
    }

    #[test]
    fn shard_peers_can_dial_and_exchange_handoff_traffic() {
        // Two "shard processes": shard 1 dials shard 0 and sends a
        // migration end-marker across the real socket.
        let (stx0, srx0) = channel::<ToShard>();
        let (server0, addr0) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx0))],
            None,
            4,
        )
        .unwrap();
        let (stx1, _srx1) = channel::<ToShard>();
        let (server1, _addr1) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(1), LocalSink::Shard(stx1))],
            None,
            4,
        )
        .unwrap();
        server1
            .dial(
                NodeId::Shard(1),
                NodeId::Shard(0),
                addr0,
                Duration::from_secs(5),
            )
            .unwrap();
        server1.handle().send(
            NodeId::Shard(1),
            NodeId::Shard(0),
            Packet::ToShard(ToShard::MigrateCommit { epoch: 7 }),
        );
        match srx0.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToShard::MigrateCommit { epoch: 7 } => {}
            other => panic!("unexpected {other:?}"),
        }
        server0.close_send();
        server1.close_send();
        server0.join();
        server1.join();
    }

    #[test]
    fn local_destination_bypasses_the_socket() {
        // An endpoint hosting both shards delivers shard->shard traffic
        // straight to the inbox (the in-process TCP fabric's handoff
        // path) and counts it settled.
        let (stx0, srx0) = channel::<ToShard>();
        let (stx1, _srx1) = channel::<ToShard>();
        let (server, _addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![
                (NodeId::Shard(0), LocalSink::Shard(stx0)),
                (NodeId::Shard(1), LocalSink::Shard(stx1)),
            ],
            None,
            4,
        )
        .unwrap();
        server.handle().send(
            NodeId::Shard(1),
            NodeId::Shard(0),
            Packet::ToShard(ToShard::MigrateCommit { epoch: 3 }),
        );
        match srx0.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToShard::MigrateCommit { epoch: 3 } => {}
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(server.stats().delivered(), 1);
        assert_eq!(server.stats().messages(), 1);
        server.close_send();
        server.join();
    }

    #[test]
    fn mismatched_magic_is_rejected() {
        let (stx, _srx) = channel::<ToShard>();
        let (server, addr) = TcpTransport::server(
            "127.0.0.1:0",
            vec![(NodeId::Shard(0), LocalSink::Shard(stx))],
            None,
            1,
        )
        .unwrap();
        // Raw garbage instead of a handshake: the server must drop us.
        {
            use std::io::Write as _;
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\n\r\n....").unwrap();
            // Either the read fails or we get EOF; both prove rejection.
            let mut buf = [0u8; 64];
            use std::io::Read as _;
            let _ = s.read(&mut buf);
        }
        server.close_send();
        server.join();
    }
}
