//! `essptable` CLI: the launcher for training runs and for regenerating
//! every paper figure (DESIGN.md §4).
//!
//! Subcommands:
//!   mf | lda | logreg | lm      — run one workload once, print a summary
//!   fig1-staleness              — Fig. 1 (left): staleness distributions
//!   fig1-breakdown              — Fig. 1 (right): comm/comp breakdown
//!   fig2-mf | fig2-lda          — Fig. 2: convergence curves
//!   robustness                  — §Robustness: step-size x staleness grid
//!   vap-compare                 — §VAP: stall cost vs ESSP
//!   artifacts                   — list AOT artifacts and their specs
//!   serve-shard                 — host one PS shard as a TCP server process
//!   run-worker                  — run one worker process against a cluster
//!   run-cluster                 — spawn shards + workers as OS processes
//!   ps-top                      — poll admin scrape endpoints, render tables
//!
//! Common flags: --workers N --shards N --clocks N --seed N
//!   --consistency bsp|ssp:S|essp:S|async[:R]|vap:V0|avap:V0:S
//!   --straggler none|uniform:F|fixed:W,..xF|spikes:P,F|rotating:PxF
//!   --net lan|instant --transport sim|tcp --out results/

use std::collections::HashMap;
use std::net::ToSocketAddrs;
use std::path::{Path, PathBuf};
use std::process::{Command, ExitCode};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context};

use essptable::apps::lda::gibbs::run_lda;
use essptable::apps::lda::LdaConfig;
use essptable::apps::lm::{run_lm, LmTrainConfig};
use essptable::apps::logreg::{run_logreg, LogRegConfig, LogRegData, LogRegWorker, W_TABLE};
use essptable::apps::mf::train::{final_sq_loss, run_mf, MfBackend, MF_ARTIFACT};
use essptable::apps::mf::MfConfig;
use essptable::harness::{self, ExpOpts};
use essptable::metrics::export;
use essptable::ps::checkpoint;
use essptable::ps::client::{ClientConfig, PsClient};
use essptable::ps::consistency::Consistency;
use essptable::ps::durability::{DurabilityConfig, FsyncPolicy};
use essptable::ps::failover::{Detector, FailoverConfig};
use essptable::ps::msg::{ToShard, ToWorker};
use essptable::ps::placement::{plan_shards, PlacementDelta, PlacementMap};
use essptable::ps::server::{self, PsApp, RunReport, TableSpec};
use essptable::ps::shard::Shard;
use essptable::ps::types::{Clock, Key};
use essptable::runtime::artifact::ArtifactDir;
use essptable::runtime::engine::RuntimeService;
use essptable::sim::fault::{FaultInjector, FaultPlan, ShardAction};
use essptable::sim::straggler::StragglerModel;
use essptable::telemetry::admin;
use essptable::telemetry::registry::MetricsSource;
use essptable::telemetry::spans::{merge_chrome_docs, SpanRing};
use essptable::telemetry::trace::TraceRing;
use essptable::transport::tcp::{LocalSink, PeerEvent, TcpTransport};
use essptable::transport::{NodeId, TransportSel};
use essptable::util::cli::Args;
use essptable::util::json::Json;

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("mf") => cmd_mf(&args),
        Some("lda") => cmd_lda(&args),
        Some("logreg") => cmd_logreg(&args),
        Some("lm") => cmd_lm(&args),
        Some("fig1-staleness") => cmd_fig1_staleness(&args),
        Some("fig1-breakdown") => cmd_fig1_breakdown(&args),
        Some("fig2-mf") => cmd_fig2_mf(&args),
        Some("fig2-lda") => cmd_fig2_lda(&args),
        Some("robustness") => cmd_robustness(&args),
        Some("vap-compare") => cmd_vap_compare(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("serve-shard") => cmd_serve_shard(&args),
        Some("run-worker") => cmd_run_worker(&args),
        Some("run-cluster") => cmd_run_cluster(&args),
        Some("ps-top") => cmd_ps_top(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    let unused = args.unused();
    if !unused.is_empty() {
        eprintln!("warning: unused flags: {unused:?}");
    }
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: essptable <subcommand> [flags]
  workloads:    mf | lda | logreg | lm
  experiments:  fig1-staleness | fig1-breakdown | fig2-mf | fig2-lda
                robustness | vap-compare
  inspection:   artifacts
  cluster:      run-cluster --app logreg|counter --workers N --shards N
                  [--cluster host:p,...] [--clocks N] [--consistency C]
                  [--replicas R] [--active A] [--migrate-at C [--grow-to N]]
                  [--wal DIR [--fsync always|commit|off]
                   [--wal-compact-every N]] [--fault-plan SPEC]
                serve-shard --index I --bind ADDR --shards N --workers N
                  [--dump FILE.ckp] [--replicas R] [--active A]
                  [--spares N [--replica-of P]]
                  [--migrate-at C --cluster addr,... [--grow-to N]]
                  [--wal DIR [--fsync P] [--wal-compact-every N]]
                  [--fault-plan SPEC --cluster addr,...]
                run-worker  --index W --cluster host:p,... --workers N
                  [--replicas R] [--spares N] [--active A]
                  [--migrate-at C [--grow-to N]] [--resend-window N]
                  [--fault-plan SPEC] [--stats-pull-every N]
                ps-top --scrape host:p,... [--interval-ms N] [--iters N]
  failover:     run-cluster with kill faults runs the coordinator's
                failure detector in the launcher:
                  [--heartbeat-every MS] [--suspect-after MS] [--missed-k N]
                  [--re-replicate true [--spares N] [--attach-slack CLOCKS]]
                  [--failover-deadline MS] [--resend-window N]
                (kills need --replicas >= 1, or --wal + a spare for
                 WAL-fallback recovery; see ps::failover docs)
  telemetry:    serve-shard/run-worker: [--metrics-addr ADDR]
                  [--trace-out FILE.jsonl [--trace-debug true]]
                  [--trace-spans FILE.json [--span-sample N] [--span-cap N]]
                serve-shard: [--hot-keys K]  (top-K hot-key sketch)
                run-cluster: [--metrics true] [--trace-dir DIR]
                  [--trace-spans FILE.json [--span-sample N]] [--hot-keys K]
                  [--stats-pull-every N]  (admin endpoints serve GET /json
                  and GET /metrics; ps-top polls them; merged Chrome trace
                  written to FILE.json post-run)
  common flags: --workers N --shards N --clocks N --seed N
                --consistency bsp|ssp:S|essp:S|async[:R]|vap:V0|avap:V0:S
                --straggler none|uniform:F|... --net lan|instant
                --transport sim|tcp --replicas R
                --wal DIR --fsync always|commit|off --fault-plan SPEC
                  (SPEC e.g. 'seed=7;kill=s0@5;drop=w*-s*:0.01', see
                   sim::fault docs for the grammar)
                --out DIR  (see README.md for per-command flags)";

fn opts(args: &Args) -> anyhow::Result<ExpOpts> {
    Ok(ExpOpts {
        workers: args.usize("workers", 8),
        shards: args.usize("shards", 4),
        seed: args.u64("seed", 42),
        clocks: args.u64("clocks", 60),
        out_dir: PathBuf::from(args.str("out", "results")),
        straggler: StragglerModel::parse(&args.str("straggler", "uniform:3"))
            .map_err(anyhow::Error::msg)?,
        lan: args.str("net", "lan") == "lan",
        transport: TransportSel::parse(&args.str("transport", "sim"))
            .map_err(anyhow::Error::msg)?,
        virtual_clock_ms: args.u64("virtual-clock-ms", 25),
        replicas: args.usize("replicas", 0),
        failover: failover_config(args),
        spare_nodes: args.usize("spares", 0),
        resend_window: args.u64("resend-window", 0) as Clock,
    })
}

/// Parse the failure-detector flags shared by the in-process harness and
/// `run-cluster`: `--heartbeat-every MS`, `--suspect-after MS`,
/// `--missed-k N`, `--re-replicate true`, `--attach-slack CLOCKS`, and
/// `--failover-deadline MS` (0 = unbounded).
fn failover_config(args: &Args) -> FailoverConfig {
    let d = FailoverConfig::default();
    FailoverConfig {
        heartbeat_every: Duration::from_millis(
            args.u64("heartbeat-every", d.heartbeat_every.as_millis() as u64),
        ),
        suspect_after: Duration::from_millis(
            args.u64("suspect-after", d.suspect_after.as_millis() as u64),
        ),
        missed_k: args.u64("missed-k", d.missed_k as u64) as u32,
        re_replicate: args.bool("re-replicate", false),
        attach_slack: args.u64("attach-slack", d.attach_slack as u64) as Clock,
        deadline: {
            let ms = args.u64("failover-deadline", 0);
            (ms > 0).then(|| Duration::from_millis(ms))
        },
    }
}

/// The statically derived migration delta for the cluster subcommands:
/// every process (launcher, shards, workers) computes the identical delta
/// from the shared flags, then arms itself with it at bootstrap — the
/// same `MigrateBegin`/`Placement` protocol the in-process coordinator
/// drives. Growth defaults to the full provisioned primary set (the
/// "2 -> 4 shards mid-run" shape).
fn migration_delta(args: &Args, at_clock: Clock, shards: usize) -> PlacementDelta {
    let grow_to = args.usize("grow-to", 0);
    let grow_to = if grow_to == 0 { shards } else { grow_to };
    PlacementDelta {
        epoch: 1,
        at_clock,
        grow_active: Some(grow_to as u32),
        promote: None,
        attach: None,
        dead: vec![],
        moves: vec![],
    }
}

/// Parse the optional `--migrate-at` clock.
fn migrate_at(args: &Args) -> anyhow::Result<Option<Clock>> {
    args.opt_str("migrate-at")
        .map(|s| {
            let c: Clock = s.parse().context("--migrate-at")?;
            ensure!(c >= 1, "--migrate-at must be >= 1 (got {c})");
            Ok(c)
        })
        .transpose()
}

fn consistency(args: &Args, default: &str) -> anyhow::Result<Consistency> {
    Consistency::parse(&args.str("consistency", default)).map_err(anyhow::Error::msg)
}

/// Parse the durability flags: `--wal DIR` enables the per-shard
/// write-ahead log + checkpoint generations, `--fsync` picks the sync
/// policy, `--wal-compact-every` the compaction cadence in commits.
fn durability_config(args: &Args) -> anyhow::Result<Option<DurabilityConfig>> {
    let Some(dir) = args.opt_str("wal") else {
        return Ok(None);
    };
    let mut cfg = DurabilityConfig::new(dir);
    cfg.fsync = FsyncPolicy::parse(&args.str("fsync", "commit")).map_err(anyhow::Error::msg)?;
    cfg.compact_every = args.u64("wal-compact-every", 64);
    Ok(Some(cfg))
}

/// Parse `--fault-plan` (absent or empty = no faults).
fn fault_plan(args: &Args) -> anyhow::Result<FaultPlan> {
    FaultPlan::parse(&args.str("fault-plan", "")).map_err(anyhow::Error::msg)
}

/// Per-node telemetry flags shared by `serve-shard` and `run-worker`:
/// `--metrics-addr ADDR` binds the admin scrape socket, `--trace-out
/// FILE.jsonl` collects structured events into a ring dumped at exit,
/// `--trace-debug true` additionally records debug-level events (e.g.
/// per-link backpressure). `--trace-spans FILE.json` turns on causal
/// request tracing (wire v9): one of every `--span-sample` client-issued
/// frames carries a span context, every hop appends a timed segment, and
/// the ring dumps a Chrome trace-event document at exit (`--span-cap`
/// bounds the raw-event ring). All strictly out-of-band: absent flags
/// cost the data plane nothing.
struct Telemetry {
    metrics_addr: Option<String>,
    trace_out: Option<PathBuf>,
    ring: Option<Arc<TraceRing>>,
    trace_spans: Option<PathBuf>,
    spans: Option<Arc<SpanRing>>,
    span_sample: u64,
}

fn telemetry(args: &Args) -> Telemetry {
    let trace_out = args.opt_str("trace-out").map(PathBuf::from);
    let ring = trace_out.as_ref().map(|_| {
        Arc::new(TraceRing::with_debug(
            args.usize("trace-cap", 65536),
            args.bool("trace-debug", false),
        ))
    });
    let trace_spans = args.opt_str("trace-spans").map(PathBuf::from);
    let spans = trace_spans
        .as_ref()
        .map(|_| Arc::new(SpanRing::new(args.usize("span-cap", 65536))));
    Telemetry {
        metrics_addr: args.opt_str("metrics-addr"),
        trace_out,
        ring,
        trace_spans,
        spans,
        span_sample: args.u64("span-sample", 64),
    }
}

impl Telemetry {
    /// Start the admin endpoint if `--metrics-addr` was given. The handle
    /// must stay alive for the process lifetime (drop stops serving).
    fn serve(
        &self,
        sources: Vec<Arc<dyn MetricsSource>>,
    ) -> anyhow::Result<Option<admin::AdminHandle>> {
        let Some(addr) = &self.metrics_addr else {
            return Ok(None);
        };
        let h = admin::serve(addr, sources)
            .with_context(|| format!("binding --metrics-addr {addr}"))?;
        println!("metrics: admin endpoint on {}", h.addr);
        Ok(Some(h))
    }

    /// Dump the event ring to `--trace-out` (call on every exit path that
    /// should preserve the trace, including fault-kill wind-downs).
    fn dump(&self) -> anyhow::Result<()> {
        if let (Some(path), Some(ring)) = (&self.trace_out, &self.ring) {
            ring.dump_jsonl(path)
                .with_context(|| format!("writing trace to {}", path.display()))?;
            println!(
                "trace: {} events ({} dropped) -> {}",
                ring.len(),
                ring.dropped(),
                path.display()
            );
        }
        Ok(())
    }

    /// Dump sampled request spans to `--trace-spans` as a Chrome
    /// trace-event document (one `pid` lane per process; `run-cluster`
    /// merges the per-process parts into one loadable file).
    fn dump_spans(&self, pid: u64) -> anyhow::Result<()> {
        if let (Some(path), Some(ring)) = (&self.trace_spans, &self.spans) {
            let p = path.to_str().context("non-utf8 --trace-spans path")?;
            ring.dump_chrome(p, pid)
                .with_context(|| format!("writing spans to {}", path.display()))?;
            println!(
                "spans: {} segment events -> {}",
                ring.events().len(),
                path.display()
            );
        }
        Ok(())
    }
}

fn mf_config(args: &Args) -> MfConfig {
    MfConfig {
        rows: args.usize("rows", 512),
        cols: args.usize("cols", 512),
        rank: args.usize("rank", 32),
        true_rank: args.usize("true-rank", 8),
        nnz_per_row: args.usize("nnz-per-row", 48),
        noise: args.f32("noise", 0.05),
        gamma: args.f32("gamma", 0.03),
        lambda: args.f32("lambda", 0.05),
        minibatch: args.f64("minibatch", 0.25),
        ..MfConfig::default()
    }
}

fn lda_config(args: &Args) -> LdaConfig {
    LdaConfig {
        vocab: args.usize("vocab", 500),
        topics: args.usize("topics", 10),
        docs: args.usize("docs", 400),
        doc_len: args.usize("doc-len", 64),
        minibatch: args.f64("minibatch", 0.5),
        ..LdaConfig::default()
    }
}

fn print_report(label: &str, report: &RunReport, final_value: f64, value_name: &str) {
    println!("== {label}");
    println!("  wall            {:.2}s", report.wall.as_secs_f64());
    println!("  {value_name:<15} {final_value:.4}");
    println!(
        "  staleness       mean {:+.3} var {:.3} range [{}, {}]",
        report.staleness.mean(),
        report.staleness.variance(),
        report.staleness.min().unwrap_or(0),
        report.staleness.max().unwrap_or(0),
    );
    println!(
        "  comm fraction   {:.1}%   net {} msgs / {:.1} MB",
        100.0 * report.comm_fraction(),
        report.net_messages,
        report.net_bytes as f64 / 1e6
    );
    if let Some((stall, reads)) = report.vap_stall {
        println!(
            "  vap stalls      {:.2}s across {reads} reads",
            stall.as_secs_f64()
        );
    }
    if report.read_latency.count > 0 {
        println!(
            "  read latency    p50 {}us  p99 {}us  p999 {}us  ({} reads)",
            report.read_latency.quantile(0.50) / 1_000,
            report.read_latency.quantile(0.99) / 1_000,
            report.read_latency.quantile(0.999) / 1_000,
            report.read_latency.count,
        );
    }
    if !report.shard_queue_hwm.is_empty() {
        let hwm: Vec<String> = report
            .shard_queue_hwm
            .iter()
            .map(|v| v.to_string())
            .collect();
        println!(
            "  shard queue hwm [{}]   staleness violations {}",
            hwm.join(", "),
            report.staleness_violations
        );
    }
    if report.staleness_lag.count > 0 {
        println!(
            "  staleness lag   p50 {}  p99 {}  max-bucket {} clocks  ({} reads)",
            report.staleness_lag.quantile(0.50),
            report.staleness_lag.quantile(0.99),
            report.staleness_lag.quantile(1.0),
            report.staleness_lag.count,
        );
    }
    if !report.span_segments.is_empty() {
        println!("  span segments   (sampled causal traces)");
        for (seg, h) in &report.span_segments {
            println!(
                "    {seg:<22} p50 {:>8}us  p99 {:>8}us  ({} spans)",
                h.quantile(0.50),
                h.quantile(0.99),
                h.count,
            );
        }
    }
}

fn cmd_mf(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let mf = mf_config(args);
    let backend = if args.bool("xla", false) {
        let rt = RuntimeService::start(ArtifactDir::open(
            args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
        )?)?;
        let handle = rt.handle();
        handle.preload(MF_ARTIFACT)?;
        // Leak the service so the handle stays valid for the whole run.
        std::mem::forget(rt);
        MfBackend::Xla(handle)
    } else {
        MfBackend::Native
    };
    let (report, data) = run_mf(o.cluster(c), mf, o.clocks, backend);
    print_report(&c.label(), &report, final_sq_loss(&report, &data), "sq loss");
    Ok(())
}

fn cmd_lda(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let (report, _) = run_lda(o.cluster(c), lda_config(args), o.clocks);
    let ll = report.convergence.last_value().unwrap_or(f64::NAN);
    print_report(&c.label(), &report, ll, "log-likelihood");
    Ok(())
}

fn cmd_logreg(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let (report, data) = run_logreg(o.cluster(c), LogRegConfig::default(), o.clocks);
    let w = &report.table_rows[&(essptable::apps::logreg::W_TABLE, 0)];
    print_report(&c.label(), &report, data.log_loss(w), "log loss");
    println!("  accuracy        {:.3}", data.accuracy(w));
    Ok(())
}

fn cmd_lm(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:1")?;
    let artifact = args.str("artifact", "lm_step_gpt-tiny");
    let art_dir = ArtifactDir::open(
        args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
    )?;
    let meta = art_dir.meta(&artifact)?.clone();
    let rt = RuntimeService::start(art_dir)?;
    let cfg = LmTrainConfig {
        artifact,
        lr: args.f32("lr", 0.12),
        lr_decay: args.f64("lr-decay", 200.0),
        seed: o.seed,
        branch: args.usize("branch", 4),
    };
    let report = run_lm(o.cluster(c), cfg, &meta, rt.handle(), o.clocks)?;
    let series = report.convergence.mean();
    print_report(
        &c.label(),
        &report,
        series.last().map(|s| s.value).unwrap_or(f64::NAN),
        "final loss",
    );
    export::convergence_csv(&o.out("lm_loss.csv"), &[(c.label(), series.clone())])?;
    println!("  loss curve -> {}", o.out("lm_loss.csv").display());
    if let Some(first) = series.first() {
        println!(
            "  loss {:.4} (clock 0) -> {:.4} (clock {})",
            first.value,
            series.last().unwrap().value,
            series.last().unwrap().clock
        );
    }
    Ok(())
}

fn cmd_fig1_staleness(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let s = args.u64("staleness", 3) as i64;
    let runs = harness::fig1_staleness(&o, mf_config(args), s)?;
    harness::write_staleness_summary(&o.out("fig1_staleness_summary.json"), &runs)?;
    println!("Fig. 1 (left) — staleness distributions (MF, s={s})");
    for run in &runs {
        println!(
            "  {:<8} mean {:+.3}  var {:.3}  range [{}, {}]  (n={})",
            run.label,
            run.report.staleness.mean(),
            run.report.staleness.variance(),
            run.report.staleness.min().unwrap_or(0),
            run.report.staleness.max().unwrap_or(0),
            run.report.staleness.total(),
        );
    }
    println!("csv -> {}", o.out("fig1_staleness.csv").display());
    // Theorem 5 on the measured profiles: the theory's account of why the
    // ESSP profile converges faster (see ps::theory).
    if runs.len() == 2 {
        let params = essptable::ps::theory::BoundParams {
            lipschitz: 1.0,
            f_sq: 1.0,
            eta: 0.1,
            workers: o.workers,
            staleness: s,
            horizon: o.clocks * o.workers as u64,
        };
        println!("\nTheorem 5 on the measured profiles (L=1, F=1, eta=0.1):");
        print!(
            "{}",
            essptable::ps::theory::compare_report(
                &params,
                &runs[0].label,
                &runs[0].report.staleness,
                &runs[1].label,
                &runs[1].report.staleness,
            )
        );
    }
    Ok(())
}

fn cmd_fig1_breakdown(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "0,1,2,4,8"))?;
    let rows = harness::fig1_breakdown(&o, lda_config(args), &staleness)?;
    println!("Fig. 1 (right) — comm/comp breakdown (LDA)");
    println!("  {:<10} {:>9} {:>9} {:>7}", "label", "comp(s)", "comm(s)", "comm%");
    for (label, comp, comm, frac) in &rows {
        println!("  {label:<10} {comp:>9.2} {comm:>9.2} {:>6.1}%", 100.0 * frac);
    }
    println!("csv -> {}", o.out("fig1_breakdown.csv").display());
    Ok(())
}

fn cmd_fig2_mf(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "2,5"))?;
    let runs = harness::fig2_mf(&o, mf_config(args), &staleness)?;
    println!("Fig. 2 (MF) — convergence (final squared loss, lower is better)");
    for run in &runs {
        println!(
            "  {:<8} final {:>12.2}  wall {:>6.2}s",
            run.label,
            run.final_value,
            run.report.wall.as_secs_f64()
        );
    }
    println!("csv -> {}", o.out("fig2_mf.csv").display());
    Ok(())
}

fn cmd_fig2_lda(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "2,5"))?;
    let runs = harness::fig2_lda(&o, lda_config(args), &staleness)?;
    println!("Fig. 2 (LDA) — convergence (final log-likelihood, higher is better)");
    for run in &runs {
        println!(
            "  {:<8} final {:>14.1}  wall {:>6.2}s",
            run.label,
            run.final_value,
            run.report.wall.as_secs_f64()
        );
    }
    println!("csv -> {}", o.out("fig2_lda.csv").display());
    Ok(())
}

fn cmd_robustness(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let gammas: Vec<f32> = parse_list(&args.str("gammas", "0.05,0.1,0.2"))?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "0,2,5,10"))?;
    let rows = harness::robustness(&o, mf_config(args), &gammas, &staleness)?;
    println!("§Robustness — MF final loss across step size x staleness");
    println!("  {:<10} {:>7} {:>14} {:>9}", "label", "gamma", "final_loss", "diverged");
    for r in &rows {
        println!(
            "  {:<10} {:>7} {:>14.2} {:>9}",
            r.label, r.gamma, r.final_loss, r.diverged
        );
    }
    println!("csv -> {}", o.out("robustness.csv").display());
    Ok(())
}

fn cmd_vap_compare(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let v0s: Vec<f32> = parse_list(&args.str("v0s", "0.5,0.1,0.02"))?;
    let s = args.u64("staleness", 3) as i64;
    let rows = harness::vap_compare(&o, mf_config(args), &v0s, s)?;
    println!("§VAP — value-bound enforcement cost vs ESSP");
    println!(
        "  {:<10} {:>8} {:>12} {:>9} {:>13}",
        "label", "wall(s)", "final_loss", "stall(s)", "stalled_reads"
    );
    for r in &rows {
        println!(
            "  {:<10} {:>8.2} {:>12.2} {:>9.2} {:>13}",
            r.label,
            r.wall.as_secs_f64(),
            r.final_loss,
            r.stall.as_secs_f64(),
            r.stalled_reads
        );
    }
    println!("csv -> {}", o.out("vap_compare.csv").display());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = ArtifactDir::open(
        args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
    )?;
    println!("artifacts in {}:", dir.dir().display());
    for name in dir.names() {
        let m = dir.meta(name)?;
        println!(
            "  {name}: {} inputs, {} outputs{}",
            m.inputs.len(),
            m.outputs.len(),
            m.lm_config
                .as_ref()
                .map(|c| format!(
                    " (LM {}: {} params, vocab {}, seq {})",
                    c.preset, c.param_count, c.vocab, c.seq
                ))
                .unwrap_or_default()
        );
    }
    Ok(())
}

// ------------------------------------------------------- cluster processes
//
// `run-cluster` spawns one OS process per shard (`serve-shard`) and per
// worker (`run-worker`), talking loopback/LAN TCP through
// `transport::tcp` — the paper's actual deployment shape (one ESSPTable
// server process per machine). Every process derives identical initial
// state from the same flags/seed via `server::init_rows`.

/// An application runnable as real OS processes. Table specs and worker
/// construction must be pure functions of the flags, identical in every
/// process.
struct DistApp {
    tables: Vec<TableSpec>,
    make: Box<dyn Fn(usize, usize) -> Box<dyn PsApp>>,
}

fn dist_app(args: &Args) -> anyhow::Result<DistApp> {
    match args.str("app", "logreg").as_str() {
        "logreg" => {
            let cfg = LogRegConfig {
                lr: args.f32("lr", 0.1),
                seed: args.u64("data-seed", 21),
                ..LogRegConfig::default()
            };
            let dim = cfg.dim;
            let data = Arc::new(LogRegData::generate(&cfg));
            Ok(DistApp {
                tables: vec![TableSpec::zeros(W_TABLE, 1, dim + 1)],
                make: Box::new(move |w, workers| {
                    Box::new(LogRegWorker::new(data.clone(), w, workers))
                }),
            })
        }
        "counter" => Ok(DistApp {
            tables: vec![TableSpec::zeros(0, 4, 1)],
            make: Box::new(|_, _| {
                Box::new(|ps: &mut PsClient, _c: Clock| {
                    let _ = ps.get((0, 0));
                    ps.inc((0, 0), &[1.0]);
                    None
                }) as Box<dyn PsApp>
            }),
        }),
        other => bail!("unknown --app {other:?} (expected logreg|counter)"),
    }
}

/// Default for the cluster subcommands' `--deterministic` flag.
///
/// Deterministic staged replay works for every model — value-bounded
/// policies fire their eager (preview) waves at update receipt, so
/// visibility never depends on the deferred commit — and is on by default
/// so multi-process runs are bit-reproducible. Async is the exception:
/// staging defers *all* read freshness to table-clock commits, the
/// opposite of the Hogwild dynamics the Async baseline exists to measure,
/// so it defaults off there. An explicit `--deterministic true|false`
/// always wins (the transport-matrix test opts Async in deliberately).
fn deterministic_default(c: Consistency) -> bool {
    !matches!(c, Consistency::Async { .. })
}

fn cmd_serve_shard(args: &Args) -> anyhow::Result<()> {
    let index = args.usize("index", 0);
    let shards = args.usize("shards", 2);
    let workers = args.usize("workers", 4);
    let replicas = args.usize("replicas", 0);
    let spares = args.usize("spares", 0);
    let active = args.usize("active", 0);
    let migrate = migrate_at(args)?;
    let bind = args.str("bind", "127.0.0.1:0");
    let consistency = consistency(args, "bsp")?;
    let deterministic = args.bool("deterministic", deterministic_default(consistency));
    let seed = args.u64("seed", 42);
    let dump = args.opt_str("dump");
    let active = if active == 0 { shards } else { active };
    let placement = PlacementMap::new(shards, active, replicas);
    let total = placement.total_shards();
    let total_nodes = total + spares;
    ensure!(
        index < total_nodes,
        "--index {index} out of range for {total_nodes} shard nodes \
         ({shards} primaries x (1 + {replicas} replicas) + {spares} spares)"
    );
    // Spare nodes (ids past the provisioned set) start empty and idle;
    // the coordinator's detector grafts state onto them at failover or
    // re-replication time. `--replica-of` additionally names the primary
    // this spare was provisioned to replace (informational — the binding
    // itself arrives in the coordinator's attach/promote delta).
    let is_spare = index >= total;
    let replica_of = args.opt_str("replica-of");
    if replica_of.is_some() {
        ensure!(
            is_spare,
            "--replica-of marks a spare node: --index must be >= {total}"
        );
    }
    let durability = durability_config(args)?;
    let plan = fault_plan(args)?;
    for f in &plan.shards {
        ensure!(
            f.shard < total_nodes,
            "fault plan targets shard {} but only {total_nodes} shard nodes are configured",
            f.shard
        );
    }
    let my_kill = plan
        .shards
        .iter()
        .find(|f| f.shard == index && f.action == ShardAction::Kill)
        .copied();
    if my_kill.is_some() {
        ensure!(
            replicas >= 1 || (durability.is_some() && spares >= 1),
            "kill faults need --replicas >= 1 (live replica promotion) or \
             --wal plus --spares >= 1 (WAL-fallback rebuild on a spare)"
        );
        ensure!(
            migrate.is_none(),
            "kill faults cannot combine with a migration: both planes advance \
             the placement epoch and their fences are not ordered against each other"
        );
        ensure!(index < shards, "kill targets must be primaries, got shard {index}");
    }
    let app = dist_app(args)?;
    let row_len = server::table_row_lens(&app.tables);

    let (shard_tx, shard_rx) = channel::<ToShard>();
    // Self-arm a scheduled migration FIRST, so MigrateBegin leads the
    // inbox before any worker traffic — the same message the in-process
    // coordinator sends, derived identically in every process.
    if let Some(at_clock) = migrate {
        let delta = migration_delta(args, at_clock, shards);
        let keys = app
            .tables
            .iter()
            .flat_map(|t| (0..t.rows).map(move |r| (t.table, r)));
        let mut plans = plan_shards(&placement, &delta, keys);
        let plan = std::mem::take(&mut plans[index]);
        let _ = shard_tx.send(ToShard::MigrateBegin {
            epoch: delta.epoch,
            at_clock: delta.at_clock,
            outgoing: plan.outgoing,
            incoming: plan.incoming,
        });
    }
    let (events_tx, events_rx) = channel::<PeerEvent>();
    // Each process evaluates the same seeded plan, and writer threads see
    // each link's packets in FIFO order — so probabilistic verdicts are
    // identical across runs, process boundaries notwithstanding.
    let injector = plan
        .has_link_faults()
        .then(|| Arc::new(FaultInjector::new(plan.clone())));
    let (transport, addr) = TcpTransport::server_with_faults(
        &bind,
        vec![(NodeId::Shard(index), LocalSink::Shard(shard_tx.clone()))],
        Some(events_tx),
        workers,
        injector.clone(),
    )?;
    let telem = telemetry(args);
    if let Some(ring) = &telem.ring {
        transport.set_trace(ring.clone());
    }
    if let Some(ring) = &telem.spans {
        transport.set_spans(ring.clone());
    }
    let role = if is_spare {
        match &replica_of {
            Some(p) => format!("spare, re-replication target for shard {p}"),
            None => "spare".to_string(),
        }
    } else if placement.is_replica(index) {
        format!("replica of shard {}", placement.primary_of(index))
    } else {
        "primary".to_string()
    };
    println!(
        "shard {index}/{total_nodes} ({role}) listening on {addr} ({workers} workers expected, {})",
        consistency.label()
    );
    // Shard->shard links. Migration handoffs dial every higher-indexed
    // peer (one connection per unordered pair, carrying both directions).
    // When spare nodes are provisioned, every serving candidate also
    // dials each spare up front, so a re-replication row cut has a live
    // link the moment the coordinator arms it (this transport does not
    // dial mid-run; workers likewise dial spares at launch).
    let mut peers: Vec<usize> = if migrate.is_some() {
        (index + 1..total).collect()
    } else {
        Vec::new()
    };
    if !is_spare {
        peers.extend(total..total_nodes);
    }
    if !peers.is_empty() {
        let cluster_addrs = args.strs("cluster");
        ensure!(
            cluster_addrs.len() == total_nodes,
            "serve-shard with --migrate-at or spare nodes needs --cluster \
             listing all {total_nodes} shard addresses (got {})",
            cluster_addrs.len()
        );
        let timeout = Duration::from_secs(args.u64("connect-timeout-s", 30));
        for j in peers {
            let a = &cluster_addrs[j];
            let sa = a
                .to_socket_addrs()
                .with_context(|| format!("resolving peer shard {j} address {a:?}"))?
                .next()
                .with_context(|| format!("peer shard {j} address {a:?} resolved to nothing"))?;
            transport
                .dial(NodeId::Shard(index), NodeId::Shard(j), sa, timeout)
                .with_context(|| format!("dialing peer shard {j}"))?;
        }
    }

    let mut shard = if is_spare || placement.is_replica(index) {
        Shard::replica(
            index,
            workers,
            consistency,
            transport.handle(),
            row_len,
            deterministic,
        )
    } else {
        Shard::new(
            index,
            workers,
            consistency,
            transport.handle(),
            row_len,
            deterministic,
        )
    };
    // Spares start with no rows: their state arrives via a WAL rebuild
    // (from-disk catch-up) or a re-replication row cut.
    if !is_spare {
        let my_primary = placement.primary_of(index);
        server::init_rows(&app.tables, seed, |key, data| {
            if placement.shard_of(&key) == my_primary {
                shard.init_row(key, data);
            }
        });
    }
    // Profiling hooks. Hot-key sketches resize through `Arc::get_mut`,
    // so they must be installed before the metrics handle is ever
    // shared (durability, admin sources); spans ride along here.
    let hot_keys = args.usize("hot-keys", 0);
    if hot_keys > 0 {
        shard.set_hot_key_k(hot_keys);
    }
    if let Some(ring) = &telem.spans {
        shard.set_spans(ring.clone(), telem.span_sample);
    }
    if let Some(dur) = &durability {
        // On-disk paths embed the shard id, so every node of a local
        // cluster may share one --wal directory without collisions.
        let recovered = shard.enable_durability(dur.clone())?;
        if recovered {
            eprintln!("shard {index}: recovered durable state from {:?}", dur.dir);
        }
    }
    let scheduled = plan.shard_faults(index);
    if !scheduled.is_empty() {
        shard.set_faults(scheduled);
    }
    shard.set_fsync_stall(plan.fsync_stall);
    if let Some(ring) = &telem.ring {
        shard.set_trace(ring.clone());
    }
    // Admin scrape sources: this shard's registry, the transport's
    // endpoint + per-link counters, and (when faulted) the injector's
    // verdict tallies. Grabbed before `spawn` moves the shard; the Arcs
    // stay valid for the process lifetime.
    let mut sources: Vec<Arc<dyn MetricsSource>> = Vec::new();
    sources.push(shard.metrics());
    sources.push(transport.metrics_source());
    if let Some(inj) = &injector {
        sources.push(inj.clone());
    }
    if let Some(ring) = &telem.spans {
        sources.push(ring.clone());
    }
    let _admin = telem.serve(sources)?;
    let (dump_tx, dump_rx) = channel();
    let handle = essptable::ps::shard::spawn(shard, shard_rx, dump_tx);

    // Lifecycle: each worker dials exactly once; when every expected
    // worker id has cleanly disconnected, its FIFO traffic has been fully
    // delivered (the reader drains the socket before seeing EOF), so the
    // shard's final state is complete. Identity is tracked per worker id:
    // stray peers (out-of-range ids, duplicate dials from a re-launched
    // worker) are warned about but never fill another worker's quota.
    let expected = |node: &NodeId| matches!(node, NodeId::Worker(w) if *w < workers);
    let mut done: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
    // Idle bound: if no lifecycle event arrives for this long (e.g. a
    // worker process died before ever dialing), fail instead of hanging
    // run-cluster (and CI) forever.
    let idle = Duration::from_secs(args.u64("worker-timeout-s", 300));
    while done.len() < workers {
        match events_rx.recv_timeout(idle) {
            Ok(PeerEvent::Connected(node)) => {
                if expected(&node) {
                    eprintln!("shard {index}: {node:?} connected");
                } else {
                    eprintln!("shard {index}: ignoring unexpected peer {node:?}");
                }
            }
            Ok(PeerEvent::Disconnected { node, clean: true }) => {
                if expected(&node) && done.insert(node) {
                    eprintln!("shard {index}: {node:?} done ({}/{workers})", done.len());
                } else {
                    eprintln!("shard {index}: ignoring disconnect of {node:?}");
                }
            }
            Ok(PeerEvent::Disconnected { node, clean: false }) => {
                // A real worker's errored link may have lost updates:
                // refuse to dump partial state as if the run succeeded.
                // Stray or already-finished peers just get logged.
                if expected(&node) && !done.contains(&node) {
                    bail!(
                        "shard {index}: connection to {node:?} failed mid-run; \
                         aborting instead of dumping partial state"
                    );
                }
                eprintln!("shard {index}: ignoring failed stray connection {node:?}");
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => bail!(
                "shard {index}: no worker activity for {idle:?} with {}/{workers} \
                 workers finished — did a worker process die before connecting?",
                done.len()
            ),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                bail!("shard {index}: transport event stream ended early")
            }
        }
    }
    if my_kill.is_some() {
        // The shard thread died at its kill clock with no dying act: the
        // coordinator's failure detector notices the silence (or the dead
        // inbox) and promotes a replacement, so there is no final state
        // to dump here — run-cluster re-targets --dump at the promoted
        // node, and this process just winds down with its workers.
        let _ = handle.join();
        println!("shard {index}: killed by fault plan (coordinator-driven failover)");
        transport.close_send();
        transport.join();
        // The kill is exactly what the trace exists to document.
        telem.dump()?;
        telem.dump_spans(index as u64)?;
        return Ok(());
    }
    let _ = shard_tx.send(ToShard::Shutdown);
    let fin = dump_rx
        .recv()
        .map_err(|_| anyhow::anyhow!("shard {index}: shard thread died without dumping"))?;
    let _ = handle.join();
    if let Some(path) = dump {
        let rows: HashMap<Key, Vec<f32>> = fin
            .rows
            .iter()
            .map(|(k, row)| (*k, row.data.to_vec()))
            .collect();
        checkpoint::save(Path::new(&path), &rows)?;
        println!("shard {index}: {} rows -> {path}", rows.len());
    }
    transport.close_send();
    transport.join();
    telem.dump()?;
    telem.dump_spans(index as u64)?;
    Ok(())
}

fn cmd_run_worker(args: &Args) -> anyhow::Result<()> {
    let index = args.usize("index", 0);
    let workers = args.usize("workers", 4);
    let clocks = args.u64("clocks", 20);
    let replicas = args.usize("replicas", 0);
    let active = args.usize("active", 0);
    let migrate = migrate_at(args)?;
    let consistency = consistency(args, "bsp")?;
    let spares = args.usize("spares", 0);
    let shard_addrs = args.strs("cluster");
    ensure!(
        !shard_addrs.is_empty(),
        "run-worker needs --cluster host:port[,host:port...] (one address per shard node)"
    );
    let total = shard_addrs.len();
    ensure!(
        total > spares,
        "--spares {spares} leaves no serving shard nodes in the {total} --cluster addresses"
    );
    // Trailing addresses are idle spares: dialed at launch like any other
    // node (so coordinator-driven failover can repoint here mid-run), but
    // outside the placement geometry until an attach/promote delta lands.
    let serving = total - spares;
    ensure!(
        serving % (1 + replicas) == 0,
        "--cluster lists {serving} non-spare addresses, not divisible by 1 + --replicas {replicas}"
    );
    let shards = serving / (1 + replicas);
    let active = if active == 0 { shards } else { active };
    let placement = PlacementMap::new(shards, active, replicas);
    ensure!(index < workers, "--index {index} out of range for --workers {workers}");
    let app = dist_app(args)?;
    let row_len = server::table_row_lens(&app.tables);

    let mut conns = Vec::new();
    for (s, a) in shard_addrs.iter().enumerate() {
        let sa = a
            .to_socket_addrs()
            .with_context(|| format!("resolving shard {s} address {a:?}"))?
            .next()
            .with_context(|| format!("shard {s} address {a:?} resolved to nothing"))?;
        conns.push((index, s, sa));
    }
    let (worker_tx, worker_rx) = channel();
    // Self-arm a scheduled migration before anything else reaches the
    // inbox: the identical Placement delta every process derives.
    if let Some(at_clock) = migrate {
        let _ = worker_tx.send(ToWorker::Placement {
            delta: migration_delta(args, at_clock, shards),
        });
    }
    let timeout = Duration::from_secs(args.u64("connect-timeout-s", 30));
    // Same seeded plan as every other process: this worker's outbound
    // links get their deterministic share of the injected faults.
    let plan = fault_plan(args)?;
    let injector = plan
        .has_link_faults()
        .then(|| Arc::new(FaultInjector::new(plan.clone())));
    let transport = TcpTransport::client_with_faults(
        vec![(NodeId::Worker(index), LocalSink::Worker(worker_tx))],
        &conns,
        timeout,
        injector.clone(),
    )?;
    println!(
        "worker {index}/{workers}: connected to {total} shard node(s), {} clocks of {}",
        clocks,
        consistency.label()
    );

    let telem = telemetry(args);
    if let Some(ring) = &telem.ring {
        transport.set_trace(ring.clone());
    }
    if let Some(ring) = &telem.spans {
        transport.set_spans(ring.clone());
    }
    let client_cfg = ClientConfig {
        consistency,
        cache_capacity: 0,
        read_my_writes: true,
        virtual_clock: None,
        stats_pull_every: args.u64("stats-pull-every", 0) as Clock,
        resend_window: args.u64("resend-window", 0) as Clock,
        span_sample: if telem.spans.is_some() { telem.span_sample } else { 0 },
    };
    let mut ps = PsClient::new(
        index,
        client_cfg,
        placement,
        transport.handle(),
        worker_rx,
        row_len,
        Instant::now(),
    );
    if let Some(ring) = &telem.ring {
        ps.set_trace(ring.clone());
    }
    if let Some(ring) = &telem.spans {
        ps.set_spans(ring.clone());
    }
    // Admin scrape sources: this worker's registry, its wire-shipped
    // mirror of shard stats (populated by StatsReport replies when
    // --stats-pull-every > 0), the transport, and any fault injector.
    let mut sources: Vec<Arc<dyn MetricsSource>> = Vec::new();
    sources.push(ps.metrics());
    sources.push(ps.shard_reports());
    sources.push(transport.metrics_source());
    if let Some(inj) = &injector {
        sources.push(inj.clone());
    }
    if let Some(ring) = &telem.spans {
        sources.push(ring.clone());
    }
    let _admin = telem.serve(sources)?;
    let mut worker = (app.make)(index, workers);
    let mut last_metric = None;
    for c in 0..clocks as Clock {
        if let Some(v) = worker.run_clock(&mut ps, c) {
            last_metric = Some(v);
        }
        ps.tick();
    }
    // Value-bounded models: tell every shard this worker is done, so the
    // cluster never waits on acks that will not come.
    ps.finish();
    println!(
        "worker {index}: done ({} pulls, {} pushes in{})",
        ps.stats.pulls,
        ps.stats.pushes_received,
        last_metric
            .map(|v| format!(", final local metric {v:.4}"))
            .unwrap_or_default()
    );
    let lat = ps.metrics().read_latency_ns.snapshot();
    if lat.count > 0 {
        println!(
            "worker {index}: read latency p50 {}us p99 {}us p999 {}us ({} reads)",
            lat.quantile(0.50) / 1_000,
            lat.quantile(0.99) / 1_000,
            lat.quantile(0.999) / 1_000,
            lat.count,
        );
    }
    transport.close_send();
    transport.join();
    telem.dump()?;
    // Worker pid lanes sit past every plausible shard index, so a
    // single-process Chrome trace load still reads unambiguously.
    telem.dump_spans(1000 + index as u64)?;
    Ok(())
}

/// Pick `n` distinct free localhost ports (bind-then-release; the small
/// race window is fine for a local launcher).
fn pick_local_ports(n: usize) -> anyhow::Result<Vec<String>> {
    let mut held = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let l = std::net::TcpListener::bind("127.0.0.1:0")?;
        addrs.push(format!("127.0.0.1:{}", l.local_addr()?.port()));
        held.push(l); // hold all simultaneously so the ports are distinct
    }
    Ok(addrs)
}

/// Order-stable digest of final parameters (sorted keys, f32 bit
/// patterns) for quick cross-run comparison.
fn params_digest(rows: &HashMap<Key, Vec<f32>>) -> u64 {
    use essptable::util::rng::splitmix64;
    let mut keys: Vec<&Key> = rows.keys().collect();
    keys.sort();
    let mut h: u64 = 0x243F_6A88_85A3_08D3;
    for k in keys {
        let mut s = h ^ (((k.0 as u64) << 32) ^ k.1);
        h = splitmix64(&mut s);
        for x in &rows[k] {
            let mut s = h ^ x.to_bits() as u64;
            h = splitmix64(&mut s);
        }
    }
    h
}

fn cmd_run_cluster(args: &Args) -> anyhow::Result<()> {
    let workers = args.usize("workers", 4);
    let shards = args.usize("shards", 2);
    let clocks = args.u64("clocks", 20);
    let replicas = args.usize("replicas", 0);
    let active = args.usize("active", 0);
    let migrate = migrate_at(args)?;
    let grow_to = if migrate.is_some() {
        Some(args.usize("grow-to", 0))
    } else {
        None
    };
    let total = shards * (1 + replicas);
    // Fault plan: validated HERE for the same reason as the migration
    // geometry below — one actionable error beats N panicking children.
    let fault_spec = args.str("fault-plan", "");
    let plan = FaultPlan::parse(&fault_spec).map_err(anyhow::Error::msg)?;
    let killed = plan.killed_shards();
    // Failure-detector tuning + spare provisioning. `--re-replicate true`
    // with no explicit `--spares` provisions one spare per planned kill.
    let failover = failover_config(args);
    let spares = {
        let s = args.usize("spares", 0);
        if s == 0 && failover.re_replicate {
            killed.len()
        } else {
            s
        }
    };
    let total_nodes = total + spares;
    for f in &plan.shards {
        ensure!(
            f.shard < total_nodes,
            "fault plan targets shard {} but only {total_nodes} shard nodes are configured",
            f.shard
        );
    }
    if !killed.is_empty() {
        ensure!(
            replicas >= 1 || (args.opt_str("wal").is_some() && spares >= 1),
            "kill faults need --replicas >= 1 (live replica promotion) or --wal \
             plus a spare node (--spares N / --re-replicate true) for \
             WAL-fallback recovery"
        );
        if replicas == 0 {
            ensure!(
                killed.len() == 1,
                "WAL-fallback recovery re-targets the dead primary's dump onto \
                 the promoted spare; with --replicas 0 only one kill per run \
                 is supported"
            );
        }
        ensure!(
            migrate.is_none(),
            "kill faults cannot combine with --migrate-at: both planes advance \
             the placement epoch and their fences are not ordered against each other"
        );
        for &k in &killed {
            ensure!(k < shards, "kill targets must be primaries, got shard {k}");
        }
    }
    // Validate the migration geometry HERE, before N processes spawn:
    // every child derives the same delta and would otherwise hit the
    // PlacementMap asserts mid-run, leaving the operator with a pile of
    // panicking processes instead of one actionable error.
    if migrate.is_some() {
        let active_eff = if active == 0 { shards } else { active };
        let grow_eff = match grow_to {
            Some(g) if g > 0 => g,
            _ => shards,
        };
        ensure!(
            active_eff <= shards,
            "--active {active_eff} exceeds --shards {shards}"
        );
        ensure!(
            grow_eff >= active_eff && grow_eff <= shards,
            "--grow-to {grow_eff} out of range {active_eff}..={shards}"
        );
        ensure!(
            grow_eff % active_eff == 0,
            "--grow-to {grow_eff} must be a multiple of the initial active \
             count {active_eff} (modular re-homing is only conservative for \
             divisible growth)"
        );
    }
    let consistency = consistency(args, "bsp")?;
    // A multi-process cluster *is* the tcp transport; accept the common
    // flag for symmetry with the in-process commands.
    let transport = args.str("transport", "tcp");
    ensure!(
        transport == "tcp",
        "run-cluster always runs over tcp (got --transport {transport:?})"
    );
    let seed = args.u64("seed", 42);
    let app_name = args.str("app", "logreg");
    let lr = args.f32("lr", 0.1);
    let data_seed = args.u64("data-seed", 21);
    let deterministic = args.bool("deterministic", deterministic_default(consistency));
    let out = PathBuf::from(args.str("out", "results/cluster"));
    std::fs::create_dir_all(&out).with_context(|| format!("creating {out:?}"))?;

    let addrs = {
        let given = args.strs("cluster");
        if given.is_empty() {
            pick_local_ports(total_nodes)?
        } else {
            ensure!(
                given.len() == total_nodes,
                "--cluster lists {} addresses but {total_nodes} shard nodes are \
                 configured ({shards} primaries x (1 + {replicas} replicas) + \
                 {spares} spares)",
                given.len()
            );
            given
        }
    };

    // Telemetry plumbing. `--metrics true` gives every child process its
    // own admin scrape socket; the launcher picks the ports and prints
    // the full map BEFORE spawning, so an operator (or test) can scrape
    // any node mid-run. `--trace-dir DIR` hands each child a private
    // `--trace-out` JSONL file inside DIR. `--stats-pull-every N` makes
    // workers poll shard registries over the wire (StatsPull/StatsReport)
    // every N clocks — it defaults on with metrics so worker endpoints
    // also expose live per-shard state.
    let metrics = args.bool("metrics", false);
    let trace_dir = args.opt_str("trace-dir").map(PathBuf::from);
    if let Some(d) = &trace_dir {
        std::fs::create_dir_all(d).with_context(|| format!("creating {d:?}"))?;
    }
    let trace_debug = args.bool("trace-debug", false);
    let stats_pull_every = args.u64("stats-pull-every", if metrics { 4 } else { 0 });
    // Causal request tracing: `--trace-spans FILE.json` makes every child
    // process collect sampled spans (`--span-sample N`, forwarded so the
    // whole cluster samples identically) into a per-process part file
    // under --out; the launcher merges the parts into FILE post-run, so
    // one document shows request spans crossing process boundaries.
    // `--hot-keys K` arms each shard's top-K space-saving key sketch.
    let trace_spans = args.opt_str("trace-spans").map(PathBuf::from);
    let span_sample = args.u64("span-sample", 64);
    let hot_keys = args.usize("hot-keys", 0);
    let mut span_parts: Vec<(String, PathBuf)> = Vec::new();
    let metrics_addrs = if metrics {
        let picked = pick_local_ports(total_nodes + workers)?;
        for (i, a) in picked.iter().take(total_nodes).enumerate() {
            println!("metrics: shard {i} -> {a}");
        }
        for (w, a) in picked.iter().skip(total_nodes).enumerate() {
            println!("metrics: worker {w} -> {a}");
        }
        picked
    } else {
        Vec::new()
    };
    let trace_file = |d: &PathBuf, name: String| -> anyhow::Result<String> {
        Ok(d.join(name)
            .to_str()
            .context("non-utf8 trace path")?
            .to_string())
    };

    let exe = std::env::current_exe().context("locating own binary")?;
    // On any spawn failure, kill what was already launched: dropped Child
    // handles do NOT terminate the processes, and shards wait on their
    // workers forever.
    fn kill_all(children: &mut Vec<(&str, usize, std::process::Child)>) {
        for (_, _, child) in children.iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    let mut children: Vec<(&str, usize, std::process::Child)> = Vec::new();
    // Per-app flags: only logreg reads these — forwarding them to the
    // counter app would trip every child's unused-flag warning.
    let app_flags: Vec<String> = if app_name == "logreg" {
        vec![
            "--lr".into(),
            lr.to_string(),
            "--data-seed".into(),
            data_seed.to_string(),
        ]
    } else {
        Vec::new()
    };
    let cluster_list = addrs.join(",");
    // Durability flags forwarded verbatim to every shard process (paths
    // embed the shard id, so one shared directory is safe).
    let mut dur_flags: Vec<String> = Vec::new();
    if let Some(dir) = args.opt_str("wal") {
        dur_flags.extend(["--wal".into(), dir]);
        dur_flags.extend(["--fsync".into(), args.str("fsync", "commit")]);
        dur_flags.extend([
            "--wal-compact-every".into(),
            args.u64("wal-compact-every", 64).to_string(),
        ]);
    }
    // Migration flags shared verbatim by every process, so all derive the
    // identical placement delta.
    let mut mig_flags: Vec<String> = Vec::new();
    if let Some(at) = migrate {
        mig_flags.extend(["--migrate-at".into(), at.to_string()]);
        if let Some(g) = grow_to {
            if g > 0 {
                mig_flags.extend(["--grow-to".into(), g.to_string()]);
            }
        }
    }
    let mut dumps = Vec::new();
    for i in 0..total_nodes {
        let mut sargs: Vec<String> = vec![
            "serve-shard".into(),
            "--index".into(),
            i.to_string(),
            "--shards".into(),
            shards.to_string(),
            "--workers".into(),
            workers.to_string(),
            "--replicas".into(),
            replicas.to_string(),
            "--spares".into(),
            spares.to_string(),
            "--active".into(),
            active.to_string(),
            "--bind".into(),
            addrs[i].clone(),
            "--consistency".into(),
            consistency.label(),
            "--seed".into(),
            seed.to_string(),
            "--app".into(),
            app_name.clone(),
            "--deterministic".into(),
            (if deterministic { "true" } else { "false" }).to_string(),
        ];
        // Dump assignments: each surviving primary dumps its own state; a
        // killed primary's dump is re-targeted at the node the detector
        // will promote in its place — its replica 0 when configured, else
        // (WAL fallback) the spare the detector pops (LIFO, so the
        // highest spare id serves the single kill --replicas 0 allows).
        // Either way the promoted node writes the same shard_<p>.ckp the
        // merge step below expects.
        let dump_owner = if i < shards {
            (!killed.contains(&i)).then_some(i)
        } else if i < total {
            killed.iter().find(|&&p| shards + p * replicas == i).copied()
        } else if replicas == 0 && i == total_nodes - 1 {
            killed.first().copied()
        } else {
            None
        };
        if let Some(owner) = dump_owner {
            let dump = out.join(format!("shard_{owner}.ckp"));
            sargs.extend([
                "--dump".into(),
                dump.to_str().context("non-utf8 dump path")?.to_string(),
            ]);
            dumps.push(dump);
        }
        if i >= total {
            if let Some(&p) = killed.get(i - total) {
                sargs.extend(["--replica-of".into(), p.to_string()]);
            }
        }
        if migrate.is_some() || spares > 0 {
            // Peer dials (handoff links, re-replication row cuts) need
            // the full address list.
            sargs.extend(["--cluster".into(), cluster_list.clone()]);
        }
        if migrate.is_some() {
            sargs.extend(mig_flags.iter().cloned());
        }
        if !fault_spec.is_empty() {
            sargs.extend(["--fault-plan".into(), fault_spec.clone()]);
        }
        if metrics {
            sargs.extend(["--metrics-addr".into(), metrics_addrs[i].clone()]);
        }
        if let Some(d) = &trace_dir {
            sargs.extend([
                "--trace-out".into(),
                trace_file(d, format!("trace_shard_{i}.jsonl"))?,
            ]);
            if trace_debug {
                sargs.extend(["--trace-debug".into(), "true".into()]);
            }
        }
        if trace_spans.is_some() {
            let part = out.join(format!("spans_shard_{i}.json"));
            sargs.extend([
                "--trace-spans".into(),
                part.to_str().context("non-utf8 span path")?.to_string(),
                "--span-sample".into(),
                span_sample.to_string(),
            ]);
            span_parts.push((format!("shard {i}"), part));
        }
        if hot_keys > 0 {
            sargs.extend(["--hot-keys".into(), hot_keys.to_string()]);
        }
        sargs.extend(dur_flags.iter().cloned());
        sargs.extend(app_flags.iter().cloned());
        let child = Command::new(&exe).args(&sargs).spawn();
        let child = match child {
            Ok(c) => c,
            Err(e) => {
                kill_all(&mut children);
                return Err(anyhow::Error::from(e).context(format!("spawning shard {i}")));
            }
        };
        children.push(("shard", i, child));
    }
    // WAL-fallback promotion lands on a spare with a possibly un-fsynced
    // tail gap; workers close it by re-sending their recent flushes, so
    // the resend window defaults on for that shape.
    let resend_window = args.u64(
        "resend-window",
        if !killed.is_empty() && replicas == 0 { 16 } else { 0 },
    );
    for w in 0..workers {
        let mut wargs: Vec<String> = vec![
            "run-worker".into(),
            "--index".into(),
            w.to_string(),
            "--workers".into(),
            workers.to_string(),
            "--replicas".into(),
            replicas.to_string(),
            "--spares".into(),
            spares.to_string(),
            "--active".into(),
            active.to_string(),
            "--cluster".into(),
            cluster_list.clone(),
            "--clocks".into(),
            clocks.to_string(),
            "--consistency".into(),
            consistency.label(),
            "--app".into(),
            app_name.clone(),
        ];
        wargs.extend(mig_flags.iter().cloned());
        if resend_window > 0 {
            wargs.extend(["--resend-window".into(), resend_window.to_string()]);
        }
        if !fault_spec.is_empty() {
            wargs.extend(["--fault-plan".into(), fault_spec.clone()]);
        }
        if metrics {
            wargs.extend(["--metrics-addr".into(), metrics_addrs[total + w].clone()]);
        }
        if stats_pull_every > 0 {
            wargs.extend([
                "--stats-pull-every".into(),
                stats_pull_every.to_string(),
            ]);
        }
        if let Some(d) = &trace_dir {
            wargs.extend([
                "--trace-out".into(),
                trace_file(d, format!("trace_worker_{w}.jsonl"))?,
            ]);
            if trace_debug {
                wargs.extend(["--trace-debug".into(), "true".into()]);
            }
        }
        if trace_spans.is_some() {
            let part = out.join(format!("spans_worker_{w}.json"));
            wargs.extend([
                "--trace-spans".into(),
                part.to_str().context("non-utf8 span path")?.to_string(),
                "--span-sample".into(),
                span_sample.to_string(),
            ]);
            span_parts.push((format!("worker {w}"), part));
        }
        wargs.extend(app_flags.iter().cloned());
        let child = Command::new(&exe).args(&wargs).spawn();
        let child = match child {
            Ok(c) => c,
            Err(e) => {
                kill_all(&mut children);
                return Err(anyhow::Error::from(e).context(format!("spawning worker {w}")));
            }
        };
        children.push(("worker", w, child));
    }

    // The launcher IS the coordinator: when the run can lose a node
    // (kill faults) or heal one (spares), it runs the failure-detecting
    // control loop (`ps::failover::Detector`) over its own TCP endpoint,
    // dialing every shard node for heartbeats (StatsPull/StatsReport)
    // and emitting the recovery deltas itself. No process is pre-armed
    // with the failure schedule — death is observed, not announced.
    let failover_active = !killed.is_empty() || spares > 0;
    let mut coordinator = None;
    if failover_active {
        let (coord_tx, coord_rx) = channel::<ToWorker>();
        let (ev_tx, ev_rx) = channel::<PeerEvent>();
        let coord_net = TcpTransport::endpoint_with_events(
            vec![(NodeId::Coordinator, LocalSink::Worker(coord_tx))],
            Some(ev_tx),
            None,
        );
        let timeout = Duration::from_secs(args.u64("connect-timeout-s", 30));
        for (n, a) in addrs.iter().enumerate() {
            let sa = match a
                .to_socket_addrs()
                .ok()
                .and_then(|mut it| it.next())
                .with_context(|| format!("resolving shard {n} address {a:?}"))
            {
                Ok(sa) => sa,
                Err(e) => {
                    kill_all(&mut children);
                    return Err(e);
                }
            };
            if let Err(e) = coord_net.dial(NodeId::Coordinator, NodeId::Shard(n), sa, timeout) {
                kill_all(&mut children);
                return Err(e.context(format!("coordinator dialing shard {n}")));
            }
        }
        let active_eff = if active == 0 { shards } else { active };
        let stop = Arc::new(AtomicBool::new(false));
        let det = Detector::new(
            failover.clone(),
            PlacementMap::new(shards, active_eff, replicas),
            (total..total_nodes).collect(),
            args.opt_str("wal").is_some(),
            coord_net.handle(),
            ev_rx,
            coord_rx,
            None,
            Arc::clone(&stop),
        );
        let resolved = det.resolved_handle();
        let handle = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || det.run())
            .context("spawning coordinator thread")?;
        coordinator = Some((coord_net, handle, resolved, stop));
    }

    // Poll rather than wait sequentially: when one process fails, the
    // survivors must be killed (they would otherwise block forever on
    // their dead peer) instead of being waited on indefinitely.
    let fo_deadline = failover
        .deadline
        .filter(|_| !killed.is_empty())
        .map(|d| Instant::now() + d);
    let mut failed = false;
    while !children.is_empty() && !failed {
        let mut still = Vec::new();
        for (kind, i, mut child) in children {
            match child.try_wait() {
                Ok(Some(status)) if status.success() => {}
                Ok(Some(status)) => {
                    eprintln!("{kind} {i} exited with {status}");
                    failed = true;
                }
                Ok(None) => still.push((kind, i, child)),
                Err(e) => {
                    eprintln!("waiting for {kind} {i}: {e}");
                    failed = true;
                }
            }
        }
        children = still;
        // The bounded failover window: a planned kill whose recovery has
        // not been emitted by the deadline aborts the whole run with a
        // named error rather than letting stalled workers hang CI.
        if let (Some(dl), Some((_, _, resolved, _))) = (fo_deadline, coordinator.as_ref()) {
            if Instant::now() > dl && resolved.load(Ordering::Acquire) < killed.len() {
                kill_all(&mut children);
                let (_, handle, resolved, stop) = coordinator.take().unwrap();
                stop.store(true, Ordering::Release);
                let _ = handle.join();
                bail!(
                    "failover_deadline_exceeded: {} of {} failed shard(s) recovered \
                     within {:?}; cluster terminated",
                    resolved.load(Ordering::Acquire),
                    killed.len(),
                    failover.deadline.unwrap()
                );
            }
        }
        if !failed && !children.is_empty() {
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    if failed {
        kill_all(&mut children);
        bail!("cluster run had failing processes; survivors were terminated");
    }

    // Harvest the detector. A kill on the run's final clocks may be
    // confirmed only after the workers finish, so give any planned death
    // a short drain before stopping — then stop promptly, before the
    // shard processes' own exits start looking like fresh failures.
    let failover_report = coordinator.take().map(|(coord_net, handle, resolved, stop)| {
        let drain = Instant::now() + Duration::from_secs(5);
        while resolved.load(Ordering::Acquire) < killed.len() && Instant::now() < drain {
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Release);
        let report = handle.join().expect("coordinator thread panicked");
        coord_net.close_send();
        coord_net.join();
        report
    });
    if let Some(rep) = &failover_report {
        if !rep.dead.is_empty() {
            println!(
                "failover: dead {:?}, promotions {:?}, re-attached {:?}{} \
                 ({} heartbeats, epoch {})",
                rep.dead,
                rep.promotions,
                rep.attached,
                rep.failover_ms
                    .map(|ms| format!(", first window {ms}ms"))
                    .unwrap_or_default(),
                rep.heartbeats,
                rep.final_epoch,
            );
        }
        // End-of-run teardown can race a final heartbeat into a closing
        // socket; only planned kills count toward the loud verdict.
        let lost: Vec<usize> = rep
            .unreplicated
            .iter()
            .copied()
            .filter(|p| killed.contains(p))
            .collect();
        ensure!(
            lost.is_empty(),
            "failover_unreplicated: partition(s) {lost:?} died with no live \
             replica and no durable spare; parameter state was lost"
        );
    }

    let mut table_rows: HashMap<Key, Vec<f32>> = HashMap::new();
    for d in &dumps {
        table_rows.extend(checkpoint::load(d)?);
    }
    println!(
        "cluster run complete: {workers} workers x {shards} shards, {} rows, \
         params digest {:016x}",
        table_rows.len(),
        params_digest(&table_rows)
    );
    match app_name.as_str() {
        "logreg" => {
            let cfg = LogRegConfig {
                lr,
                seed: data_seed,
                ..LogRegConfig::default()
            };
            let data = LogRegData::generate(&cfg);
            let w = table_rows
                .get(&(W_TABLE, 0))
                .context("weight row missing from shard dumps")?;
            println!(
                "  log loss {:.4}  accuracy {:.3}",
                data.log_loss(w),
                data.accuracy(w)
            );
        }
        "counter" => {
            let total = table_rows.get(&(0, 0)).map(|r| r[0]).unwrap_or(0.0);
            println!("  counter {total} (expected {})", workers as u64 * clocks);
        }
        _ => {}
    }

    // Merge the per-process span parts into one Chrome trace document:
    // a sampled request's client-, transport-, and shard-side segments
    // share one trace id, so the merged file shows individual requests
    // crossing process boundaries.
    if let Some(path) = &trace_spans {
        let mut parts: Vec<(String, Json)> = Vec::new();
        for (label, file) in &span_parts {
            let body = std::fs::read_to_string(file)
                .with_context(|| format!("reading span part {}", file.display()))?;
            let doc = Json::parse(&body)
                .map_err(|e| anyhow::anyhow!("span part {}: {e:?}", file.display()))?;
            parts.push((label.clone(), doc));
        }
        let merged = merge_chrome_docs(&parts);
        std::fs::write(path, merged.to_string_pretty(1))
            .with_context(|| format!("writing merged spans to {}", path.display()))?;
        println!(
            "spans: merged {} process parts -> {}",
            parts.len(),
            path.display()
        );
    }
    Ok(())
}

/// `ps-top`: poll one or more admin scrape endpoints (`--scrape a,b,...`)
/// and render per-node tables. A worker endpoint whose client runs with
/// `--stats-pull-every` also carries wire-shipped shard rows (its
/// [`ShardReportMirror`]), so pointing ps-top at a single worker shows
/// live cluster-wide state. `--iters N` bounds the loop (0 = run until
/// interrupted); `--interval-ms` sets the poll cadence.
///
/// [`ShardReportMirror`]: essptable::ps::client::ShardReportMirror
fn cmd_ps_top(args: &Args) -> anyhow::Result<()> {
    let addrs = args.strs("scrape");
    ensure!(
        !addrs.is_empty(),
        "ps-top needs --scrape host:port[,host:port...] (each node's \
         --metrics-addr; `run-cluster --metrics true` prints the full map)"
    );
    let interval = Duration::from_millis(args.u64("interval-ms", 1000));
    let iters = args.u64("iters", 0);
    let timeout = Duration::from_secs(2);
    let mut round = 0u64;
    // Per-poll rates: previous counter values keyed "addr|node|metric";
    // the delta over the measured inter-poll interval is the live rate.
    let mut prev: HashMap<String, u64> = HashMap::new();
    let mut last_poll = Instant::now();
    loop {
        round += 1;
        // First round has no baseline — rate cells stay blank.
        let elapsed = if round > 1 {
            last_poll.elapsed().as_secs_f64()
        } else {
            0.0
        };
        last_poll = Instant::now();
        println!("== ps-top round {round}");
        println!(
            "  {:<22} {:<14} {:>10} {:>10} {:>8} {:>8} {:>8} {:>7} {:>9} {:>9}",
            "endpoint", "node", "reads", "upd/pull", "reads/s", "upds/s", "commits", "queue",
            "p50(us)", "p99(us)"
        );
        for addr in &addrs {
            match admin::scrape(addr, "/json", timeout) {
                Ok(body) => match Json::parse(&body) {
                    Ok(doc) => print_top_rows(addr, &doc, &mut prev, elapsed),
                    Err(e) => println!("  {addr:<22} <bad json: {e:?}>"),
                },
                Err(e) => println!("  {addr:<22} <unreachable: {e}>"),
            }
        }
        if iters != 0 && round >= iters {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// One table row per node in one endpoint's JSON snapshot. Shard and
/// worker registries use different metric names for the analogous idea
/// (a shard *serves* gets, a worker *issues* them); each cell takes the
/// first name the node actually has, and stays blank otherwise (tcp and
/// fault rows mostly show blanks — their numbers live in `/json`).
///
/// `prev` holds the last poll's counter values (keyed addr|node|metric)
/// so the rate cells show the per-interval delta; a node carrying
/// hot-key sketch entries (`hot.g.*` / `hot.u.*`) or span segment
/// histograms (`span.*`) gets an extra panel line under its row.
fn print_top_rows(addr: &str, doc: &Json, prev: &mut HashMap<String, u64>, elapsed: f64) {
    let nodes = match doc.get("nodes").and_then(|n| n.as_arr()) {
        Ok(n) => n,
        Err(e) => {
            println!("  {addr:<22} <unexpected document: {e:?}>");
            return;
        }
    };
    for node in nodes {
        let name = node.get("node").and_then(|n| n.as_str()).unwrap_or("?");
        let lookup = |keys: &[&str]| -> Option<(String, u64)> {
            keys.iter().find_map(|k| {
                node.get("metrics")
                    .and_then(|o| o.get(k))
                    .and_then(|v| v.as_u64())
                    .ok()
                    .map(|v| (k.to_string(), v))
            })
        };
        let metric = |keys: &[&str]| -> String {
            lookup(keys).map(|(_, v)| v.to_string()).unwrap_or_default()
        };
        let mut rate = |keys: &[&str]| -> String {
            let Some((k, v)) = lookup(keys) else {
                return String::new();
            };
            let before = prev.insert(format!("{addr}|{name}|{k}"), v);
            match before {
                Some(p) if elapsed > 0.0 => {
                    format!("{:.0}", v.saturating_sub(p) as f64 / elapsed)
                }
                _ => String::new(),
            }
        };
        let quant = |hists: &[&str], p: &str| -> String {
            hists
                .iter()
                .find_map(|h| {
                    node.get("hists")
                        .and_then(|o| o.get(h))
                        .and_then(|o| o.get(p))
                        .and_then(|v| v.as_f64())
                        .ok()
                })
                .map(|ns| format!("{:.0}", ns / 1_000.0))
                .unwrap_or_default()
        };
        let reads_rate = rate(&["gets_served", "gets"]);
        let upds_rate = rate(&["updates_applied", "pulls"]);
        println!(
            "  {addr:<22} {name:<14} {:>10} {:>10} {reads_rate:>8} {upds_rate:>8} {:>8} {:>7} \
             {:>9} {:>9}",
            metric(&["gets_served", "gets"]),
            metric(&["updates_applied", "pulls"]),
            metric(&["commits"]),
            metric(&["queue_depth"]),
            quant(&["read_latency_ns", "wal_append_ns"], "p50"),
            quant(&["read_latency_ns", "wal_append_ns"], "p99"),
        );
        // Hot-key panel: the shard's space-saving sketch ships its top-K
        // entries as plain metrics named hot.g.<table>:<row> (GETs) and
        // hot.u.<table>:<row> (updates).
        let mut hot: Vec<(&str, &str, u64)> = node
            .get("metrics")
            .and_then(|o| o.as_obj())
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| {
                        let (kind, key) = if let Some(r) = k.strip_prefix("hot.g.") {
                            ("G", r)
                        } else if let Some(r) = k.strip_prefix("hot.u.") {
                            ("U", r)
                        } else {
                            return None;
                        };
                        v.as_u64().ok().map(|c| (kind, key, c))
                    })
                    .collect()
            })
            .unwrap_or_default();
        if !hot.is_empty() {
            hot.sort_by(|a, b| b.2.cmp(&a.2).then(a.1.cmp(b.1)).then(a.0.cmp(b.0)));
            let cells: Vec<String> = hot
                .iter()
                .take(8)
                .map(|(kind, key, c)| format!("{kind}:{key}={c}"))
                .collect();
            println!("  {:<22} {name:<14} hot keys  {}", "", cells.join("  "));
        }
        // Span-segment panel: per-segment latency families recorded by
        // the causal tracing plane (span.<segment>_us histograms).
        let segs: Vec<String> = node
            .get("hists")
            .and_then(|o| o.as_obj())
            .map(|m| {
                m.iter()
                    .filter(|(k, _)| k.starts_with("span."))
                    .filter_map(|(k, v)| {
                        let p50 = v.get("p50").and_then(|x| x.as_f64()).ok()?;
                        let p99 = v.get("p99").and_then(|x| x.as_f64()).ok()?;
                        let seg = k.strip_prefix("span.").unwrap_or(k);
                        let seg = seg.strip_suffix("_us").unwrap_or(seg);
                        Some(format!("{seg} {p50:.0}/{p99:.0}"))
                    })
                    .collect()
            })
            .unwrap_or_default();
        if !segs.is_empty() {
            println!(
                "  {:<22} {name:<14} spans p50/p99(us)  {}",
                "",
                segs.join("  ")
            );
        }
    }
}

fn parse_list<T: std::str::FromStr>(s: &str) -> anyhow::Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .filter(|x| !x.is_empty())
        .map(|x| {
            x.trim()
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad list item {x:?}: {e}"))
        })
        .collect()
}
