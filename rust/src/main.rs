//! `essptable` CLI: the launcher for training runs and for regenerating
//! every paper figure (DESIGN.md §4).
//!
//! Subcommands:
//!   mf | lda | logreg | lm      — run one workload once, print a summary
//!   fig1-staleness              — Fig. 1 (left): staleness distributions
//!   fig1-breakdown              — Fig. 1 (right): comm/comp breakdown
//!   fig2-mf | fig2-lda          — Fig. 2: convergence curves
//!   robustness                  — §Robustness: step-size x staleness grid
//!   vap-compare                 — §VAP: stall cost vs ESSP
//!   artifacts                   — list AOT artifacts and their specs
//!
//! Common flags: --workers N --shards N --clocks N --seed N
//!   --consistency bsp|ssp:S|essp:S|async[:R]|vap:V0
//!   --straggler none|uniform:F|fixed:W,..xF|spikes:P,F|rotating:PxF
//!   --net lan|instant --out results/

use std::path::PathBuf;
use std::process::ExitCode;

use essptable::apps::lda::gibbs::run_lda;
use essptable::apps::lda::LdaConfig;
use essptable::apps::lm::{run_lm, LmTrainConfig};
use essptable::apps::logreg::{run_logreg, LogRegConfig};
use essptable::apps::mf::train::{final_sq_loss, run_mf, MfBackend, MF_ARTIFACT};
use essptable::apps::mf::MfConfig;
use essptable::harness::{self, ExpOpts};
use essptable::metrics::export;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::RunReport;
use essptable::runtime::artifact::ArtifactDir;
use essptable::runtime::engine::RuntimeService;
use essptable::sim::straggler::StragglerModel;
use essptable::util::cli::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("mf") => cmd_mf(&args),
        Some("lda") => cmd_lda(&args),
        Some("logreg") => cmd_logreg(&args),
        Some("lm") => cmd_lm(&args),
        Some("fig1-staleness") => cmd_fig1_staleness(&args),
        Some("fig1-breakdown") => cmd_fig1_breakdown(&args),
        Some("fig2-mf") => cmd_fig2_mf(&args),
        Some("fig2-lda") => cmd_fig2_lda(&args),
        Some("robustness") => cmd_robustness(&args),
        Some("vap-compare") => cmd_vap_compare(&args),
        Some("artifacts") => cmd_artifacts(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    let unused = args.unused();
    if !unused.is_empty() {
        eprintln!("warning: unused flags: {unused:?}");
    }
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: essptable <subcommand> [flags]
  workloads:    mf | lda | logreg | lm
  experiments:  fig1-staleness | fig1-breakdown | fig2-mf | fig2-lda
                robustness | vap-compare
  inspection:   artifacts
  common flags: --workers N --shards N --clocks N --seed N
                --consistency bsp|ssp:S|essp:S|async[:R]|vap:V0
                --straggler none|uniform:F|... --net lan|instant
                --out DIR  (see README.md for per-command flags)";

fn opts(args: &Args) -> anyhow::Result<ExpOpts> {
    Ok(ExpOpts {
        workers: args.usize("workers", 8),
        shards: args.usize("shards", 4),
        seed: args.u64("seed", 42),
        clocks: args.u64("clocks", 60),
        out_dir: PathBuf::from(args.str("out", "results")),
        straggler: StragglerModel::parse(&args.str("straggler", "uniform:3"))
            .map_err(anyhow::Error::msg)?,
        lan: args.str("net", "lan") == "lan",
        virtual_clock_ms: args.u64("virtual-clock-ms", 25),
    })
}

fn consistency(args: &Args, default: &str) -> anyhow::Result<Consistency> {
    Consistency::parse(&args.str("consistency", default)).map_err(anyhow::Error::msg)
}

fn mf_config(args: &Args) -> MfConfig {
    MfConfig {
        rows: args.usize("rows", 512),
        cols: args.usize("cols", 512),
        rank: args.usize("rank", 32),
        true_rank: args.usize("true-rank", 8),
        nnz_per_row: args.usize("nnz-per-row", 48),
        noise: args.f32("noise", 0.05),
        gamma: args.f32("gamma", 0.03),
        lambda: args.f32("lambda", 0.05),
        minibatch: args.f64("minibatch", 0.25),
        ..MfConfig::default()
    }
}

fn lda_config(args: &Args) -> LdaConfig {
    LdaConfig {
        vocab: args.usize("vocab", 500),
        topics: args.usize("topics", 10),
        docs: args.usize("docs", 400),
        doc_len: args.usize("doc-len", 64),
        minibatch: args.f64("minibatch", 0.5),
        ..LdaConfig::default()
    }
}

fn print_report(label: &str, report: &RunReport, final_value: f64, value_name: &str) {
    println!("== {label}");
    println!("  wall            {:.2}s", report.wall.as_secs_f64());
    println!("  {value_name:<15} {final_value:.4}");
    println!(
        "  staleness       mean {:+.3} var {:.3} range [{}, {}]",
        report.staleness.mean(),
        report.staleness.variance(),
        report.staleness.min().unwrap_or(0),
        report.staleness.max().unwrap_or(0),
    );
    println!(
        "  comm fraction   {:.1}%   net {} msgs / {:.1} MB",
        100.0 * report.comm_fraction(),
        report.net_messages,
        report.net_bytes as f64 / 1e6
    );
    if let Some((stall, reads)) = report.vap_stall {
        println!(
            "  vap stalls      {:.2}s across {reads} reads",
            stall.as_secs_f64()
        );
    }
}

fn cmd_mf(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let mf = mf_config(args);
    let backend = if args.bool("xla", false) {
        let rt = RuntimeService::start(ArtifactDir::open(
            args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
        )?)?;
        let handle = rt.handle();
        handle.preload(MF_ARTIFACT)?;
        // Leak the service so the handle stays valid for the whole run.
        std::mem::forget(rt);
        MfBackend::Xla(handle)
    } else {
        MfBackend::Native
    };
    let (report, data) = run_mf(o.cluster(c), mf, o.clocks, backend);
    print_report(&c.label(), &report, final_sq_loss(&report, &data), "sq loss");
    Ok(())
}

fn cmd_lda(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let (report, _) = run_lda(o.cluster(c), lda_config(args), o.clocks);
    let ll = report.convergence.last_value().unwrap_or(f64::NAN);
    print_report(&c.label(), &report, ll, "log-likelihood");
    Ok(())
}

fn cmd_logreg(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:3")?;
    let (report, data) = run_logreg(o.cluster(c), LogRegConfig::default(), o.clocks);
    let w = &report.table_rows[&(essptable::apps::logreg::W_TABLE, 0)];
    print_report(&c.label(), &report, data.log_loss(w), "log loss");
    println!("  accuracy        {:.3}", data.accuracy(w));
    Ok(())
}

fn cmd_lm(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let c = consistency(args, "essp:1")?;
    let artifact = args.str("artifact", "lm_step_gpt-tiny");
    let art_dir = ArtifactDir::open(
        args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
    )?;
    let meta = art_dir.meta(&artifact)?.clone();
    let rt = RuntimeService::start(art_dir)?;
    let cfg = LmTrainConfig {
        artifact,
        lr: args.f32("lr", 0.12),
        lr_decay: args.f64("lr-decay", 200.0),
        seed: o.seed,
        branch: args.usize("branch", 4),
    };
    let report = run_lm(o.cluster(c), cfg, &meta, rt.handle(), o.clocks)?;
    let series = report.convergence.mean();
    print_report(
        &c.label(),
        &report,
        series.last().map(|s| s.value).unwrap_or(f64::NAN),
        "final loss",
    );
    export::convergence_csv(&o.out("lm_loss.csv"), &[(c.label(), series.clone())])?;
    println!("  loss curve -> {}", o.out("lm_loss.csv").display());
    if let Some(first) = series.first() {
        println!(
            "  loss {:.4} (clock 0) -> {:.4} (clock {})",
            first.value,
            series.last().unwrap().value,
            series.last().unwrap().clock
        );
    }
    Ok(())
}

fn cmd_fig1_staleness(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let s = args.u64("staleness", 3) as i64;
    let runs = harness::fig1_staleness(&o, mf_config(args), s)?;
    harness::write_staleness_summary(&o.out("fig1_staleness_summary.json"), &runs)?;
    println!("Fig. 1 (left) — staleness distributions (MF, s={s})");
    for run in &runs {
        println!(
            "  {:<8} mean {:+.3}  var {:.3}  range [{}, {}]  (n={})",
            run.label,
            run.report.staleness.mean(),
            run.report.staleness.variance(),
            run.report.staleness.min().unwrap_or(0),
            run.report.staleness.max().unwrap_or(0),
            run.report.staleness.total(),
        );
    }
    println!("csv -> {}", o.out("fig1_staleness.csv").display());
    // Theorem 5 on the measured profiles: the theory's account of why the
    // ESSP profile converges faster (see ps::theory).
    if runs.len() == 2 {
        let params = essptable::ps::theory::BoundParams {
            lipschitz: 1.0,
            f_sq: 1.0,
            eta: 0.1,
            workers: o.workers,
            staleness: s,
            horizon: o.clocks * o.workers as u64,
        };
        println!("\nTheorem 5 on the measured profiles (L=1, F=1, eta=0.1):");
        print!(
            "{}",
            essptable::ps::theory::compare_report(
                &params,
                &runs[0].label,
                &runs[0].report.staleness,
                &runs[1].label,
                &runs[1].report.staleness,
            )
        );
    }
    Ok(())
}

fn cmd_fig1_breakdown(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "0,1,2,4,8"))?;
    let rows = harness::fig1_breakdown(&o, lda_config(args), &staleness)?;
    println!("Fig. 1 (right) — comm/comp breakdown (LDA)");
    println!("  {:<10} {:>9} {:>9} {:>7}", "label", "comp(s)", "comm(s)", "comm%");
    for (label, comp, comm, frac) in &rows {
        println!("  {label:<10} {comp:>9.2} {comm:>9.2} {:>6.1}%", 100.0 * frac);
    }
    println!("csv -> {}", o.out("fig1_breakdown.csv").display());
    Ok(())
}

fn cmd_fig2_mf(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "2,5"))?;
    let runs = harness::fig2_mf(&o, mf_config(args), &staleness)?;
    println!("Fig. 2 (MF) — convergence (final squared loss, lower is better)");
    for run in &runs {
        println!(
            "  {:<8} final {:>12.2}  wall {:>6.2}s",
            run.label,
            run.final_value,
            run.report.wall.as_secs_f64()
        );
    }
    println!("csv -> {}", o.out("fig2_mf.csv").display());
    Ok(())
}

fn cmd_fig2_lda(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "2,5"))?;
    let runs = harness::fig2_lda(&o, lda_config(args), &staleness)?;
    println!("Fig. 2 (LDA) — convergence (final log-likelihood, higher is better)");
    for run in &runs {
        println!(
            "  {:<8} final {:>14.1}  wall {:>6.2}s",
            run.label,
            run.final_value,
            run.report.wall.as_secs_f64()
        );
    }
    println!("csv -> {}", o.out("fig2_lda.csv").display());
    Ok(())
}

fn cmd_robustness(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let gammas: Vec<f32> = parse_list(&args.str("gammas", "0.05,0.1,0.2"))?;
    let staleness: Vec<i64> = parse_list(&args.str("staleness-list", "0,2,5,10"))?;
    let rows = harness::robustness(&o, mf_config(args), &gammas, &staleness)?;
    println!("§Robustness — MF final loss across step size x staleness");
    println!("  {:<10} {:>7} {:>14} {:>9}", "label", "gamma", "final_loss", "diverged");
    for r in &rows {
        println!(
            "  {:<10} {:>7} {:>14.2} {:>9}",
            r.label, r.gamma, r.final_loss, r.diverged
        );
    }
    println!("csv -> {}", o.out("robustness.csv").display());
    Ok(())
}

fn cmd_vap_compare(args: &Args) -> anyhow::Result<()> {
    let o = opts(args)?;
    let v0s: Vec<f32> = parse_list(&args.str("v0s", "0.5,0.1,0.02"))?;
    let s = args.u64("staleness", 3) as i64;
    let rows = harness::vap_compare(&o, mf_config(args), &v0s, s)?;
    println!("§VAP — value-bound enforcement cost vs ESSP");
    println!(
        "  {:<10} {:>8} {:>12} {:>9} {:>13}",
        "label", "wall(s)", "final_loss", "stall(s)", "stalled_reads"
    );
    for r in &rows {
        println!(
            "  {:<10} {:>8.2} {:>12.2} {:>9.2} {:>13}",
            r.label,
            r.wall.as_secs_f64(),
            r.final_loss,
            r.stall.as_secs_f64(),
            r.stalled_reads
        );
    }
    println!("csv -> {}", o.out("vap_compare.csv").display());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = ArtifactDir::open(
        args.str("artifacts", ArtifactDir::default_dir().to_str().unwrap()),
    )?;
    println!("artifacts in {}:", dir.dir().display());
    for name in dir.names() {
        let m = dir.meta(name)?;
        println!(
            "  {name}: {} inputs, {} outputs{}",
            m.inputs.len(),
            m.outputs.len(),
            m.lm_config
                .as_ref()
                .map(|c| format!(
                    " (LM {}: {} params, vocab {}, seq {})",
                    c.preset, c.param_count, c.vocab, c.seq
                ))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn parse_list<T: std::str::FromStr>(s: &str) -> anyhow::Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .filter(|x| !x.is_empty())
        .map(|x| {
            x.trim()
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad list item {x:?}: {e}"))
        })
        .collect()
}
