//! ESSPTable: a parameter-server framework with pluggable consistency
//! models, reproducing *High-Performance Distributed ML at Scale through
//! Parameter Server Consistency Models* (Dai et al., AAAI 2015).
//!
//! Layering (see DESIGN.md):
//! * [`ps`] — the parameter server: GET/INC/CLOCK client, sharded server,
//!   the consistency-policy engine (`ps::policy`) enforcing
//!   BSP / SSP / ESSP / Async / VAP / AVAP as pluggable policy pairs, and
//!   the elastic shard plane (`ps::placement`): epoch-versioned key
//!   placement, live key migration, and replica read fan-out.
//! * [`transport`] — the data plane: binary wire codec plus two backends,
//!   the in-process simulated network and a real TCP transport for
//!   multi-process clusters.
//! * [`sim`] — the simulated cluster substrate (network, stragglers).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX+Pallas
//!   artifacts (`artifacts/*.hlo.txt`).
//! * [`apps`] — the paper's workloads (MF-SGD, LDA Gibbs) plus the LM
//!   trainer and logistic regression.
//! * [`metrics`] — staleness histograms, comm/comp timelines, convergence.
//! * [`telemetry`] — the live plane: per-node atomic metrics registries,
//!   wire-shipped stats snapshots, `--metrics-addr` admin scrape sockets,
//!   and the bounded event-trace ring (`--trace-out`).
//! * [`harness`] — experiment drivers regenerating each paper figure.

// Crate lint policy (CI runs `cargo clippy -- -D warnings`): these style
// lints are deliberately accepted — constructor-style `new()` without
// `Default`, protocol structs/fns whose arity mirrors the wire messages,
// and index loops over parallel per-worker arrays read better here.
#![allow(
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::needless_range_loop,
    clippy::manual_div_ceil
)]

pub mod util {
    pub mod benchkit;
    pub mod cli;
    pub mod hash;
    pub mod json;
    pub mod rng;
    pub mod stats;
}

pub mod sim {
    pub mod fault;
    pub mod net;
    pub mod priority;
    pub mod straggler;
}

pub mod transport;

pub mod ps {
    pub mod cache;
    pub mod client;
    pub mod consistency;
    pub mod durability;
    pub mod failover;
    pub mod kernels;
    pub mod msg;
    pub mod placement;
    pub mod policy;
    pub mod server;
    pub mod shard;
    pub mod theory;
    pub mod types;
    pub mod update;
    pub mod vap;
    pub mod vclock;

    // `ps::checkpoint` moved under the durability plane; keep the old
    // path alive for callers and docs.
    pub use self::durability::checkpoint;
}

pub mod metrics {
    pub mod convergence;
    pub mod export;
    pub mod staleness;
    pub mod timeline;
}

pub mod telemetry;

pub mod runtime {
    pub mod artifact;
    pub mod engine;
}

pub mod apps {
    pub mod lda;
    pub mod lm;
    pub mod logreg;
    pub mod mf;
}

pub mod harness;
